"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref)."""

from .hadamard import fwht
from .quantize import fake_quant
from .rotate import matmul, rotate
from .whip import whip_loss
from . import ref

__all__ = ["fwht", "fake_quant", "matmul", "rotate", "whip_loss", "ref"]
