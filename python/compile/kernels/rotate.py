"""L1 Pallas kernel: rotation application O = X @ R (and the general
blocked GEMM it is built from).

TPU shaping: a classic MXU-blocked GEMM. The CUDA threadblock tiling of the
paper's rotation kernels becomes a BlockSpec grid over
(rows/BT, cols/BN, depth/BK) with an f32 output block accumulated across
the K-steps (K innermost so the accumulator block stays resident in VMEM).

Autodiff: Pallas cannot differentiate through grid-accumulator kernels, so
`rotate` carries a hand-written VJP — the backward passes are themselves
calls into the same GEMM kernel (dX = dO @ Rᵀ, dR = Xᵀ @ dO), exactly how a
production QAT stack wires its custom kernels.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128  # row tile
BLOCK_N = 128  # column tile (MXU lane width multiple)
BLOCK_K = 128  # contraction depth per step


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BT, BK) @ (BK, BN) — lands on the MXU systolic array on real TPU.
    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _block(dim, pref):
    """Largest tile <= pref that divides dim (dims here are 2^a * m with
    small m, so this terminates at a sane tile quickly)."""
    b = min(pref, dim)
    while dim % b != 0:
        b -= 1
    return b


def matmul(a, b, *, interpret: bool = True):
    """Blocked Pallas GEMM: (m, k) @ (k, n) -> (m, n)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bt, bn, bk = _block(m, BLOCK_T), _block(n, BLOCK_N), _block(k, BLOCK_K)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bt, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


@jax.custom_vjp
def rotate(x, r):
    """O = X @ R for X (tokens, n), R (n, n) orthogonal."""
    return matmul(x, r)


def _rotate_fwd(x, r):
    return matmul(x, r), (x, r)


def _rotate_bwd(res, g):
    x, r = res
    # dX = g @ Rᵀ ; dR = Xᵀ @ g — both through the same MXU-blocked kernel.
    return matmul(g, r.T), matmul(x.T, g)


rotate.defvjp(_rotate_fwd, _rotate_bwd)
