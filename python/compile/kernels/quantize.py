"""L1 Pallas kernel: per-token asymmetric fake quantization.

TPU shaping: per-token asymmetric quantization reduces along the lane axis
(min/max of each row) then applies scale/round/dequant element-wise — one
pass over a (BT, dim) tile, no cross-tile communication. The row must be
resident in full (the reduction spans it), so tiles are full-width, which
also matches how a real int4 epilogue would fuse into the preceding GEMM.

The level count arrives as a (1, 1) tensor block rather than a baked
constant so one compiled artifact serves every bit-width (the paper's
4-8-16 / 4-4-16 / 4-4-4 settings).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128


def _quant_kernel(x_ref, lv_ref, o_ref):
    x = x_ref[...]
    lv = lv_ref[0, 0]
    mn = jnp.min(x, axis=-1, keepdims=True)
    mx = jnp.max(x, axis=-1, keepdims=True)
    scale = (mx - mn) / jnp.maximum(lv - 1.0, 1.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round((x - mn) / safe)
    o_ref[...] = jnp.where(scale > 0, q * safe + mn, x)


def fake_quant(x, n_levels, *, block_t: int = BLOCK_T, interpret: bool = True):
    """Per-token asymmetric fake quantization of x (tokens, dim) to
    `n_levels` uniform levels (scalar or () array)."""
    t, n = x.shape
    bt = min(block_t, t)
    assert t % bt == 0, f"tokens {t} not a multiple of block {bt}"
    lv = jnp.asarray(n_levels, dtype=x.dtype).reshape(1, 1)
    return pl.pallas_call(
        _quant_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=interpret,
    )(x, lv)
