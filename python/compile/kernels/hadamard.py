"""L1 Pallas kernel: fast Walsh-Hadamard transform (the online R3/R4
rotations of the inference graph, Appendix A).

TPU shaping: the whole (BT, n) tile sits in VMEM (n <= 1536 here), so the
log2(n) butterfly stages run register-to-VMEM without the shared-memory
staging a CUDA FWHT needs. The stage loop is a Python loop — unrolled at
trace time into log2(n) reshaped add/sub pairs, which XLA fuses into a
handful of elementwise ops.

Non-power-of-two orders (12*2^k, 20*2^k) are handled one level up in
`model.py` by a Kronecker factorization: FWHT on the 2^k factor (this
kernel) then a dense (m, m) base multiply.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128


def _fwht_kernel(x_ref, o_ref, *, n):
    x = x_ref[...]
    bt = x.shape[0]
    h = 1
    while h < n:
        x = x.reshape(bt, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(bt, n)
        h *= 2
    o_ref[...] = x * (1.0 / jnp.sqrt(float(n)))


def fwht(x, *, block_t: int = BLOCK_T, interpret: bool = True):
    """Orthonormal FWHT along the last axis of x (tokens, n), n = 2^k."""
    t, n = x.shape
    assert n & (n - 1) == 0, f"FWHT needs power-of-two length, got {n}"
    bt = min(block_t, t)
    assert t % bt == 0, f"tokens {t} not a multiple of block {bt}"
    return pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=interpret,
    )(x)
