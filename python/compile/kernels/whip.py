"""L1 Pallas kernel: Whip loss (Eq. 4) with a hand-written backward kernel.

TPU shaping (DESIGN.md §Hardware-Adaptation): the CUDA warp-reduction the
paper would use becomes a row-tiled VPU reduction with a grid-carried (1,1)
accumulator block — every grid step adds its tile's partial sum into the
same output block, and step 0 initializes it.

Autodiff: grid-accumulator kernels are not Pallas-differentiable, so the
VJP is explicit: dL/dx = -sign(x)·exp(-|x|)/tokens, a pure element-wise
kernel over the same tiling.

`interpret=True` everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls, so kernels lower to plain HLO grid emulation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile height. 128 rows keeps the (tile × dim) block plus the
# accumulator well inside a TPU core's ~16 MiB VMEM for every dim we emit
# (max 640: 128*640*4 B = 320 KiB/block, double-buffered 640 KiB).
BLOCK_T = 128


def _whip_fwd_kernel(x_ref, o_ref, *, inv_tokens):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = 0.0

    # exp(-|x|) is pure VPU element-wise work; the tile reduction happens
    # in-register before touching the accumulator.
    o_ref[0, 0] += jnp.sum(jnp.exp(-jnp.abs(x_ref[...]))) * inv_tokens


def _whip_bwd_kernel(x_ref, o_ref, *, inv_tokens):
    x = x_ref[...]
    o_ref[...] = -jnp.sign(x) * jnp.exp(-jnp.abs(x)) * inv_tokens


def _tile(t, block_t):
    bt = min(block_t, t)
    assert t % bt == 0, f"tokens {t} not a multiple of block {bt}"
    return bt


@jax.custom_vjp
def whip_loss(x):
    """mean_t sum_c exp(-|x_tc|) for x of shape (tokens, dim)."""
    return _whip_value(x)


def _whip_value(x, *, block_t: int = BLOCK_T, interpret: bool = True):
    t, _ = x.shape
    bt = _tile(t, block_t)
    out = pl.pallas_call(
        functools.partial(_whip_fwd_kernel, inv_tokens=1.0 / t),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, x.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=interpret,
    )(x)
    return out[0, 0]


def whip_grad(x, *, block_t: int = BLOCK_T, interpret: bool = True):
    """dWhip/dx — exposed for tests; also the backward kernel."""
    t, n = x.shape
    bt = _tile(t, block_t)
    return pl.pallas_call(
        functools.partial(_whip_bwd_kernel, inv_tokens=1.0 / t),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=interpret,
    )(x)


def _whip_fwd(x):
    return _whip_value(x), x


def _whip_bwd(x, g):
    return (whip_grad(x) * g,)


whip_loss.defvjp(_whip_fwd, _whip_bwd)
