"""Pure-jnp oracles for every Pallas kernel — the L1 correctness ground
truth. The pytest suite sweeps shapes/values (hypothesis) asserting each
kernel matches its oracle; the L2 graphs may call either implementation
(`model.py` uses the kernels inside calibration artifacts and these
references inside the big forward graphs, where interpret-mode grid
emulation would dominate runtime)."""

import jax.numpy as jnp


def whip_ref(x):
    """Whip loss (Eq. 4), averaged over tokens: mean_t sum_c exp(-|x_tc|).

    Token-averaging makes the loss (and learning rates) independent of the
    calibration batch size, matching the per-vector definition in the paper.
    """
    return jnp.mean(jnp.sum(jnp.exp(-jnp.abs(x)), axis=-1))


def rotate_ref(x, r):
    """Rotation application O = X @ R."""
    return x @ r


def fake_quant_ref(x, n_levels):
    """Per-token (row-wise) asymmetric uniform fake quantization.

    scale = (max - min) / (levels - 1), zero-point at min; degenerate rows
    (constant) pass through unchanged.
    """
    mn = jnp.min(x, axis=-1, keepdims=True)
    mx = jnp.max(x, axis=-1, keepdims=True)
    scale = (mx - mn) / jnp.maximum(n_levels - 1.0, 1.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round((x - mn) / safe)
    out = q * safe + mn
    return jnp.where(scale > 0, out, x)


def fwht_ref(x):
    """Orthonormal fast Walsh-Hadamard transform along the last axis
    (power-of-two length), matching rust `linalg::fwht_row` ordering."""
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT needs power-of-two length, got {n}"
    orig_shape = x.shape
    x = x.reshape(-1, n)
    h = 1
    while h < n:
        x = x.reshape(-1, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        h *= 2
    return (x / jnp.sqrt(float(n))).reshape(orig_shape)


def quant_error_ref(x, n_levels):
    """Mean squared fake-quantization error — the 'Quant' ablation
    objective (Fig 7a) and the quant-error metric of Fig 3b."""
    return jnp.mean((fake_quant_ref(x, n_levels) - x) ** 2)
