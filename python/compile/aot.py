"""AOT lowering driver: `python -m compile.aot --out-dir ../artifacts`.

Lowers every L2 graph to **HLO text** (not serialized HloModuleProto — jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids) and writes `manifest.json`, the typed contract
consumed by `rust/src/runtime/`.

This is the only python entry point in the system; `make artifacts` runs it
once and the rust binary is self-contained afterwards.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model
from .configs import BATCH, CALIB_TOKENS, CONFIGS, SEQ
from .kernels import fake_quant, fwht, whip_loss
from .kernels.rotate import rotate

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Emitter:
    def __init__(self, out_dir: str, estimate_flops: bool):
        self.out_dir = out_dir
        self.estimate_flops = estimate_flops
        self.manifest = {"version": 1, "models": {}, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)
        # Partial regeneration (--only) must MERGE with the existing
        # manifest, not clobber the other groups' entries.
        existing = os.path.join(out_dir, "manifest.json")
        if os.path.exists(existing):
            try:
                with open(existing) as f:
                    old = json.load(f)
                self.manifest["artifacts"].update(old.get("artifacts", {}))
            except Exception:
                pass

    def emit(self, name, fn, in_specs, out_names, meta=None):
        """Lower `fn(*args)` -> tuple to `{name}.hlo.txt` + manifest entry.

        in_specs: list of (arg_name, ShapeDtypeStruct).
        out_names: names for the outputs (shapes inferred from lowering).
        """
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
        flops = 0
        if self.estimate_flops:
            try:
                cost = lowered.compile().cost_analysis()
                flops = int(cost.get("flops", 0.0))
            except Exception:
                flops = 0
        text = _to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)

        out_avals = lowered.out_info
        flat, _ = jax.tree_util.tree_flatten(out_avals)
        assert len(flat) == len(out_names), (
            f"{name}: {len(flat)} outputs vs {len(out_names)} names")

        def dt(d):
            return {"float32": "f32", "int32": "i32"}[str(d)]

        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": dt(s.dtype)}
                for n, s in in_specs
            ],
            "outputs": [
                {"name": n, "shape": list(a.shape), "dtype": dt(a.dtype)}
                for n, a in zip(out_names, flat)
            ],
            "flops": flops,
            "meta": meta or {},
        }
        print(f"  {name:40s} {len(text)//1024:6d} KiB  {time.time()-t0:5.1f}s")

    def write_manifest(self):
        for cname, cfg in CONFIGS.items():
            self.manifest["models"][cname] = cfg.to_dict()
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


def _param_specs(cfg):
    return [(n, _spec(configs.param_shape(cfg, n))) for n in configs.param_names(cfg)]


def emit_calibration(em: Emitter):
    """QR-Orth and Cayley calibration steps (Algorithm 1 / Algorithm 3)."""
    for n in configs.CALIB_DIMS:
        sq = _spec((n, n))
        x = _spec((CALIB_TOKENS, n))
        lr = _spec(())
        step = model.make_calib_step_sgd("whip")
        em.emit(
            f"calib_whip_sgd_n{n}", step,
            [("Z", sq), ("M", sq), ("X", x), ("lr", lr)],
            ["Z_new", "M_new", "loss"],
            meta={"objective": "whip", "opt": "sgd", "n": n, "kind": "qr_orth"},
        )
        cay = model.make_cayley_step("whip")
        em.emit(
            f"cayley_whip_sgd_n{n}", cay,
            [("R", sq), ("M", sq), ("X", x), ("lr", lr)],
            ["R_new", "M_new", "loss"],
            meta={"objective": "whip", "opt": "sgd", "n": n, "kind": "cayley"},
        )

    # Ablation objectives (Fig 7a / Table 22) at the two ablation dims.
    for n in (256, 384):
        sq = _spec((n, n))
        x = _spec((CALIB_TOKENS, n))
        lr = _spec(())
        for obj in ("variance", "kurtosis", "quant"):
            step = model.make_calib_step_sgd(obj)
            em.emit(
                f"calib_{obj}_sgd_n{n}", step,
                [("Z", sq), ("M", sq), ("X", x), ("lr", lr)],
                ["Z_new", "M_new", "loss"],
                meta={"objective": obj, "opt": "sgd", "n": n, "kind": "qr_orth"},
            )

    # Adam variants (Fig 7b compares QR-SGD/QR-Adam vs Cayley-SGD/-Adam).
    n = 256
    sq, x, lr, t = _spec((n, n)), _spec((CALIB_TOKENS, n)), _spec(()), _spec(())
    em.emit(
        f"calib_whip_adam_n{n}", model.make_calib_step_adam("whip"),
        [("Z", sq), ("M", sq), ("V", sq), ("t", t), ("X", x), ("lr", lr)],
        ["Z_new", "M_new", "V_new", "t_new", "loss"],
        meta={"objective": "whip", "opt": "adam", "n": n, "kind": "qr_orth"},
    )
    em.emit(
        f"cayley_whip_adam_n{n}", model.make_cayley_step_adam("whip"),
        [("R", sq), ("M", sq), ("V", sq), ("t", t), ("X", x), ("lr", lr)],
        ["R_new", "M_new", "V_new", "t_new", "loss"],
        meta={"objective": "whip", "opt": "adam", "n": n, "kind": "cayley"},
    )


def emit_models(em: Emitter):
    """Forward / capture / quantized-forward graphs for every config."""
    tok = _spec((BATCH, SEQ), I32)
    for cname, cfg in CONFIGS.items():
        pspecs = _param_specs(cfg)
        names = [n for n, _ in pspecs]

        def fwd(*args, cfg=cfg, names=names):
            params = dict(zip(names, args[: len(names)]))
            tokens = args[len(names)]
            return (model.forward_nll(cfg, params, tokens),)

        em.emit(
            f"fwd_{cname}", fwd, pspecs + [("tokens", tok)], ["nll"],
            meta={"model": cname, "kind": "fwd"},
        )

        def fwdq(*args, cfg=cfg, names=names):
            params = dict(zip(names, args[: len(names)]))
            tokens, a_levels, kv_levels, use_had = args[len(names):]
            return (model.forward_nll(cfg, params, tokens, a_levels=a_levels,
                                      kv_levels=kv_levels, use_had=use_had),)

        em.emit(
            f"fwdq_{cname}", fwdq,
            pspecs + [("tokens", tok), ("a_levels", _spec(())),
                      ("kv_levels", _spec(())), ("use_had", _spec(()))],
            ["nll"],
            meta={"model": cname, "kind": "fwdq"},
        )

        def capture(*args, cfg=cfg, names=names):
            params = dict(zip(names, args[: len(names)]))
            tokens = args[len(names)]
            xs, vs = model.capture_sites(cfg, params, tokens)
            # XLA prunes unused parameters from the compiled executable
            # (head + the last layer's FFN don't affect the captured
            # sites), which would break the fixed input arity the rust
            # side supplies. A 1e-30-weighted checksum output keeps every
            # parameter alive without perturbing the capture numerics.
            live = sum(jnp.sum(p) for p in params.values()) * jnp.float32(1e-30)
            return xs, vs, live

        em.emit(
            f"capture_{cname}", capture, pspecs + [("tokens", tok)],
            ["x_sites", "v_sites", "live"],
            meta={"model": cname, "kind": "capture"},
        )


def emit_spin(em: Emitter):
    """SpinQuant-sim end-to-end Cayley steps (Tables 1, 3; Fig 1)."""
    tok = _spec((BATCH, SEQ), I32)
    for cname in ("llama2-tiny", "llama2-small", "llama2-large"):
        cfg = CONFIGS[cname]
        pspecs = _param_specs(cfg)
        names = [n for n, _ in pspecs]
        d = cfg.dim
        step = model.make_spin_step(cfg)

        def spin(*args, step=step, names=names):
            r1, m = args[0], args[1]
            params = dict(zip(names, args[2: 2 + len(names)]))
            tokens, lr = args[2 + len(names):]
            return step(r1, m, params, tokens, lr)

        em.emit(
            f"spin_{cname}", spin,
            [("R1", _spec((d, d))), ("M", _spec((d, d)))] + pspecs
            + [("tokens", tok), ("lr", _spec(()))],
            ["R1_new", "M_new", "loss"],
            meta={"model": cname, "kind": "spin"},
        )


def emit_train(em: Emitter):
    """Adam train step for the end-to-end example (tiny config only)."""
    tok = _spec((BATCH, SEQ), I32)
    for cname in ("llama2-tiny",):
        cfg = CONFIGS[cname]
        pspecs = _param_specs(cfg)
        names = [n for n, _ in pspecs]
        step = model.make_train_step(cfg)

        def train(*args, step=step, names=names):
            k = len(names)
            params = dict(zip(names, args[:k]))
            m = dict(zip(names, args[k: 2 * k]))
            v = dict(zip(names, args[2 * k: 3 * k]))
            t, tokens, lr = args[3 * k:]
            p2, m2, v2, t2, loss = step(params, m, v, t, tokens, lr)
            outs = tuple(p2[n] for n in names) + tuple(m2[n] for n in names) \
                + tuple(v2[n] for n in names) + (t2, loss)
            return outs

        in_specs = (
            pspecs
            + [(f"m.{n}", s) for n, s in pspecs]
            + [(f"v.{n}", s) for n, s in pspecs]
            + [("t", _spec(())), ("tokens", tok), ("lr", _spec(()))]
        )
        out_names = (
            names + [f"m.{n}" for n in names] + [f"v.{n}" for n in names]
            + ["t_new", "loss"]
        )
        em.emit(f"train_{cname}", train, in_specs, out_names,
                meta={"model": cname, "kind": "train"})


def emit_kernel_smoke(em: Emitter):
    """Standalone kernel entry points for runtime integration tests."""
    x = _spec((256, 256))
    em.emit("k_whip", lambda x: (whip_loss(x),), [("X", x)], ["loss"],
            meta={"kind": "kernel", "kernel": "whip"})
    em.emit("k_rotate", lambda x, r: (rotate(x, r),),
            [("X", x), ("R", _spec((256, 256)))], ["O"],
            meta={"kind": "kernel", "kernel": "rotate"})
    em.emit("k_fwht", lambda x: (fwht(x),), [("X", _spec((128, 256)))], ["Y"],
            meta={"kind": "kernel", "kernel": "fwht"})
    em.emit("k_quant", lambda x, lv: (fake_quant(x, lv),),
            [("X", _spec((128, 256))), ("levels", _spec(()))], ["Y"],
            meta={"kind": "kernel", "kernel": "quantize"})
    # QR factor alone (integration test compares with rust householder_qr).
    em.emit("k_qr_q", lambda z: (model.householder_qr_q(z),),
            [("Z", _spec((64, 64)))], ["Q"],
            meta={"kind": "kernel", "kernel": "qr"})


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--flops", action="store_true",
                    help="compile each artifact to record an XLA FLOP estimate")
    ap.add_argument("--only", default=None,
                    help="comma-separated groups: calib,models,spin,train,kernels")
    args = ap.parse_args()

    groups = args.only.split(",") if args.only else [
        "calib", "models", "spin", "train", "kernels"]
    em = Emitter(args.out_dir, estimate_flops=args.flops)
    t0 = time.time()
    if "calib" in groups:
        emit_calibration(em)
    if "models" in groups:
        emit_models(em)
    if "spin" in groups:
        emit_spin(em)
    if "train" in groups:
        emit_train(em)
    if "kernels" in groups:
        emit_kernel_smoke(em)
    em.write_manifest()
    print(f"total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
