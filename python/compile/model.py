"""L2 — the JAX compute graphs lowered to AOT artifacts.

Contents:
  * differentiable Householder QR (pure-jnp scan — no LAPACK custom-calls,
    sign-canonicalized to match ``rust/src/linalg/qr.rs``),
  * the four calibration objectives (whip / variance / kurtosis / quant),
  * QR-Orth calibration steps (SGD-momentum and Adam) — Algorithm 1,
  * the Cayley-SGD baseline step — Algorithm 3 (SpinQuant's optimizer),
  * the tiny Llama-architecture forward (fp + fake-quant variants, with the
    online R3/R4 Hadamard sites of Appendix A), NLL outputs for PPL /
    zero-shot scoring, activation capture for the coordinator,
  * the SpinQuant-style end-to-end fine-tuning step (fuse R1 in-graph,
    pseudo-quantize, task loss, Cayley update) used by the overfitting and
    cost experiments,
  * an Adam training step for the end-to-end example's tiny-model training.

Everything here runs exactly once, inside ``aot.py``; the rust coordinator
executes the lowered HLO through PJRT.
"""

import functools
import math

import jax
import jax.numpy as jnp

from . import configs
from .kernels import ref
from .kernels.rotate import rotate
from .kernels.whip import whip_loss

# --------------------------------------------------------------------------
# Householder QR (pure jnp, differentiable, sign-canonical)
# --------------------------------------------------------------------------


def householder_qr_q(z):
    """Orthogonal factor Q of the QR decomposition of square ``z``.

    Implemented as a ``lax.scan`` of Householder reflections so that it
    (a) lowers to pure HLO (the 0.5.1 CPU runtime cannot run LAPACK
    custom-calls), (b) differentiates through scan's transpose rule, and
    (c) matches ``rust/src/linalg/qr.rs`` bit-for-convention: columns are
    sign-flipped so diag(R) >= 0.
    """
    n = z.shape[0]
    eye = jnp.eye(n, dtype=z.dtype)

    def body(carry, k):
        r, qt = carry
        idx = jnp.arange(n)
        mask = (idx >= k).astype(z.dtype)
        x = r[:, k] * mask
        alpha = jnp.sqrt(jnp.sum(x * x) + 1e-30)
        sign = jnp.where(x[k] >= 0, 1.0, -1.0).astype(z.dtype)
        v = x + sign * alpha * (idx == k).astype(z.dtype)
        vnorm2 = jnp.sum(v * v) + 1e-30
        coef = 2.0 / vnorm2
        r = r - coef * jnp.outer(v, v @ r)
        qt = qt - coef * jnp.outer(v, v @ qt)
        return (r, qt), None

    (r, qt), _ = jax.lax.scan(body, (z, eye), jnp.arange(n))
    d = jnp.sign(jnp.diagonal(r))
    d = jnp.where(d == 0, 1.0, d).astype(z.dtype)
    return qt.T * d[None, :]


# --------------------------------------------------------------------------
# Calibration objectives (rotated activations O = X @ R)
# --------------------------------------------------------------------------


def objective_whip(o):
    """Whip loss (Eq. 4) via the Pallas kernel."""
    return whip_loss(o)


def objective_variance(o):
    """Mean per-token variance across channels — the 'Variance' ablation.
    Norm invariance of R makes this nearly constant (Fig 7a)."""
    return jnp.mean(jnp.var(o, axis=-1))


def objective_kurtosis(o):
    """Mean per-token excess kurtosis — heavy-tail measure; slow to
    optimize because rotated activations are already near-Gaussian."""
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.mean((o - mu) ** 2, axis=-1, keepdims=True)
    m4 = jnp.mean((o - mu) ** 4, axis=-1)
    return jnp.mean(m4 / (var[..., 0] ** 2 + 1e-12) - 3.0)


def objective_quant(o, bits: int = 4):
    """Mean squared int4 fake-quant error. ``round`` has zero gradient, so
    signal only flows through the min/max scale terms — reproducing the
    paper's observation that direct quant-loss optimization barely moves."""
    return ref.quant_error_ref(o, float(2 ** bits))


OBJECTIVES = {
    "whip": objective_whip,
    "variance": objective_variance,
    "kurtosis": objective_kurtosis,
    "quant": objective_quant,
}


# --------------------------------------------------------------------------
# QR-Orth calibration steps (Algorithm 1)
# --------------------------------------------------------------------------


def make_calib_step_sgd(objective: str, momentum: float = 0.9):
    """One QR-Orth SGD-momentum step on the latent Z.

    (Z, M, X, lr) -> (Z', M', loss). R = qr(Z).Q is recomputed inside the
    step; the latent Z is unconstrained, which is the whole point — any
    Euclidean optimizer applies.
    """
    obj = OBJECTIVES[objective]

    def loss_fn(z, x):
        r = householder_qr_q(z)
        return obj(rotate(x, r))

    def step(z, m, x, lr):
        loss, g = jax.value_and_grad(loss_fn)(z, x)
        m_new = momentum * m + g
        z_new = z - lr * m_new
        return z_new, m_new, loss

    return step


def make_calib_step_adam(objective: str, b1=0.9, b2=0.999, eps=1e-8):
    """One QR-Orth Adam step: (Z, M, V, t, X, lr) -> (Z', M', V', t', loss)."""
    obj = OBJECTIVES[objective]

    def loss_fn(z, x):
        r = householder_qr_q(z)
        return obj(rotate(x, r))

    def step(z, m, v, t, x, lr):
        loss, g = jax.value_and_grad(loss_fn)(z, x)
        t_new = t + 1.0
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** t_new)
        vhat = v_new / (1 - b2 ** t_new)
        z_new = z - lr * mhat / (jnp.sqrt(vhat) + eps)
        return z_new, m_new, v_new, t_new, loss

    return step


# --------------------------------------------------------------------------
# Cayley SGD baseline (Algorithm 3) — SpinQuant's manifold optimizer
# --------------------------------------------------------------------------


def make_cayley_step(objective: str, momentum: float = 0.9, q: float = 0.5,
                     s: int = 2, eps: float = 1e-8):
    """One Cayley-SGD-with-momentum step directly on the rotation R.

    (R, M, X, lr) -> (R', M', loss). Implements the paper's Algorithm 3:
    skew-projection of the momentum followed by ``s`` fixed-point
    iterations of the Cayley retraction — the ~6n^3 extra work QR-Orth
    avoids (Appendix B.2).
    """
    obj = OBJECTIVES[objective]

    def loss_fn(r, x):
        return obj(rotate(x, r))

    def step(r, m, x, lr):
        loss, g = jax.value_and_grad(loss_fn)(r, x)
        m1 = momentum * m - g
        w_hat = m1 @ r.T - 0.5 * r @ (r.T @ m1 @ r.T)
        w = w_hat - w_hat.T
        m2 = w @ r
        wnorm = jnp.sqrt(jnp.sum(w * w))
        alpha = jnp.minimum(lr, 2.0 * q / (wnorm + eps))
        y = r + alpha * m2
        for _ in range(s):
            y = r + (alpha / 2.0) * (w @ (r + y))
        return y, m2, loss

    return step


def make_cayley_step_adam(objective: str, b1=0.9, b2=0.999, q: float = 0.5,
                          s: int = 2, eps: float = 1e-8):
    """Cayley-Adam variant: Adam preconditioning of the Euclidean gradient
    followed by the same skew-projection + retraction.
    (R, M, V, t, X, lr) -> (R', M', V', t', loss)."""
    obj = OBJECTIVES[objective]

    def loss_fn(r, x):
        return obj(rotate(x, r))

    def step(r, m, v, t, x, lr):
        loss, g = jax.value_and_grad(loss_fn)(r, x)
        t_new = t + 1.0
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** t_new)
        vhat = v_new / (1 - b2 ** t_new)
        gp = mhat / (jnp.sqrt(vhat) + eps)
        w_hat = -gp @ r.T - 0.5 * r @ (r.T @ (-gp) @ r.T)
        w = w_hat - w_hat.T
        wnorm = jnp.sqrt(jnp.sum(w * w))
        alpha = jnp.minimum(lr, 2.0 * q / (wnorm + eps))
        y = r + alpha * (w @ r)
        for _ in range(s):
            y = r + (alpha / 2.0) * (w @ (r + y))
        return y, m_new, v_new, t_new, loss

    return step


# --------------------------------------------------------------------------
# Hadamard transforms for the in-graph R3/R4 sites
# --------------------------------------------------------------------------


def _legendre(a: int, p: int) -> int:
    a %= p
    if a == 0:
        return 0
    return 1 if pow(a, (p - 1) // 2, p) == 1 else -1


@functools.lru_cache(maxsize=None)
def _paley_base(m: int):
    """Paley-I ±1 Hadamard matrix of order m in {12, 20} as a tuple-of-
    tuples (hashable for the lru_cache); mirrors rust `linalg::hadamard`."""
    q = m - 1
    rows = []
    for i in range(m):
        row = []
        for j in range(m):
            if i == 0 and j == 0:
                s = 0
            elif i == 0:
                s = 1
            elif j == 0:
                s = -1
            else:
                s = _legendre(i - j, q)
            row.append(float(s + (1 if i == j else 0)))
        rows.append(tuple(row))
    return tuple(rows)


def hadamard_transform(x):
    """x @ H_n along the last axis, H_n orthonormal, n = m * 2^k with
    m in {1, 12, 20}. Matches rust ``linalg::hadamard_matrix`` (Sylvester
    doubling prepends the 2^k factor: H_n = H_{2^k} (x) H_m)."""
    n = x.shape[-1]
    m = n
    while m % 2 == 0:
        m //= 2
    if m == 3:
        m = 12
    elif m == 5:
        m = 20
    elif m != 1:
        raise ValueError(f"no Hadamard construction for order {n}")
    p2 = n // m
    if m == 1:
        return ref.fwht_ref(x)
    base = jnp.asarray(_paley_base(m), dtype=x.dtype) / jnp.sqrt(float(m))
    shape = x.shape
    # index i = a*m + b (a over 2^k, b over m): FWHT over a, base over b.
    xr = x.reshape(*shape[:-1], p2, m)
    xr = jnp.swapaxes(xr, -1, -2)            # (..., m, p2)
    xr = ref.fwht_ref(xr)                    # FWHT over the 2^k axis
    xr = jnp.swapaxes(xr, -1, -2)            # (..., p2, m)
    xr = xr @ base                           # dense base multiply
    return xr.reshape(shape)


# --------------------------------------------------------------------------
# Tiny Llama-architecture forward
# --------------------------------------------------------------------------


def _top_k(x, k):
    """Iterative top-k over the last axis. `lax.top_k` lowers to an HLO
    `topk(..., largest=true)` attribute the xla_extension 0.5.1 text
    parser rejects; this unrolled argmax version lowers to plain HLO
    (k is tiny — the MoE top-2)."""
    vals, idxs = [], []
    cur = x
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)
        v = jnp.take_along_axis(cur, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i)
        cur = cur - jax.nn.one_hot(i, x.shape[-1], dtype=x.dtype) * jnp.float32(1e30)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def rmsnorm(x, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, theta):
    """Rotary embedding over (B, H, T, hd)."""
    b, h, t, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=x.dtype) / half)
    ang = jnp.arange(t, dtype=x.dtype)[:, None] * freqs[None, :]
    cos = jnp.cos(ang)[None, None]
    sin = jnp.sin(ang)[None, None]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _fq_act(x, levels):
    """Per-token asymmetric fake quant over the last axis; `levels` is a
    traced scalar — levels >= 2^15 means 'off' (the fp16 settings)."""
    return jnp.where(levels >= 32767.0, x, ref.fake_quant_ref(x, levels))


def _fq_weight(w, bits: int):
    """Per-output-channel symmetric fake quant (host-side quantization is
    the rust default; this in-graph version feeds the SpinQuant-sim e2e
    step where W depends on the trainable R1)."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-10)
    return jnp.clip(jnp.round(w / scale), -qmax - 1, qmax) * scale


def forward_nll(cfg: configs.ModelConfig, params: dict, tokens,
                a_levels=None, kv_levels=None, use_had=None):
    """Causal-LM forward returning per-position NLL (B, T-1).

    ``a_levels``/``kv_levels`` are traced scalars (quant level counts) or
    None for the pure fp path; ``use_had`` (traced 0/1 scalar or None)
    gates the online R3/R4 Hadamard sites — when 1, the caller must pass
    ``wd`` pre-fused with H_f (rust `rotation::fuse_r4`).
    """
    eps = cfg.norm_eps
    b, t = tokens.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    x = params["embed"][tokens]  # (B, T, d)

    fq = (lambda v: _fq_act(v, a_levels)) if a_levels is not None else (lambda v: v)
    fqkv = (lambda v: _fq_act(v, kv_levels)) if kv_levels is not None else (lambda v: v)

    def maybe_had(v):
        if use_had is None:
            return v
        return jnp.where(use_had > 0.5, hadamard_transform(v), v)

    mask = jnp.tril(jnp.ones((t, t), dtype=bool))

    for l in range(cfg.n_layers):
        p = lambda leaf: params[f"l{l}.{leaf}"]
        h = rmsnorm(x, eps)
        hq = fq(h)
        q = (hq @ p("wq").T).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        k = (hq @ p("wk").T).reshape(b, t, nkv, hd).transpose(0, 2, 1, 3)
        v = (hq @ p("wv").T).reshape(b, t, nkv, hd).transpose(0, 2, 1, 3)
        q = rope(q, cfg.rope_theta)
        k = rope(k, cfg.rope_theta)
        # R3: per-head online Hadamard — cancels inside q·kᵀ, but K enters
        # the (quantized) KV cache in the rotated basis.
        q = maybe_had(q)
        k = maybe_had(k)
        k = fqkv(k)
        v = fqkv(v)
        if nkv != nh:  # GQA: repeat kv heads across query groups
            rep = nh // nkv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        scores = q @ jnp.swapaxes(k, -1, -2) / math.sqrt(hd)
        scores = jnp.where(mask[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, nh * hd)
        out = fq(out)
        x = x + out @ p("wo").T

        h2 = rmsnorm(x, eps)
        h2q = fq(h2)
        if cfg.is_moe:
            gate_logits = h2q @ p("router").T  # (B, T, E)
            topv, topi = _top_k(gate_logits, cfg.top_k)
            gates = jax.nn.softmax(topv, axis=-1)
            ffn_out = jnp.zeros_like(x)
            for e in range(cfg.n_experts):
                pe = lambda leaf: params[f"l{l}.e{e}.{leaf}"]
                a = jax.nn.silu(h2q @ pe("wg").T) * (h2q @ pe("wu").T)
                a = maybe_had(a)
                a = fq(a)
                y = a @ pe("wd").T
                # weight of expert e = sum of gate probs where topi == e
                w_e = jnp.sum(jnp.where(topi == e, gates, 0.0), axis=-1)
                ffn_out = ffn_out + w_e[..., None] * y
            x = x + ffn_out
        else:
            a = jax.nn.silu(h2q @ p("wg").T) * (h2q @ p("wu").T)
            a = maybe_had(a)  # R4 (inverse fused into wd by the caller)
            a = fq(a)
            x = x + a @ p("wd").T

    h = rmsnorm(x, eps)
    logits = h @ params["head"].T  # (B, T, V)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
    return nll  # (B, T-1)


def capture_sites(cfg: configs.ModelConfig, params: dict, tokens):
    """Forward pass that records the calibration sites:

    returns (x_sites, v_sites) with
      x_sites (2L, B*T, d)  — post-RMSNorm hidden states feeding the
                               attention and FFN linears (the R1 site),
      v_sites (L, B*T, kv)  — value-projection outputs (the R2 site).
    """
    eps = cfg.norm_eps
    b, t = tokens.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    x = params["embed"][tokens]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    xs, vs = [], []

    for l in range(cfg.n_layers):
        p = lambda leaf: params[f"l{l}.{leaf}"]
        h = rmsnorm(x, eps)
        xs.append(h.reshape(b * t, -1))
        q = (h @ p("wq").T).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        k = (h @ p("wk").T).reshape(b, t, nkv, hd).transpose(0, 2, 1, 3)
        v = (h @ p("wv").T).reshape(b, t, nkv, hd).transpose(0, 2, 1, 3)
        vs.append(v.transpose(0, 2, 1, 3).reshape(b * t, nkv * hd))
        q = rope(q, cfg.rope_theta)
        k = rope(k, cfg.rope_theta)
        if nkv != nh:
            rep = nh // nkv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        scores = q @ jnp.swapaxes(k, -1, -2) / math.sqrt(hd)
        scores = jnp.where(mask[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, nh * hd)
        x = x + out @ p("wo").T
        h2 = rmsnorm(x, eps)
        xs.append(h2.reshape(b * t, -1))
        if cfg.is_moe:
            gate_logits = h2 @ p("router").T
            topv, topi = _top_k(gate_logits, cfg.top_k)
            gates = jax.nn.softmax(topv, axis=-1)
            ffn_out = jnp.zeros_like(x)
            for e in range(cfg.n_experts):
                pe = lambda leaf: params[f"l{l}.e{e}.{leaf}"]
                a = jax.nn.silu(h2 @ pe("wg").T) * (h2 @ pe("wu").T)
                y = a @ pe("wd").T
                w_e = jnp.sum(jnp.where(topi == e, gates, 0.0), axis=-1)
                ffn_out = ffn_out + w_e[..., None] * y
            x = x + ffn_out
        else:
            a = jax.nn.silu(h2 @ p("wg").T) * (h2 @ p("wu").T)
            x = x + a @ p("wd").T

    return jnp.stack(xs), jnp.stack(vs)


# --------------------------------------------------------------------------
# SpinQuant-style end-to-end step (the expensive baseline)
# --------------------------------------------------------------------------


def fuse_r1(cfg: configs.ModelConfig, params: dict, r1):
    """Fuse a global rotation R1 into the weights (Appendix A):
    input-side weights get W @ R1, output-side get R1ᵀ @ W, embeddings and
    head rotate rows. Exact — fp outputs are unchanged."""
    out = {}
    for name, w in params.items():
        leaf = name.split(".")[-1]
        if leaf in ("embed", "head"):
            out[name] = w @ r1
        elif leaf in ("wq", "wk", "wv", "wg", "wu", "router"):
            out[name] = w @ r1
        elif leaf in ("wo", "wd"):
            out[name] = r1.T @ w
        else:
            out[name] = w
    return out


def make_spin_step(cfg: configs.ModelConfig, wbits: int = 4,
                   a_bits: int = 4, momentum: float = 0.9,
                   q: float = 0.5, s: int = 2, eps: float = 1e-8):
    """SpinQuant-sim: one end-to-end Cayley step on R1.

    (R1, M, *weights, tokens, lr) -> (R1', M', loss). In-graph: fuse R1,
    pseudo-quantize weights and activations, task cross-entropy loss,
    Cayley retraction. Deliberately holds the whole computation graph —
    this is the memory/time cost Table 3 contrasts with DartQuant.
    """
    a_levels = float(2 ** a_bits)

    def loss_fn(r1, params, tokens):
        fused = fuse_r1(cfg, params, r1)
        fused = {
            k: (_fq_weight(w, wbits) if k not in ("embed", "head") else w)
            for k, w in fused.items()
        }
        nll = forward_nll(cfg, fused, tokens, a_levels=jnp.asarray(a_levels))
        return jnp.mean(nll)

    def step(r1, m, params, tokens, lr):
        loss, g = jax.value_and_grad(loss_fn)(r1, params, tokens)
        m1 = momentum * m - g
        w_hat = m1 @ r1.T - 0.5 * r1 @ (r1.T @ m1 @ r1.T)
        w = w_hat - w_hat.T
        m2 = w @ r1
        wnorm = jnp.sqrt(jnp.sum(w * w))
        alpha = jnp.minimum(lr, 2.0 * q / (wnorm + eps))
        y = r1 + alpha * m2
        for _ in range(s):
            y = r1 + (alpha / 2.0) * (w @ (r1 + y))
        return y, m2, loss

    return step


# --------------------------------------------------------------------------
# Training step (Adam) for the end-to-end example
# --------------------------------------------------------------------------


def make_train_step(cfg: configs.ModelConfig, b1=0.9, b2=0.98, eps=1e-8):
    """(params, m, v, t, tokens, lr) -> (params', m', v', t', loss) where
    params/m/v are dicts over configs.param_names(cfg)."""

    def loss_fn(params, tokens):
        return jnp.mean(forward_nll(cfg, params, tokens))

    def step(params, m, v, t, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        t_new = t + 1.0
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            new_m[k] = b1 * m[k] + (1 - b1) * g
            new_v[k] = b2 * v[k] + (1 - b2) * g * g
            mhat = new_m[k] / (1 - b1 ** t_new)
            vhat = new_v[k] / (1 - b2 ** t_new)
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v, t_new, loss

    return step
