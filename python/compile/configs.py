"""Model configurations — the tiny Llama-architecture stand-ins.

Single source of truth for the build path; `aot.py` embeds these into
``artifacts/manifest.json`` so the rust side (``rust/src/model/config.rs``)
can cross-check its mirrored constants in an integration test.

Dims are chosen so every rotation site has a constructible Hadamard
(n = m * 2^k, m in {1, 12, 20}), mirroring how QuaRot handles real Llama
dims with had12/had20 Kronecker blocks:

* llama2-tiny  (7B stand-in):  d=256,          ffn=512  (2^k)
* llama2-small (13B stand-in): d=320 = 20*16,  ffn=768  = 12*64
* llama2-large (70B stand-in): d=512,          ffn=1280 = 20*64
* llama3-small (8B stand-in):  d=384 = 12*32,  ffn=1024, GQA 6q/2kv
* llama3-large (70B stand-in): d=640 = 20*32,  ffn=1536 = 12*128, GQA 10q/2kv
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    dim: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    ffn_dim: int
    vocab: int
    head_dim: int = 64
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # MoE (0 experts == dense)
    n_experts: int = 0
    top_k: int = 0

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def to_dict(self):
        d = asdict(self)
        d["kv_dim"] = self.kv_dim
        return d


CONFIGS = {
    c.name: c
    for c in [
        ModelConfig("llama2-tiny", dim=256, n_layers=4, n_heads=4, n_kv_heads=4,
                    ffn_dim=512, vocab=512),
        ModelConfig("llama2-small", dim=320, n_layers=5, n_heads=5, n_kv_heads=5,
                    ffn_dim=768, vocab=512),
        ModelConfig("llama2-large", dim=512, n_layers=8, n_heads=8, n_kv_heads=8,
                    ffn_dim=1280, vocab=512),
        ModelConfig("llama3-small", dim=384, n_layers=4, n_heads=6, n_kv_heads=2,
                    ffn_dim=1024, vocab=1024),
        ModelConfig("llama3-large", dim=640, n_layers=8, n_heads=10, n_kv_heads=2,
                    ffn_dim=1536, vocab=1024),
        # MoE stand-ins (Appendix H): dense attention + top-2 routed experts.
        ModelConfig("mixtral-tiny", dim=256, n_layers=4, n_heads=4, n_kv_heads=4,
                    ffn_dim=512, vocab=512, n_experts=4, top_k=2),
    ]
}

# Sequence geometry shared by all fwd/train artifacts.
BATCH = 8
SEQ = 256

# Calibration activation batch: sampled token rows per optimizer step.
CALIB_TOKENS = 1024

# Hidden sizes for which standalone calibration artifacts are emitted:
# every model dim plus the shared head_dim (R2 calibration site).
CALIB_DIMS = sorted({64} | {c.dim for c in CONFIGS.values()})


def param_names(cfg: ModelConfig) -> list[str]:
    """Flat, ordered parameter list — the weight-passing convention shared
    with rust. Order matters: rust builds its input Vec in this order."""
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo"]
        if cfg.is_moe:
            names += [f"l{l}.router"]
            for e in range(cfg.n_experts):
                names += [f"l{l}.e{e}.wg", f"l{l}.e{e}.wu", f"l{l}.e{e}.wd"]
        else:
            names += [f"l{l}.wg", f"l{l}.wu", f"l{l}.wd"]
    names += ["head"]
    return names


def param_shape(cfg: ModelConfig, name: str) -> tuple[int, ...]:
    """Shape of each named parameter (all linear weights stored [out, in],
    applied as x @ W.T — torch nn.Linear convention, matching the paper's
    Y = X W^T notation)."""
    d, f, v, kd = cfg.dim, cfg.ffn_dim, cfg.vocab, cfg.kv_dim
    if name == "embed":
        return (v, d)
    if name == "head":
        return (v, d)
    leaf = name.split(".")[-1]
    return {
        "wq": (cfg.n_heads * cfg.head_dim, d),
        "wk": (kd, d),
        "wv": (kd, d),
        "wo": (d, cfg.n_heads * cfg.head_dim),
        "wg": (f, d),
        "wu": (f, d),
        "wd": (d, f),
        "router": (cfg.n_experts, d),
    }[leaf]
