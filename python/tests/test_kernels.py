"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/dtypes/values with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, fwht, ref, whip_loss
from compile.kernels.rotate import matmul, rotate

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------- whip ----


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 2, 64, 128, 256, 512]),
    n=st.sampled_from([8, 64, 256, 320]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 30.0),
)
def test_whip_matches_ref(t, n, seed, scale):
    x = rand(seed, (t, n), scale)
    np.testing.assert_allclose(whip_loss(x), ref.whip_ref(x), rtol=2e-4, atol=1e-5)


def test_whip_grad_matches_autodiff_of_ref():
    x = rand(0, (128, 64))
    g = jax.grad(lambda x: whip_loss(x))(x)
    gref = jax.grad(lambda x: ref.whip_ref(x))(x)
    np.testing.assert_allclose(g, gref, rtol=1e-4, atol=1e-6)


def test_whip_of_zeros_is_dim():
    # exp(0) = 1 summed over channels.
    x = jnp.zeros((64, 32))
    assert float(whip_loss(x)) == pytest.approx(32.0, rel=1e-5)


def test_whip_decreases_with_magnitude():
    small = jnp.full((64, 32), 0.1)
    large = jnp.full((64, 32), 5.0)
    assert float(whip_loss(large)) < float(whip_loss(small))


# -------------------------------------------------------------- rotate ----


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([64, 256, 320, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rotate_matches_ref(t, n, seed):
    x = rand(seed, (t, n))
    r = jnp.linalg.qr(rand(seed + 1, (n, n), 1.0))[0]
    np.testing.assert_allclose(
        rotate(x, r), ref.rotate_ref(x, r), rtol=1e-3, atol=1e-3)


def test_rotate_vjp_matches_ref_vjp():
    x = rand(0, (128, 64))
    r = jnp.linalg.qr(rand(1, (64, 64), 1.0))[0]

    def f(x, r):
        return jnp.sum(jnp.sin(rotate(x, r)))

    def fr(x, r):
        return jnp.sum(jnp.sin(ref.rotate_ref(x, r)))

    gx, gr = jax.grad(f, argnums=(0, 1))(x, r)
    gxr, grr = jax.grad(fr, argnums=(0, 1))(x, r)
    np.testing.assert_allclose(gx, gxr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gr, grr, rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([32, 100, 256]),
    k=st.sampled_from([64, 320]),
    n=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_general_matmul_odd_shapes(m, k, n, seed):
    a = rand(seed, (m, k), 1.0)
    b = rand(seed + 7, (k, n), 1.0)
    np.testing.assert_allclose(matmul(a, b), a @ b, rtol=1e-3, atol=1e-3)


def test_rotate_preserves_norms():
    x = rand(5, (128, 256))
    r = jnp.linalg.qr(rand(6, (256, 256), 1.0))[0]
    o = rotate(x, r)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=1), jnp.linalg.norm(o, axis=1), rtol=1e-3)


# ---------------------------------------------------------- fake_quant ----


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 64, 128, 256]),
    n=st.sampled_from([16, 64, 320]),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(t, n, bits, seed):
    x = rand(seed, (t, n), 10.0)
    lv = float(2**bits)
    np.testing.assert_allclose(
        fake_quant(x, lv), ref.fake_quant_ref(x, lv), rtol=1e-5, atol=1e-5)


def test_fake_quant_level_count():
    x = rand(3, (64, 256), 10.0)
    y = np.asarray(fake_quant(x, 16.0))
    for row in y:
        assert len(np.unique(np.round(row, 5))) <= 16


def test_fake_quant_constant_row_passthrough():
    x = jnp.full((64, 32), 3.25)
    np.testing.assert_allclose(fake_quant(x, 16.0), x)


def test_fake_quant_error_bound():
    x = rand(4, (128, 64), 5.0)
    y = fake_quant(x, 16.0)
    step = (jnp.max(x, 1) - jnp.min(x, 1)) / 15.0
    assert jnp.all(jnp.abs(y - x) <= step[:, None] / 2 + 1e-5)


def test_more_levels_less_error():
    x = rand(9, (128, 64), 5.0)
    e4 = float(ref.quant_error_ref(x, 16.0))
    e8 = float(ref.quant_error_ref(x, 256.0))
    assert e8 < e4


# ---------------------------------------------------------------- fwht ----


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 64, 128]),
    logn=st.integers(0, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_ref(t, logn, seed):
    n = 2**logn
    x = rand(seed, (t, n))
    np.testing.assert_allclose(fwht(x), ref.fwht_ref(x), rtol=1e-4, atol=1e-4)


def test_fwht_is_involution_and_isometry():
    x = rand(11, (128, 256))
    y = fwht(x)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=1), jnp.linalg.norm(y, axis=1), rtol=1e-4)
    np.testing.assert_allclose(fwht(y), x, rtol=1e-3, atol=1e-4)


def test_fwht_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        fwht(jnp.zeros((8, 12)))


def test_fwht_smooths_outliers():
    # A single huge spike spreads to magnitude spike/sqrt(n) everywhere —
    # the outlier-smoothing property rotations exploit.
    x = jnp.zeros((1, 256)).at[0, 3].set(100.0)
    y = np.asarray(fwht(x))
    assert np.abs(y).max() == pytest.approx(100.0 / 16.0, rel=1e-4)
