"""L2 graph correctness: QR, calibration steps, Cayley, rotation fusion
invariance, hadamard transforms, forward/NLL sanity, train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


# ------------------------------------------------------------------ QR ----


@pytest.mark.parametrize("n", [2, 8, 32, 64])
def test_householder_qr_orthogonal_and_matches_lapack(n):
    z = jax.random.normal(key(n), (n, n), jnp.float32)
    q = model.householder_qr_q(z)
    np.testing.assert_allclose(q @ q.T, jnp.eye(n), atol=5e-5)
    qref, rref = jnp.linalg.qr(z)
    d = jnp.sign(jnp.diagonal(rref))
    np.testing.assert_allclose(q, qref * d[None, :], atol=5e-4)


def test_qr_grad_is_finite_and_nonzero():
    z = jax.random.normal(key(1), (16, 16), jnp.float32)
    x = jax.random.normal(key(2), (64, 16), jnp.float32)

    def loss(z):
        return jnp.sum(jnp.exp(-jnp.abs(x @ model.householder_qr_q(z))))

    g = jax.grad(loss)(z)
    assert jnp.all(jnp.isfinite(g))
    assert float(jnp.linalg.norm(g)) > 1e-4


def test_qr_grad_matches_finite_difference():
    n = 8
    z = jax.random.normal(key(3), (n, n), jnp.float32)
    x = jax.random.normal(key(4), (32, n), jnp.float32)

    def loss(z):
        return jnp.mean((x @ model.householder_qr_q(z)) ** 4)

    g = jax.grad(loss)(z)
    eps = 1e-3
    for idx in [(0, 0), (3, 5), (7, 2)]:
        dz = jnp.zeros_like(z).at[idx].set(eps)
        fd = (loss(z + dz) - loss(z - dz)) / (2 * eps)
        assert float(jnp.abs(g[idx] - fd)) < 2e-2, f"{idx}: {g[idx]} vs {fd}"


# ---------------------------------------------------- calibration steps ----


def heavy_tailed_acts(k, t, n):
    """Laplace body + planted outlier channels (the paper's regime)."""
    x = jax.random.laplace(key(k), (t, n), jnp.float32)
    cols = jax.random.choice(key(k + 1), n, (max(1, n // 32),), replace=False)
    return x.at[:, cols].multiply(25.0)


@pytest.mark.parametrize("objective", ["whip", "variance", "kurtosis", "quant"])
def test_calib_step_runs_and_outputs_finite(objective):
    n, t = 64, 256
    step = jax.jit(model.make_calib_step_sgd(objective))
    z = jnp.eye(n) + 0.01 * jax.random.normal(key(5), (n, n))
    m = jnp.zeros((n, n))
    x = heavy_tailed_acts(6, t, n)
    z2, m2, loss = step(z, m, x, 1e-2)
    assert jnp.all(jnp.isfinite(z2)) and jnp.all(jnp.isfinite(loss))


def test_whip_calibration_reduces_loss_and_outliers():
    n, t = 64, 512
    step = jax.jit(model.make_calib_step_sgd("whip"))
    x = heavy_tailed_acts(7, t, n)
    z = jnp.eye(n)
    m = jnp.zeros((n, n))
    losses = []
    for _ in range(30):
        z, m, loss = step(z, m, x, 5e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, f"whip did not descend: {losses[:3]}...{losses[-3:]}"
    # Outliers after calibrated rotation < before.
    r = model.householder_qr_q(z)
    o = x @ r
    tau = 4.0 * jnp.std(x)
    assert int(jnp.sum(jnp.abs(o) > tau)) < int(jnp.sum(jnp.abs(x) > tau))


def test_adam_step_descends():
    n, t = 64, 256
    step = jax.jit(model.make_calib_step_adam("whip"))
    x = heavy_tailed_acts(8, t, n)
    z, m, v, t_ = jnp.eye(n), jnp.zeros((n, n)), jnp.zeros((n, n)), jnp.zeros(())
    first = None
    for _ in range(15):
        z, m, v, t_, loss = step(z, m, v, t_, x, 1e-2)
        first = first if first is not None else float(loss)
    assert float(loss) < first


def test_cayley_step_stays_on_manifold_and_descends():
    n, t = 64, 256
    step = jax.jit(model.make_cayley_step("whip"))
    x = heavy_tailed_acts(9, t, n)
    r = jnp.eye(n)
    m = jnp.zeros((n, n))
    first = None
    for _ in range(25):
        r, m, loss = step(r, m, x, 5e-3)
        first = first if first is not None else float(loss)
    np.testing.assert_allclose(r @ r.T, jnp.eye(n), atol=1e-2)
    assert float(loss) < first


def test_qr_orth_converges_faster_than_cayley():
    """Fig 7b's shape: at equal step counts, QR-SGD reaches a lower whip
    loss than Cayley-SGD from the same init."""
    n, t, steps = 64, 512, 40
    x = heavy_tailed_acts(10, t, n)
    qr_step = jax.jit(model.make_calib_step_sgd("whip"))
    cay_step = jax.jit(model.make_cayley_step("whip"))
    z, mz = jnp.eye(n), jnp.zeros((n, n))
    r, mr = jnp.eye(n), jnp.zeros((n, n))
    for _ in range(steps):
        z, mz, ql = qr_step(z, mz, x, 5e-3)
        r, mr, cl = cay_step(r, mr, x, 5e-3)
    assert float(ql) <= float(cl) * 1.05, f"qr {ql} vs cayley {cl}"


# ------------------------------------------------------------- hadamard ----


@pytest.mark.parametrize("n", [64, 256, 768, 320, 1280, 1536])
def test_hadamard_transform_is_orthogonal(n):
    x = jax.random.normal(key(n), (8, n), jnp.float32)
    y = model.hadamard_transform(x)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=1), jnp.linalg.norm(y, axis=1), rtol=1e-4)
    # Matches dense multiply by the explicit matrix (built the same way
    # rust builds it): apply to identity to extract H, check H Hᵀ = I.
    h = model.hadamard_transform(jnp.eye(n))
    np.testing.assert_allclose(h @ h.T, jnp.eye(n), atol=1e-4)


def test_hadamard_unsupported_order_raises():
    with pytest.raises(ValueError):
        model.hadamard_transform(jnp.zeros((2, 36)))


# ------------------------------------------------------- forward / fuse ----


def tiny_params(cfg, seed=0, scale=0.5):
    params = {}
    k = key(seed)
    for name in configs.param_names(cfg):
        k, sub = jax.random.split(k)
        shape = configs.param_shape(cfg, name)
        params[name] = jax.random.normal(sub, shape, jnp.float32) * scale / np.sqrt(shape[-1])
    return params


@pytest.mark.parametrize("cname", ["llama2-tiny", "llama3-small", "mixtral-tiny"])
def test_forward_nll_shape_and_finite(cname):
    cfg = CONFIGS[cname]
    params = tiny_params(cfg)
    toks = jax.random.randint(key(1), (2, 32), 0, cfg.vocab)
    nll = model.forward_nll(cfg, params, toks)
    assert nll.shape == (2, 31)
    assert jnp.all(jnp.isfinite(nll))
    # Untrained model ≈ uniform: NLL near log(V).
    assert abs(float(jnp.mean(nll)) - np.log(cfg.vocab)) < 1.5


def test_fuse_r1_preserves_fp_outputs():
    """Computational invariance (Appendix A): fusing any orthogonal R1
    leaves the fp forward exactly unchanged."""
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 3)
    toks = jax.random.randint(key(2), (2, 16), 0, cfg.vocab)
    base = model.forward_nll(cfg, params, toks)
    r1 = model.householder_qr_q(jax.random.normal(key(4), (cfg.dim, cfg.dim)))
    fused = model.fuse_r1(cfg, params, r1)
    rot = model.forward_nll(cfg, fused, toks)
    np.testing.assert_allclose(base, rot, rtol=2e-2, atol=2e-3)


def test_quantized_forward_degrades_gracefully():
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 5)
    toks = jax.random.randint(key(6), (2, 32), 0, cfg.vocab)
    fp = float(jnp.mean(model.forward_nll(cfg, params, toks)))
    q8 = float(jnp.mean(model.forward_nll(
        cfg, params, toks, a_levels=jnp.float32(256.0),
        kv_levels=jnp.float32(65536.0))))
    q4 = float(jnp.mean(model.forward_nll(
        cfg, params, toks, a_levels=jnp.float32(16.0),
        kv_levels=jnp.float32(65536.0))))
    assert abs(q8 - fp) < 0.3, f"8-bit acts should be near-lossless: {fp} vs {q8}"
    assert q4 >= q8 - 0.05, "4-bit should not beat 8-bit"


def test_use_had_flag_with_fused_wd_is_consistent():
    """R4 convention: graph applies H to the FFN activation, caller fuses
    H into wd. fp output must be preserved (no act quant)."""
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 7)
    toks = jax.random.randint(key(8), (2, 16), 0, cfg.vocab)
    base = model.forward_nll(cfg, params, toks)
    h_f = model.hadamard_transform(jnp.eye(cfg.ffn_dim))
    h_hd = model.hadamard_transform(jnp.eye(cfg.head_dim))
    fused = dict(params)
    for l in range(cfg.n_layers):
        fused[f"l{l}.wd"] = params[f"l{l}.wd"] @ h_f
    huge = jnp.float32(1e9)
    rot = model.forward_nll(cfg, fused, toks, a_levels=huge, kv_levels=huge,
                            use_had=jnp.float32(1.0))
    assert h_hd.shape == (cfg.head_dim, cfg.head_dim)
    np.testing.assert_allclose(base, rot, rtol=2e-2, atol=2e-3)


def test_capture_sites_shapes():
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 9)
    toks = jax.random.randint(key(10), (2, 16), 0, cfg.vocab)
    xs, vs = model.capture_sites(cfg, params, toks)
    assert xs.shape == (2 * cfg.n_layers, 2 * 16, cfg.dim)
    assert vs.shape == (cfg.n_layers, 2 * 16, cfg.kv_dim)
    assert jnp.all(jnp.isfinite(xs)) and jnp.all(jnp.isfinite(vs))


def test_spin_step_descends_and_stays_orthogonal():
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 11)
    toks = jax.random.randint(key(12), (2, 32), 0, cfg.vocab)
    step = jax.jit(model.make_spin_step(cfg))
    r1 = model.householder_qr_q(jax.random.normal(key(13), (cfg.dim, cfg.dim)))
    m = jnp.zeros_like(r1)
    first = None
    for _ in range(5):
        r1, m, loss = step(r1, m, params, toks, 0.5)
        first = first if first is not None else float(loss)
    np.testing.assert_allclose(r1 @ r1.T, jnp.eye(cfg.dim), atol=5e-2)
    assert jnp.isfinite(loss)


def test_train_step_reduces_loss():
    cfg = CONFIGS["llama2-tiny"]
    params = tiny_params(cfg, 14)
    names = configs.param_names(cfg)
    m = {n: jnp.zeros_like(params[n]) for n in names}
    v = {n: jnp.zeros_like(params[n]) for n in names}
    step = jax.jit(model.make_train_step(cfg))
    toks = jax.random.randint(key(15), (4, 32), 0, cfg.vocab)
    t = jnp.zeros(())
    losses = []
    for _ in range(10):
        params, m, v, t, loss = step(params, m, v, t, toks, 3e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"train loss did not drop: {losses}"
