#!/usr/bin/env bash
# Tier-1 verify in one command (also `make check`):
#   release build, quiet tests, formatting.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo fmt --check
