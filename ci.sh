#!/usr/bin/env bash
# Tier-1 verify in one command (also `make check`):
#   release build, bench compile (perf_gemm/perf_decode & friends build
#   but do not run; `make bench-json` runs the pinned perf set), example
#   compile (quickstart & friends), quiet tests (includes the GEMM
#   parity suite rust/tests/gemm.rs, the decode-parity suite
#   rust/tests/serving.rs, the speculative-decode equality gate
#   rust/tests/spec.rs and the out-of-core suite
#   rust/tests/streaming.rs), the dqlint
#   static-analysis pass (docs/LINTS.md; lint_report.json is the
#   machine-readable archive), clippy (warnings as errors), rustdoc
#   (warnings as errors), docs link check, formatting.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo build --release --benches
cargo build --release --examples
cargo test -q
# dqlint exits nonzero on any error-severity diagnostic, failing the run.
cargo run --release --quiet --bin dqlint -- --json > lint_report.json
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
./scripts/check_links.sh
cargo fmt --check
# Receipt drift (scripts/bench_diff.sh) stays warning-only while the
# committed BENCH_*.json receipts remain analytic estimates — the script
# itself exits 0 in its default WARN_ONLY mode, and the `|| echo` keeps
# even an unexpected failure from gating tier-1.
./scripts/bench_diff.sh || echo "ci: bench-diff reported drift (warning-only)"
