#!/usr/bin/env bash
# Tier-1 verify in one command (also `make check`):
#   release build, quiet tests, clippy (warnings as errors), rustdoc
#   (warnings as errors), formatting.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo fmt --check
