#!/usr/bin/env bash
# Tier-1 verify in one command (also `make check`):
#   release build, bench compile (perf_decode & friends build but do not
#   run), quiet tests (includes the decode-parity suite
#   rust/tests/serving.rs), clippy (warnings as errors), rustdoc
#   (warnings as errors), formatting.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo build --release --benches
cargo test -q
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo fmt --check
