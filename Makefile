# DartQuant reproduction — build/verify entry points.
#
#   make artifacts   AOT-lower the JAX/Pallas graphs to artifacts/ (the one
#                    python step; everything after runs from rust)
#   make check       tier-1 verify: release build + bench/example compile
#                    + tests (incl. rust/tests/serving.rs decode parity
#                    and rust/tests/streaming.rs out-of-core) + dqlint
#                    + clippy + doc + docs link check + fmt check
#   make lint        dqlint static-analysis pass over rust/src + rust/benches
#                    (docs/LINTS.md; exit code gates CI)
#   make clippy      cargo clippy over every target (warnings are errors)
#   make doc         rustdoc the public API (warnings are errors)
#   make check-links docs link checker (scripts/check_links.sh)
#   make bench       run the paper-table bench binaries (needs artifacts)
#   make bench-decode     run the serving-path bench (native; no artifacts)
#   make bench-gemm       run the tiled-GEMM bench (native; no artifacts)
#   make bench-serve      run the paged-KV vs contiguous serving bench
#                         (native; sessions/GB, prefix hit rate, p99 step)
#   make bench-spec       run the self-speculative decoding bench (native;
#                         accept rate, tokens/round, decode speedup)
#   make bench-streaming  run the out-of-core vs in-memory bench (native)
#   make bench-json       pinned perf run emitting BENCH_*.json receipts
#                         (scripts/bench_json.sh; gemm/decode/serve/streaming
#                         always, hotpath + scheduler when artifacts/ exists)
#   make bench-diff       regenerate receipts into a temp dir and diff vs the
#                         committed BENCH_*.json (scripts/bench_diff.sh;
#                         warning-only while committed receipts are analytic)

.PHONY: artifacts check test lint fmt clippy doc check-links bench bench-decode bench-gemm bench-serve bench-spec bench-streaming bench-json bench-diff

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

check:
	./ci.sh

test:
	cargo test -q

lint:
	cargo run --release --bin dqlint

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

check-links:
	./scripts/check_links.sh

bench:
	cargo bench

bench-decode:
	cargo bench --bench perf_decode

bench-gemm:
	cargo bench --bench perf_gemm

bench-serve:
	cargo bench --bench perf_serve

bench-spec:
	cargo bench --bench perf_spec

bench-streaming:
	cargo bench --bench perf_streaming

bench-json:
	./scripts/bench_json.sh

bench-diff:
	./scripts/bench_diff.sh
