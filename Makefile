# DartQuant reproduction — build/verify entry points.
#
#   make artifacts   AOT-lower the JAX/Pallas graphs to artifacts/ (the one
#                    python step; everything after runs from rust)
#   make check       tier-1 verify: release build + bench compile + tests
#                    (incl. the rust/tests/serving.rs decode-parity suite)
#                    + clippy + doc + fmt check
#   make clippy      cargo clippy over every target (warnings are errors)
#   make doc         rustdoc the public API (warnings are errors)
#   make bench       run the paper-table bench binaries (needs artifacts)
#   make bench-decode  run the serving-path bench (native; no artifacts)

.PHONY: artifacts check test fmt clippy doc bench bench-decode

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

check:
	./ci.sh

test:
	cargo test -q

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench

bench-decode:
	cargo bench --bench perf_decode
