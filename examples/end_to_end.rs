//! End-to-end driver — exercises every layer of the system on a real small
//! workload (recorded in EXPERIMENTS.md):
//!
//!   1. TRAIN   the tiny Llama-style model for a few hundred steps on the
//!              synthetic Wiki dialect via the AOT `train_*` Adam artifact
//!              (L2 graph, PJRT-executed from rust), logging the loss curve;
//!   2. QUANTIZE with the full DartQuant pipeline (capture → whip/QR-Orth
//!              calibration on the worker pool → fuse → GPTQ) and with the
//!              QuaRot + RTN baselines;
//!   3. EVALUATE perplexity on all three dialects + the 9-task zero-shot
//!              suite, printing the paper-style comparison row.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Env: DQ_TRAIN_STEPS (default 200), DQ_E2E_ITEMS (default 8).

use dartquant::coordinator::{Method, Pipeline, PipelineConfig, PrintObserver};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::{BitSetting, ModelConfig, TokenBatch, TrainState, Weights};
use dartquant::runtime::Runtime;
use dartquant::util::bench::{fnum, Table};
use dartquant::util::fmt_duration;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    let cfg = ModelConfig::builtin("llama2-tiny")?;
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let steps: usize = std::env::var("DQ_TRAIN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let items: usize = std::env::var("DQ_E2E_ITEMS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);

    // ---------------- 1. train -------------------------------------------
    println!("== stage 1: training {} ({:.1}M params) for {steps} steps ==",
        cfg.name, cfg.n_params() as f64 / 1e6);
    let init = Weights::default_grammar(&cfg, 1, corpus.successor())?;
    let mut state = TrainState::new(init);
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = 0.0;
    for step in 0..steps {
        let toks = TokenBatch::new(&corpus.train_batch(8, 256, step as u64));
        let loss = state.step(&rt, &toks, 1e-3)?;
        first.get_or_insert(loss);
        last = loss;
        if step % 20 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}  ppl {:.2}", (loss as f64).exp());
        }
    }
    println!("trained in {} — loss {:.3} → {:.3}", fmt_duration(t0.elapsed()), first.unwrap(), last);
    let weights = state.weights.clone();

    // ---------------- 2+3. quantize & evaluate -----------------------------
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: 3 };
    let eval_row = |w: &Weights, bits: BitSetting, use_had: bool| -> anyhow::Result<(f64, f64)> {
        let (a, kv) = (BitSetting::levels(bits.a), BitSetting::levels(bits.kv));
        let mut total = 0.0;
        for d in Dialect::ALL {
            let c = Corpus::new(d, cfg.vocab, 7);
            total += eval::ppl_artifact(&rt, w, &c, spec, a, kv, use_had)?;
        }
        let (_t, zs) = eval::zeroshot::suite_accuracy_artifact(
            &rt, w, Dialect::Wiki, items, 256, 99, a, kv, use_had,
        )?;
        Ok((total / 3.0, zs * 100.0))
    };

    let mut table = Table::new(&["Method", "Bits", "PPL(avg3)", "0-shot9", "calib time"]);
    let (fp_ppl, fp_zs) = eval_row(&weights, BitSetting::FP, false)?;
    table.row(&["FloatingPoint".into(), "16-16-16".into(), fnum(fp_ppl, 2), fnum(fp_zs, 2), "-".into()]);

    for method in [Method::Rtn, Method::QuaRot, Method::DartQuant] {
        let bits = BitSetting::W4A4;
        let mut pcfg = PipelineConfig::new(method, bits);
        pcfg.calib.steps = 50;
        pcfg.calib_sequences = 32;
        println!("\n== stage 2: {} pipeline ==", method.name());
        // The builder runs discrete stages; the observer prints each one
        // as it finishes (the same surface the CLI uses).
        let report = Pipeline::builder(&weights)
            .config(pcfg)
            .observer(Arc::new(PrintObserver))
            .run(&rt)?;
        println!(
            "  peak job bytes {:.1} MiB",
            report.stats.peak_job_bytes as f64 / (1 << 20) as f64
        );
        let use_had = report.rotation.as_ref().map(|r| r.online_had).unwrap_or(false);
        let (ppl, zs) = eval_row(&report.weights, bits, use_had)?;
        table.row(&[
            report.method.clone(),
            bits.label(),
            fnum(ppl, 2),
            fnum(zs, 2),
            fmt_duration(report.stats.calibrate_time),
        ]);
    }
    table.print("end-to-end: trained tiny model, W4A4 quantization");
    println!("\nexpected shape (paper Table 2): RTN collapses at W4A4; rotations recover\nmost of the fp gap; DartQuant calibration is the cheapest rotation method.");
    Ok(())
}
