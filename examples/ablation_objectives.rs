//! Objective ablation on real captured activations (Fig 6/7a in miniature):
//! calibrate the same rotation site with each of the four objectives and
//! compare loss trajectories, outlier counts and quantization error.
//!
//! ```sh
//! make artifacts && cargo run --release --example ablation_objectives
//! ```

use dartquant::calib::{calibrate_rotation, CalibConfig, Objective};
use dartquant::coordinator::capture_pools_native;
use dartquant::data::{Corpus, Dialect};
use dartquant::eval::stats;
use dartquant::model::{ModelConfig, Weights};
use dartquant::runtime::Runtime;
use dartquant::tensor::matmul;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    let cfg = ModelConfig::builtin("llama2-tiny")?;
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let weights = Weights::default_grammar(&cfg, 1, corpus.successor())?;

    println!("capturing calibration activations (native forward, 10% token sampling)...");
    let pools = capture_pools_native(&weights, &corpus.calib_sequences(8, 256), 0.1, 0);
    let pool = &pools.r1_pool;
    let tau = stats::outlier_threshold(pool, 0.995);
    println!(
        "pool: {} rows × {} dims; unrotated: {} outliers, quant error {:.4}\n",
        pool.rows,
        pool.cols,
        stats::count_outliers(pool, tau),
        stats::quant_error(pool, 4)
    );

    println!("{:10} {:>12} {:>12} {:>12} {:>12}", "objective", "loss[0]", "loss[end]", "#outliers", "quant err");
    for obj in Objective::ALL {
        let res = calibrate_rotation(
            &rt,
            pool,
            &CalibConfig { objective: obj, steps: 40, ..Default::default() },
        )?;
        let rotated = matmul(pool, &res.rotation);
        println!(
            "{:10} {:>12.4} {:>12.4} {:>12} {:>12.4}",
            obj.name(),
            res.losses[0],
            res.losses.last().unwrap(),
            stats::count_outliers(&rotated, tau),
            stats::quant_error(&rotated, 4)
        );
    }
    println!("\nall rotations collapse the outlier count (paper Fig 3); whip additionally\ndescends fastest on its own loss (Fig 7a).");
    Ok(())
}
