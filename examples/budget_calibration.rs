//! The single-3090 story (Table 3's DartQuant₃₀₉₀ rows): run the largest
//! stand-in model's calibration under a memory budget scaled to 24 GiB —
//! the end-to-end fine-tuning job is rejected by the admission gate while
//! DartQuant's per-rotation jobs stream through it.
//!
//! ```sh
//! make artifacts && cargo run --release --example budget_calibration
//! ```

use dartquant::coordinator::{spin_job_bytes, Method, Pipeline, PipelineConfig, WeightQuant};
use dartquant::data::{Corpus, Dialect};
use dartquant::model::{BitSetting, ModelConfig, Weights};
use dartquant::runtime::Runtime;
use dartquant::util::fmt_duration;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    let cfg = ModelConfig::builtin("llama2-large")?; // the 70B stand-in
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let weights = Weights::default_grammar(&cfg, 1, corpus.successor())?;
    let budget: u64 = 24 << 20; // 24 GiB scaled 1000× to our model scale

    println!(
        "model {} ({:.1}M params); scaled-3090 budget {} MiB",
        cfg.name,
        cfg.n_params() as f64 / 1e6,
        budget >> 20
    );
    println!(
        "e2e fine-tuning job needs {:.1} MiB of resident state\n",
        spin_job_bytes(&cfg) as f64 / (1 << 20) as f64
    );

    for method in [Method::SpinQuant, Method::DartQuant] {
        let mut pcfg = PipelineConfig::new(method, BitSetting::W4A4);
        pcfg.weight_quant = WeightQuant::Rtn;
        pcfg.calib.steps = 40;
        pcfg.spin.steps = 8;
        pcfg.calib_sequences = 16;
        print!("{:14} → ", method.name());
        // `.budget(...)` is the admission-gate axis of the builder API.
        match Pipeline::builder(&weights).config(pcfg).budget(Some(budget)).run(&rt) {
            Ok(report) => println!(
                "OK: calibrated in {} with peak job memory {:.1} MiB (budget {} MiB)",
                fmt_duration(report.stats.calibrate_time),
                report.stats.peak_job_bytes as f64 / (1 << 20) as f64,
                budget >> 20
            ),
            Err(e) => println!("REJECTED: {e}"),
        }
    }
    println!("\nThis is the paper's feasibility claim: rotation calibration for the\nlargest model fits a single consumer GPU; end-to-end fine-tuning does not.");
    Ok(())
}
