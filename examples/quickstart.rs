//! Quickstart: calibrate a rotation with DartQuant and watch it smooth an
//! activation distribution.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use dartquant::calib::{calibrate_rotation, CalibConfig};
use dartquant::eval::stats;
use dartquant::runtime::Runtime;
use dartquant::tensor::{matmul, Mat};
use dartquant::util::prng::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. A heavy-tailed activation pool with planted outlier channels —
    //    the distribution LLM quantization struggles with.
    let (rows, dim) = (2048, 256);
    let mut rng = Pcg64::new(42);
    let mut pool = Mat::from_fn(rows, dim, |_, _| rng.laplace(1.0));
    for &c in &rng.sample_indices(dim, 8) {
        for i in 0..rows {
            *pool.at_mut(i, c) *= 15.0;
        }
    }
    let tau = stats::outlier_threshold(&pool, 0.995);
    println!("before: {} outliers, 4-bit quant error {:.4}",
        stats::count_outliers(&pool, tau), stats::quant_error(&pool, 4));

    // 2. Calibrate a rotation: whip loss + QR-Orth, executed through the
    //    AOT-compiled XLA artifact (python never runs here).
    let rt = Runtime::open(Runtime::default_dir())?;
    let result = calibrate_rotation(&rt, &pool, &CalibConfig { steps: 40, ..Default::default() })?;
    println!(
        "calibrated in {:?} — whip loss {:.2} → {:.2}",
        result.wall,
        result.losses[0],
        result.losses.last().unwrap()
    );

    // 3. Rotate and re-measure: outliers collapse, quant error drops.
    let rotated = matmul(&pool, &result.rotation);
    println!("after:  {} outliers, 4-bit quant error {:.4}",
        stats::count_outliers(&rotated, tau), stats::quant_error(&rotated, 4));

    // Rotations are exact: norms (and hence fp model outputs) unchanged.
    let n0 = pool.row_sq_norms()[0];
    let n1 = rotated.row_sq_norms()[0];
    println!("norm preservation: {:.4} → {:.4}", n0.sqrt(), n1.sqrt());
    Ok(())
}
