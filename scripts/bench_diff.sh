#!/usr/bin/env bash
# Receipt drift check (`make bench-diff`): regenerate the BENCH_*.json
# receipts into a temp dir via scripts/bench_json.sh (same DQ_WORKERS
# pinning) and diff them against the committed copies at the repo root.
#
# Warning-only by default: committed receipts may still carry provenance
# "analytic estimate ..." (seeded in a container without a cargo
# toolchain), and even measured gflops wobble run to run — so drift
# prints a per-file report and exits 0. Set WARN_ONLY=0 to make drift
# fail the run once committed receipts are measured and you want a hard
# gate. Degrades to a clean skip when cargo is unavailable.
set -uo pipefail
cd "$(dirname "$0")/.."

WARN_ONLY="${WARN_ONLY:-1}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench-diff: cargo not available — skipping receipt regeneration (committed receipts unchecked)"
    exit 0
fi

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

if ! DQ_BENCH_JSON="$tmp" ./scripts/bench_json.sh; then
    echo "bench-diff: bench run failed — cannot compare receipts"
    if [ "$WARN_ONLY" = "1" ]; then exit 0; else exit 1; fi
fi

status=0
found=0
for fresh in "$tmp"/BENCH_*.json; do
    [ -e "$fresh" ] || continue
    found=1
    name="$(basename "$fresh")"
    committed="./$name"
    if [ ! -f "$committed" ]; then
        echo "bench-diff: $name: no committed receipt — commit the fresh one"
        status=1
        continue
    fi
    if grep -q '"provenance": "analytic estimate' "$committed"; then
        echo "bench-diff: $name: committed receipt is an analytic estimate — fresh numbers are expected to differ"
    fi
    if diff -u "$committed" "$fresh" > "$tmp/$name.diff" 2>&1; then
        echo "bench-diff: $name matches the committed receipt"
    else
        echo "bench-diff: $name drifted from the committed receipt:"
        sed 's/^/  /' "$tmp/$name.diff"
        status=1
    fi
done

if [ "$found" = "0" ]; then
    echo "bench-diff: no receipts generated — nothing to compare"
    exit 0
fi

if [ "$status" -ne 0 ] && [ "$WARN_ONLY" = "1" ]; then
    echo "bench-diff: drift found (warning-only while committed receipts remain analytic estimates; WARN_ONLY=0 to enforce)"
    exit 0
fi
exit "$status"
