#!/usr/bin/env bash
# Docs link checker (part of ci.sh / `make check` / `make check-links`):
# every relative path referenced from README.md and docs/*.md — markdown
# link targets plus `inline code` paths under docs/ or rust/src/ — must
# exist in the repo. Anchors (#...) are stripped; absolute URLs skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0

check() {
  local src="$1" target="$2"
  local base
  base="$(dirname "$src")"
  target="${target%%#*}" # strip in-page anchors
  [ -z "$target" ] && return 0
  case "$target" in
    http://*|https://*|mailto:*) return 0 ;;
  esac
  if [ ! -e "$base/$target" ] && [ ! -e "$target" ]; then
    echo "BROKEN: $src -> $target"
    fail=1
  fi
}

for f in README.md docs/*.md; do
  # Markdown link targets: [text](target)
  while IFS= read -r t; do
    check "$f" "$t"
  done < <(grep -o '](\([^)]*\))' "$f" | sed 's/^](//; s/)$//')
  # Path-like inline-code references to docs/ and rust/src/
  while IFS= read -r t; do
    check "$f" "$t"
  done < <(grep -o '`\(docs\|rust/src\)/[A-Za-z0-9_./-]*`' "$f" | tr -d '`' || true)
done

if [ "$fail" -ne 0 ]; then
  echo "docs link check failed"
  exit 1
fi
echo "docs link check: OK"
