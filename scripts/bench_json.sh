#!/usr/bin/env bash
# Persistent bench harness (`make bench-json`): run the perf benches on
# pinned configs and collect machine-readable receipts (BENCH_*.json)
# next to this repo's EXPERIMENTS.md.
#
# Pinning: DQ_WORKERS is fixed (4 unless the caller overrides) so
# committed receipts are comparable across runs; DQ_BENCH_JSON names the
# receipt directory and is what turns the receipt writer on — without it
# the benches are table-only.
#
#   perf_gemm      native; emits BENCH_gemm.json (gflops_f32 / gflops_i8 /
#                  gflops_i4 / weight_bytes — acceptance: i8 ≥ f32)
#   perf_decode    native; BENCH_decode.json — the KV-cached serving-path
#                  ledger (µs/token per path × prefix)
#   perf_serve     native; BENCH_serve.json — paged KV vs contiguous
#                  (sessions/GB, prefix hit rate, p99 step µs;
#                  acceptance: shared-prefix ratio ≥ 2)
#   perf_spec      native; BENCH_spec.json — self-speculative decoding
#                  (accept rate, tokens/round, decode speedup; acceptance:
#                  speculative streams token-identical to the verifier's)
#   perf_streaming native; BENCH_streaming.json — out-of-core vs
#                  in-memory pipeline cost + canonical byte-identity
#   perf_hotpath / perf_scheduler need artifacts/ (PJRT executables);
#                  skipped with a note when `make artifacts` hasn't run
#                  (perf_scheduler emits BENCH_scheduler.json)
#
# perf_gemm additionally emits BENCH_shard.json (the `--shards` plan's
# column-/row-parallel kernel rows, bit-identity gated). To compare a
# fresh run against the committed receipts, use `make bench-diff`
# (scripts/bench_diff.sh), which points DQ_BENCH_JSON at a temp dir.
set -euo pipefail
cd "$(dirname "$0")/.."

export DQ_WORKERS="${DQ_WORKERS:-4}"
export DQ_BENCH_JSON="${DQ_BENCH_JSON:-$PWD}"

echo "bench-json: DQ_WORKERS=$DQ_WORKERS receipts -> $DQ_BENCH_JSON"
cargo bench --bench perf_gemm
cargo bench --bench perf_decode
cargo bench --bench perf_serve
cargo bench --bench perf_spec
cargo bench --bench perf_streaming
if [ -d artifacts ]; then
    cargo bench --bench perf_hotpath
    cargo bench --bench perf_scheduler
else
    echo "bench-json: artifacts/ missing — skipping perf_hotpath and perf_scheduler (run 'make artifacts' first)"
fi
