//! Shared fixtures for the serving-side integration suites
//! (`serving.rs`, `shard.rs`, `packed.rs`, `pager.rs`, `spec.rs`).
//!
//! Each suite compiles as its own crate and pulls this in with
//! `mod common;`, so helpers unused by one suite are expected —
//! hence the file-level `allow(dead_code)`.
#![allow(dead_code)]

use dartquant::coordinator::MemoryGate;
use dartquant::data::{Corpus, Dialect};
use dartquant::model::{ModelConfig, Weights};
use dartquant::serve::{KvSlot, PagedKv, Pager};
use dartquant::tensor::Mat;
use std::sync::Arc;

/// The table2 configs exercised by the quick bench grid (llama3-small
/// adds grouped-query attention: 6 q heads over 2 kv heads).
pub const TABLE2_CONFIGS: [&str; 2] = ["llama2-tiny", "llama3-small"];

/// 4-bit KV codes — the paper's serving point — for pager-level tests.
pub const KV_LEVELS: f32 = 16.0;

/// Synthetic weights plus a 48-token stream: the decode-parity fixture.
/// Deterministic in (name, seed), like everything else here.
pub fn model(name: &str, seed: u64) -> (Arc<Weights>, Vec<i32>) {
    let cfg = ModelConfig::builtin(name).unwrap();
    let w = Weights::default_synthetic(&cfg, seed);
    let mut rng = dartquant::util::prng::Pcg64::new(seed ^ 0x5e55);
    let toks: Vec<i32> = (0..48).map(|_| rng.below(cfg.vocab) as i32).collect();
    (Arc::new(w), toks)
}

/// Grammar-initialized weights over the Wiki corpus — the pipeline
/// suites' fixture (quantization needs a model whose statistics aren't
/// pure noise).
pub fn grammar(cfg: &ModelConfig) -> (Weights, Corpus) {
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let w = Weights::default_grammar(cfg, 1, corpus.successor()).unwrap();
    (w, corpus)
}

pub fn tiny_cfg() -> ModelConfig {
    ModelConfig::builtin("llama2-tiny").unwrap()
}

pub fn tiny_pager(page_positions: usize, spill: bool, budget: Option<u64>) -> Arc<Pager> {
    Arc::new(Pager::new(
        &tiny_cfg(),
        KV_LEVELS,
        page_positions,
        spill,
        Arc::new(MemoryGate::new(budget)),
    ))
}

/// Prefill `kv` up to `to` positions through the `KvSlot` surface the
/// way `block_step` does: prepare, then extend + write rows per layer.
/// Row contents are a deterministic function of (seed, pos, head, i).
pub fn prefill_rows(pager: &Arc<Pager>, kv: &mut PagedKv, to: usize, seed: f32) {
    let from = kv.positions();
    assert!(
        pager.prepare_step(kv.sid(), to - from, &[kv.sid()]).unwrap(),
        "prepare_step deferred a session the test expected to run"
    );
    let (nl, nkv, hd) = {
        let l = pager.layout();
        (l.n_layers, l.nkv, l.hd)
    };
    for l in 0..nl {
        let slot = kv.layer_mut(l);
        slot.extend(to - from);
        for pos in from..to {
            for head in 0..nkv {
                let row: Vec<f32> = (0..hd)
                    .map(|i| seed + (pos * nkv + head) as f32 + i as f32 * 0.5)
                    .collect();
                slot.set_k(pos, head, &row);
                slot.set_v(pos, head, &row);
            }
        }
    }
}

/// Decode one K head of one layer into a dense matrix.
pub fn k_head(kv: &mut PagedKv, layer: usize, head: usize, hd: usize) -> Mat {
    let mut out = Mat::zeros(kv.positions(), hd);
    kv.layer_mut(layer).k_head_into(head, &mut out);
    out
}
