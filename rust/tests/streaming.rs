//! Out-of-core streaming integration tests — the `docs/STREAMING.md`
//! contract end-to-end, without artifacts:
//!
//! * a streamed run (`--streaming`) produces a **byte-identical
//!   canonical report** — and bit-identical weights — to the in-memory
//!   run, for every native-capable method, dense and `--packed`, at any
//!   worker count;
//! * peak resident weight bytes are bounded by the configured
//!   `--resident-budget`, which is a small fraction of model size;
//! * an over-tight budget (or an inherently monolithic method like
//!   SpinQuant's end-to-end fine-tuning) fails contextfully;
//! * `WeightStore` resident-byte accounting is **exact** under random
//!   checkout/checkin interleavings (propcheck);
//! * packed artifacts (`Weights::save`/`load`) roundtrip codes + scales
//!   bit-identically for every QMat scheme.

use dartquant::coordinator::{Pipeline, PipelineReport};
use dartquant::data::{Corpus, Dialect};
use dartquant::model::{
    suggested_resident_budget, BitSetting, ModelConfig, WeightStore, Weights,
};
use dartquant::util::propcheck::{gen, Runner};
use std::path::PathBuf;

fn model(name: &str) -> Weights {
    let cfg = ModelConfig::builtin(name).unwrap();
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap()
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("dartquant-test-streaming");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}-{}.dartq", std::process::id()))
}

fn run(w: &Weights, method: &str, packed: bool, streamed: bool, workers: usize) -> PipelineReport {
    let mut b = Pipeline::builder(w)
        .method(method)
        .unwrap()
        .bits(BitSetting::W4A4)
        .packed(packed)
        .workers(workers);
    if streamed {
        b = b
            .streaming(true)
            .resident_budget(Some(suggested_resident_budget(&w.cfg)));
    }
    b.run_native().unwrap_or_else(|e| panic!("{method} streamed={streamed}: {e:#}"))
}

fn assert_same_model(a: &Weights, b: &Weights) {
    assert_eq!(a.names(), b.names());
    for name in a.names() {
        assert_eq!(a.tensor(name), b.tensor(name), "weight {name} differs");
    }
}

#[test]
fn streamed_canonical_reports_are_byte_identical_to_in_memory() {
    let w = model("llama2-tiny");
    for method in ["rtn", "smoothquant", "gptq", "omniquant", "quarot"] {
        let inmem = run(&w, method, false, false, 2);
        let streamed = run(&w, method, false, true, 2);
        assert_eq!(
            streamed.record().canonical().to_json().to_string(),
            inmem.record().canonical().to_json().to_string(),
            "canonical report differs for {method}"
        );
        assert_same_model(&streamed.weights, &inmem.weights);
        assert!(streamed.stats.peak_weight_bytes > 0, "{method}: streamed peak not recorded");
        assert_eq!(inmem.stats.peak_weight_bytes, 0, "{method}: in-memory runs hold no leases");
    }
}

#[test]
fn streamed_packed_run_matches_in_memory_bit_for_bit() {
    let w = model("llama2-tiny");
    let inmem = run(&w, "rtn", true, false, 1);
    let streamed = run(&w, "rtn", true, true, 1);
    assert!(streamed.weights.has_packed(), "packed run must emit QMat linears");
    assert_same_model(&streamed.weights, &inmem.weights);
    assert_eq!(
        streamed.record().canonical().to_json().to_string(),
        inmem.record().canonical().to_json().to_string()
    );
    assert_eq!(streamed.model_bytes, inmem.model_bytes);
    assert!(streamed.compression_ratio() > 6.0, "4-bit packing must shrink the linears");
}

#[test]
fn streamed_runs_are_worker_count_invariant() {
    // The scheduler fan-out (OmniQuant's per-layer jobs) composed with
    // store leases: workers=1 and workers=4 must not change anything.
    let w = model("llama2-tiny");
    let one = run(&w, "omniquant", true, true, 1);
    let four = run(&w, "omniquant", true, true, 4);
    assert_eq!(
        one.record().canonical().to_json().to_string(),
        four.record().canonical().to_json().to_string()
    );
    assert_same_model(&one.weights, &four.weights);
}

#[test]
fn resident_budget_bounds_peak_weight_bytes_to_a_model_fraction() {
    let w = model("llama2-tiny");
    let budget = suggested_resident_budget(&w.cfg);
    let model_bytes = w.nbytes();
    assert!(budget * 4 <= model_bytes, "budget {budget} not ≤ 1/4 of {model_bytes}");
    let report = run(&w, "gptq", false, true, 2);
    assert!(report.stats.peak_weight_bytes <= budget);
    assert!(report.stats.peak_weight_bytes > 0);
}

#[test]
fn overtight_budget_fails_with_the_gate_error() {
    let w = model("llama2-tiny");
    let err = Pipeline::builder(&w)
        .method("rtn")
        .unwrap()
        .bits(BitSetting::W4A4)
        .streaming(true)
        .resident_budget(Some(1024)) // smaller than any single tensor
        .run_native()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("memory budget"), "got: {msg}");
    assert!(msg.contains("checkout"), "got: {msg}");
}

#[test]
fn end_to_end_fine_tuning_declines_streaming() {
    let w = model("llama2-tiny");
    let err = Pipeline::builder(&w)
        .method("spinquant")
        .unwrap()
        .bits(BitSetting::W4A4)
        .streaming(true)
        .run_native()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("--streaming"), "got: {msg}");
    assert!(msg.contains("whole model"), "got: {msg}");
}

#[test]
fn prop_resident_accounting_is_exact_under_random_interleavings() {
    let w = model("llama2-tiny");
    let path = scratch("propcheck");
    let store = WeightStore::create(&path, &w, None).unwrap();
    let names: Vec<String> = store.names().to_vec();
    Runner::new().cases(24).run("resident bytes == Σ live lease bytes", |rng| {
        let mut live = Vec::new();
        for _ in 0..gen::size(rng, 4, 24) {
            if !live.is_empty() && rng.below(2) == 0 {
                // Check a random lease back in (drop = release).
                let at = rng.below(live.len());
                live.swap_remove(at);
            } else {
                // Check a random tensor subset out.
                let k = gen::size(rng, 1, 4);
                let mut subset = Vec::new();
                for _ in 0..k {
                    subset.push(names[rng.below(names.len())].clone());
                }
                subset.sort();
                subset.dedup();
                live.push(store.checkout(&subset).unwrap());
            }
            let expect: u64 = live.iter().map(|l| l.bytes()).sum();
            if store.resident_bytes() != expect {
                return Err(format!(
                    "resident {} != expected {expect} with {} live leases",
                    store.resident_bytes(),
                    live.len()
                ));
            }
        }
        drop(live);
        if store.resident_bytes() != 0 {
            return Err("leases leaked resident bytes".into());
        }
        Ok(())
    });
    std::fs::remove_file(path).ok();
}

#[test]
fn packed_checkpoints_feed_the_pipeline_like_their_dense_dequantization() {
    // save() now persists packed tensors natively; a reloaded --packed
    // checkpoint must still enter the (dense-only) pipeline stages —
    // exactly as the dense dequantization that pre-streaming save()
    // wrote, rather than panicking in fuse/map_linear_weights.
    use dartquant::quant;
    let w = model("llama2-tiny");
    let packed = quant::rtn_quantize_model_packed(&w, 4);
    let path = scratch("packed-into-pipeline");
    packed.save(&path).unwrap();
    let reloaded = Weights::load(&path).unwrap();
    assert!(reloaded.has_packed());
    let from_packed = run(&reloaded, "quarot", false, false, 1);
    let from_dense = run(&packed.to_dense(), "quarot", false, false, 1);
    assert_same_model(&from_packed.weights, &from_dense.weights);
    // Streamed runs take the same dense entry path.
    let streamed = run(&reloaded, "quarot", false, true, 1);
    assert_same_model(&streamed.weights, &from_dense.weights);
    std::fs::remove_file(path).ok();
}

#[test]
fn packed_artifact_save_load_is_bit_identical_for_every_scheme() {
    use dartquant::coordinator::act_absmax;
    use dartquant::quant;
    let w = model("llama2-tiny");
    // Cover all three QMat schemes in one checkpoint: per-row (RTN),
    // protected (QUIK) and grouped (Atom), alongside dense embed/head.
    let mut q = quant::rtn_quantize_model_packed(&w, 4);
    let corpus = Corpus::new(Dialect::Wiki, w.cfg.vocab, 7);
    let absmax = act_absmax(&w, &corpus.calib_sequences(1, 64));
    let a = &absmax["l0.wq"];
    q.set_packed("l0.wq", quant::quik_quantize_qmat(w.get("l0.wq"), a, 16, 4));
    q.set_packed("l0.wk", quant::atom_quantize_qmat(w.get("l0.wk"), a, 4));
    let path = scratch("packed-roundtrip");
    q.save(&path).unwrap();
    let back = Weights::load(&path).unwrap();
    assert!(back.has_packed());
    assert_same_model(&back, &q);
    assert_eq!(back.nbytes(), q.nbytes(), "true packed footprint survives the roundtrip");
    assert_eq!(
        back.tensor("l0.wq").as_packed().unwrap().scheme_label(),
        "protected"
    );
    assert_eq!(back.tensor("l0.wk").as_packed().unwrap().scheme_label(), "grouped");
    std::fs::remove_file(path).ok();
}
