//! Speculative-decoding equality gate (`serve::spec`):
//!
//! * **exact equality** — greedy speculative decode is token-for-token
//!   identical to the verifier decoding alone, at every tested draft
//!   window `k ∈ {1, 2, 4, 8}`, KV backend (contiguous, paged P=16),
//!   worker count {1, 4}, and shard count — the tentpole contract,
//! * **seeded sampling** — at temperature > 0 the realized stream is a
//!   deterministic function of (seed, k), invariant to workers and
//!   backend,
//! * **degenerate shapes** — draft ≡ verifier precision accepts every
//!   proposal; `k = 1`; prompts longer than the continuation,
//! * **rollback** — a rejected round truncates both caches to state
//!   observationally bit-identical to a fresh prefill of the accepted
//!   prefix (same bytes, same positions, same continuation logits), and
//!   paged mode releases the freed pages,
//! * a `util::propcheck` property pins accepted-prefix length as
//!   invariant to the KV backend.
//!
//! Runs natively (no artifacts needed).

use dartquant::model::{FwdOptions, Weights};
use dartquant::serve::{
    BatchEngine, DecodeSession, EngineConfig, GenRequest, KvCache, PagedConfig, SpecConfig,
    SpecSession,
};
use dartquant::util::prng::Pcg64;
use dartquant::util::propcheck::{gen, Runner};
use std::sync::Arc;

mod common;
use common::{model, tiny_pager, TABLE2_CONFIGS};

/// A packed low-bit draft of the same checkpoint — the self-speculative
/// setup the tentpole serves.
fn packed_draft(w: &Arc<Weights>, bits: u8) -> Arc<Weights> {
    Arc::new(dartquant::quant::rtn_quantize_model_packed(w, bits))
}

#[test]
fn greedy_speculative_decode_is_token_identical_at_every_k_backend_and_worker_count() {
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 41);
        let draft = packed_draft(&w, 4);
        let base =
            EngineConfig { opt: FwdOptions::quant(8, 8, false), seed: 3, ..Default::default() };
        let requests: Vec<(Vec<i32>, usize)> =
            (0..3).map(|i| (toks[i * 6..i * 6 + 6 + i].to_vec(), 5 + 2 * i)).collect();
        let run = |speculate: Option<SpecConfig>, paged: Option<PagedConfig>, workers: usize| {
            let mut e = BatchEngine::new(
                Arc::clone(&w),
                EngineConfig { speculate, paged, workers, ..base },
            );
            if speculate.is_some() {
                e.set_draft(Arc::clone(&draft), FwdOptions::quant(4, 8, false));
            }
            for (prompt, max_new) in &requests {
                e.submit(GenRequest { prompt: prompt.clone(), max_new: *max_new });
            }
            e.run().unwrap();
            e
        };
        let oracle = run(None, None, 1);
        for k in [1usize, 2, 4, 8] {
            for paged in [None, Some(PagedConfig { page_positions: 16, spill: false })] {
                for workers in [1usize, 4] {
                    let e = run(Some(SpecConfig { k }), paged, workers);
                    let ctx = format!(
                        "{name} k={k} paged={} workers={workers}",
                        paged.is_some()
                    );
                    assert_eq!(e.results(), oracle.results(), "{ctx}: tokens diverged");
                    assert_eq!(
                        e.canonical_events(),
                        oracle.canonical_events(),
                        "{ctx}: lifecycle diverged"
                    );
                    if let Some(pager) = e.pager() {
                        assert_eq!(pager.charged_bytes(), 0, "{ctx}: pages leaked");
                    }
                    let stats = e.spec_stats().unwrap();
                    assert!(stats.rounds > 0, "{ctx}: no speculative round ever ran");
                }
            }
        }
    }
}

#[test]
fn greedy_speculative_decode_is_shard_invariant() {
    // The verifier's greedy stream is bit-identical at any shard count,
    // so the speculative stream must be too — including when only the
    // pair's forwards are sharded and the oracle's are not.
    let (w, toks) = model("llama2-tiny", 44);
    let draft = packed_draft(&w, 4);
    let base = EngineConfig { opt: FwdOptions::quant(8, 8, false), seed: 7, ..Default::default() };
    let mut oracle = BatchEngine::new(Arc::clone(&w), base);
    oracle.submit(GenRequest { prompt: toks[..9].to_vec(), max_new: 8 });
    oracle.run().unwrap();
    for shards in [1usize, 2, 4] {
        let opt = base.opt.with_shards(shards);
        let mut e = BatchEngine::new(
            Arc::clone(&w),
            EngineConfig { opt, speculate: Some(SpecConfig { k: 4 }), ..base },
        );
        e.set_draft(Arc::clone(&draft), FwdOptions::quant(4, 8, false).with_shards(shards));
        e.submit(GenRequest { prompt: toks[..9].to_vec(), max_new: 8 });
        e.run().unwrap();
        assert_eq!(e.results(), oracle.results(), "shards={shards}");
    }
}

#[test]
fn seeded_sampling_stream_is_deterministic_per_seed_at_any_k() {
    // Temperature > 0: the realized stream is a deterministic function
    // of (seed, k) — repeat runs, worker counts, and KV backends must
    // reproduce it exactly. (Different k legitimately realizes different
    // streams: the rejection-sampling draw order depends on k.)
    let (w, toks) = model("llama2-tiny", 42);
    let draft = packed_draft(&w, 4);
    for k in [1usize, 2, 4, 8] {
        let run = |paged: Option<PagedConfig>, workers: usize| {
            let mut e = BatchEngine::new(
                Arc::clone(&w),
                EngineConfig {
                    opt: FwdOptions::quant(8, 8, false),
                    seed: 9,
                    temperature: 0.8,
                    speculate: Some(SpecConfig { k }),
                    paged,
                    workers,
                    ..Default::default()
                },
            );
            e.set_draft(Arc::clone(&draft), FwdOptions::quant(4, 8, false));
            e.submit(GenRequest { prompt: toks[..7].to_vec(), max_new: 9 });
            e.submit(GenRequest { prompt: toks[7..12].to_vec(), max_new: 6 });
            e.run().unwrap().to_vec()
        };
        let want = run(None, 1);
        assert!(want.iter().all(|r| r.error.is_none()), "k={k}");
        assert_eq!(want[0].tokens.len(), 9, "k={k}: short stream");
        assert_eq!(run(None, 1), want, "k={k}: rerun diverged");
        assert_eq!(run(None, 4), want, "k={k}: workers changed the stream");
        let paged = Some(PagedConfig { page_positions: 16, spill: false });
        assert_eq!(run(paged, 1), want, "k={k}: paged backend changed the stream");
        assert_eq!(run(paged, 4), want, "k={k}: paged × workers changed the stream");
    }
}

#[test]
fn identical_precisions_accept_every_proposal_through_the_engine() {
    // Draft ≡ verifier (no set_draft): every proposal must accept, so
    // total engine steps collapse well below one per token.
    let (w, toks) = model("llama2-tiny", 45);
    let mut e = BatchEngine::new(
        Arc::clone(&w),
        EngineConfig { speculate: Some(SpecConfig { k: 4 }), ..Default::default() },
    );
    e.submit(GenRequest { prompt: toks[..6].to_vec(), max_new: 12 });
    e.run().unwrap();
    let stats = e.spec_stats().unwrap();
    assert_eq!(stats.accepted, stats.proposed, "identical models must all-accept");
    assert!(stats.proposed > 0);
    assert!(e.steps() < 12, "all-accept rounds must beat one-token-per-step");
}

#[test]
fn prompts_longer_than_the_continuation_clamp_the_round() {
    // max_new < k: rounds clamp to the remaining headroom (k_round =
    // remaining − 1, down to the plain single-step path) and the stream
    // still matches the verifier alone — in both backends.
    let (w, toks) = model("llama2-tiny", 46);
    let draft = packed_draft(&w, 4);
    let base = EngineConfig { opt: FwdOptions::quant(8, 8, false), ..Default::default() };
    for max_new in [1usize, 2, 3] {
        let mut oracle = BatchEngine::new(Arc::clone(&w), base);
        oracle.submit(GenRequest { prompt: toks[..20].to_vec(), max_new });
        oracle.run().unwrap();
        for paged in [None, Some(PagedConfig { page_positions: 16, spill: false })] {
            let mut e = BatchEngine::new(
                Arc::clone(&w),
                EngineConfig { speculate: Some(SpecConfig { k: 8 }), paged, ..base },
            );
            e.set_draft(Arc::clone(&draft), FwdOptions::quant(4, 8, false));
            e.submit(GenRequest { prompt: toks[..20].to_vec(), max_new });
            e.run().unwrap();
            assert_eq!(
                e.results(),
                oracle.results(),
                "max_new={max_new} paged={}",
                paged.is_some()
            );
        }
    }
}

/// Build a standalone speculative pair over `pager`-less contiguous
/// caches (`paged = false`) or one shared pager (`paged = true`, the
/// draft admitted privately — different KV precision must never share
/// prefix pages).
fn standalone_pair(
    w: &Arc<Weights>,
    draft_w: &Arc<Weights>,
    prompt: &[i32],
    max_new: usize,
    k: usize,
    page_positions: Option<usize>,
) -> SpecSession {
    let vopt = FwdOptions::quant(8, 4, false); // 4-bit KV == common::KV_LEVELS
    let dopt = FwdOptions::quant(4, 4, false);
    match page_positions {
        None => SpecSession::new(
            DecodeSession::new(Arc::clone(draft_w), dopt),
            DecodeSession::new(Arc::clone(w), vopt),
            k,
        ),
        Some(p) => {
            let pager = tiny_pager(p, false, None);
            let target = (prompt.len() + max_new - 1).max(prompt.len());
            let vsid = pager.admit(prompt, target).unwrap().unwrap();
            let dsid = pager.admit_private(prompt, target).unwrap().unwrap();
            SpecSession::new(
                DecodeSession::with_cache(
                    Arc::clone(draft_w),
                    dopt,
                    KvCache::paged(&pager, dsid),
                ),
                DecodeSession::with_cache(Arc::clone(w), vopt, KvCache::paged(&pager, vsid)),
                k,
            )
        }
    }
}

#[test]
fn prop_accepted_prefix_length_is_invariant_to_the_kv_backend() {
    // The draft's proposals and the verifier's verdicts depend only on
    // model math, never on how KV rows are stored — so per-run accept
    // counts (and the tokens) must match between a contiguous pair and a
    // paged pair at any page size.
    let (w, toks) = model("llama2-tiny", 43);
    let draft_w = packed_draft(&w, 4);
    Runner::new().cases(10).run("accepted prefix is backend-invariant", |rng| {
        let k = 1 + rng.below(8);
        let plen = gen::size(rng, 2, 16);
        let max_new = 1 + rng.below(10);
        let page = [1usize, 4, 16][rng.below(3)];
        let prompt = &toks[..plen];
        let mut streams = Vec::new();
        let mut stats = Vec::new();
        for paged in [None, Some(page)] {
            let mut spec = standalone_pair(&w, &draft_w, prompt, max_new, k, paged);
            let mut rng2 = Pcg64::new(17);
            let out = spec.generate(prompt, max_new, 0.0, &mut rng2).unwrap();
            streams.push(out);
            stats.push(spec.stats());
        }
        if streams[0] != streams[1] {
            return Err(format!("tokens diverged: {:?} vs {:?}", streams[0], streams[1]));
        }
        if stats[0] != stats[1] {
            return Err(format!(
                "k={k} plen={plen} max_new={max_new} P={page}: stats diverged: {:?} vs {:?}",
                stats[0], stats[1]
            ));
        }
        Ok(())
    });
}

#[test]
fn rejected_rounds_roll_both_caches_back_to_the_committed_prefix() {
    // A draft from a *different* synthetic seed proposes near-random
    // tokens, forcing rejections; greedy output must still be exactly
    // the verifier's own stream, and the pending-tail accounting must
    // land where the round protocol says it lands.
    let (w, toks) = model("llama2-tiny", 47);
    let (mismatched, _) = model("llama2-tiny", 48); // same config, different weights
    let opt = FwdOptions::quant(8, 8, false);
    let prompt = &toks[..8];
    let max_new = 10;

    // Verifier-alone oracle.
    let mut solo = BatchEngine::new(Arc::clone(&w), EngineConfig { opt, ..Default::default() });
    solo.submit(GenRequest { prompt: prompt.to_vec(), max_new });
    let want = solo.run().unwrap()[0].tokens.clone();

    let mut spec = SpecSession::new(
        DecodeSession::new(Arc::clone(&mismatched), opt),
        DecodeSession::new(Arc::clone(&w), opt),
        4,
    );
    let out = spec.generate(prompt, max_new, 0.0, &mut Pcg64::new(0)).unwrap();
    assert_eq!(out, want, "rejections must never leak draft tokens into the stream");
    let stats = spec.stats();
    assert!(
        stats.accepted < stats.proposed,
        "a mismatched draft should have been rejected at least once \
         (accepted {} of {})",
        stats.accepted,
        stats.proposed
    );
    // Pending-tail invariant after the final commit: the verifier always
    // holds every committed token but the newest; the draft's pending
    // tail is 1 between rounds, 2 after an all-accept carry, plus at
    // most 1 from a final plain step.
    let committed = prompt.len() + out.len();
    assert_eq!(spec.verifier_positions(), committed - 1);
    let dpos = spec.draft_positions();
    assert!(
        (committed - 3..committed).contains(&dpos),
        "draft positions {dpos} outside the pending-tail envelope of {committed}"
    );

    // Rolled-back caches account exactly like sessions that only ever
    // prefilled the committed prefix each cache has consumed.
    let seq: Vec<i32> = prompt.iter().chain(&out).copied().collect();
    let mut fresh_d = DecodeSession::new(Arc::clone(&mismatched), opt);
    fresh_d.prefill(&seq[..dpos]);
    let mut fresh_v = DecodeSession::new(Arc::clone(&w), opt);
    fresh_v.prefill(&seq[..committed - 1]);
    assert_eq!(
        spec.cache_nbytes(),
        fresh_d.cache_nbytes() + fresh_v.cache_nbytes(),
        "post-rollback bytes differ from fresh prefills of the same prefixes"
    );
}

/// Rollback must leave a cache observationally identical to one that
/// only ever prefilled the kept prefix: same byte accounting, same
/// positions, and — the bit-for-bit part — identical logits for any
/// continuation (logits integrate every cached row, so a single
/// corrupted or stale-read row would diverge).
#[test]
fn truncate_is_indistinguishable_from_a_fresh_prefill_in_both_backends() {
    let (w, toks) = model("llama2-tiny", 49);
    let opt = FwdOptions::quant(8, 4, false); // 4-bit KV == common::KV_LEVELS
    let (keep, full) = (6usize, 10usize);

    // Contiguous.
    let mut rolled = DecodeSession::new(Arc::clone(&w), opt);
    rolled.prefill(&toks[..full]);
    rolled.truncate(keep);
    let mut fresh = DecodeSession::new(Arc::clone(&w), opt);
    fresh.prefill(&toks[..keep]);
    assert_eq!(rolled.positions(), fresh.positions());
    assert_eq!(rolled.cache_nbytes(), fresh.cache_nbytes());
    assert_eq!(
        rolled.prefill(&toks[keep..full + 2]),
        fresh.prefill(&toks[keep..full + 2]),
        "contiguous: rolled-back cache decodes differently from a fresh prefill"
    );

    // Paged, P=4: keep=6 straddles a page boundary (1 full page + a
    // partially-kept one); the dropped tail page must be released.
    let pager = tiny_pager(4, false, None);
    let lay_bytes = pager.layout().page_bytes() * pager.layout().n_layers as u64;
    let sid = pager.admit(&toks[..full], full + 4).unwrap().unwrap();
    let mut rolled = DecodeSession::with_cache(Arc::clone(&w), opt, KvCache::paged(&pager, sid));
    rolled.reserve(full).unwrap();
    rolled.prefill(&toks[..full]);
    assert_eq!(pager.session_pages(sid), 3 * pager.layout().n_layers, "10 positions, P=4");
    rolled.truncate(keep);
    assert_eq!(
        pager.session_pages(sid),
        2 * pager.layout().n_layers,
        "paged rollback must release the dropped tail page"
    );
    assert_eq!(pager.charged_bytes(), 2 * lay_bytes, "released pages leave the gate");
    let fsid = pager.admit(&toks[..keep], keep + full + 2).unwrap().unwrap();
    let mut fresh = DecodeSession::with_cache(Arc::clone(&w), opt, KvCache::paged(&pager, fsid));
    fresh.reserve(keep).unwrap();
    fresh.prefill(&toks[..keep]);
    assert_eq!(rolled.positions(), fresh.positions());
    assert_eq!(rolled.cache_nbytes(), fresh.cache_nbytes());
    rolled.reserve(full + 2 - keep).unwrap();
    let a = rolled.prefill(&toks[keep..full + 2]);
    fresh.reserve(full + 2 - keep).unwrap();
    let b = fresh.prefill(&toks[keep..full + 2]);
    assert_eq!(a, b, "paged: rolled-back cache decodes differently from a fresh prefill");
}
