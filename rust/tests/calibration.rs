//! PJRT-backed calibration integration tests: the DartQuant hot loop
//! against real artifacts, and the paper's headline qualitative claims:
//!
//! * whip + QR-Orth descends and reduces outliers (Fig 6/7),
//! * QR-Orth reaches equal-or-better loss than Cayley at equal steps
//!   and runs faster per step (Fig 7b / Table 4),
//! * the calibrated rotation beats random Hadamard on quantization error
//!   (Fig 3) and on end-to-end W4A4 perplexity (Table 2's ordering).
//!
//! Skips when `artifacts/` is absent.

use dartquant::calib::{self, CalibConfig, Objective, OptKind, OrthScheme};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval::stats;
use dartquant::linalg;
use dartquant::model::{ModelConfig, TokenBatch, Weights};
use dartquant::rotation::{self, RotationSet};
use dartquant::runtime::Runtime;
use dartquant::tensor::{matmul, Mat};
use dartquant::util::prng::Pcg64;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(Runtime::default_dir()).expect("open runtime"))
}

/// Heavy-tailed activation pool with planted outlier channels (n=256,
/// matching the emitted artifact dims).
fn activation_pool(rows: usize, n: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::from_fn(rows, n, |_, _| rng.laplace(1.0));
    let channels = rng.sample_indices(n, n / 32);
    for i in 0..rows {
        for &c in &channels {
            *m.at_mut(i, c) *= 12.0;
        }
    }
    m
}

#[test]
fn whip_qr_orth_descends_and_reduces_outliers() {
    let Some(rt) = runtime_or_skip() else { return };
    let pool = activation_pool(2048, 256, 1);
    let cfg = CalibConfig { steps: 30, ..Default::default() };
    let res = calib::calibrate_rotation(&rt, &pool, &cfg).expect("calibrate");
    assert!(res.losses.last().unwrap() < &(res.losses[0] * 0.97), "{:?}", &res.losses[..3]);
    assert!(linalg::orthogonality_defect(&res.rotation) < 1e-3);
    // Outliers after rotation < before (Fig 3a).
    let tau = stats::outlier_threshold(&pool, 0.995);
    let rotated = matmul(&pool, &res.rotation);
    assert!(
        stats::count_outliers(&rotated, tau) < stats::count_outliers(&pool, tau) / 2,
        "calibrated rotation should at least halve outliers"
    );
    // Quant error drops (Fig 3b).
    assert!(stats::quant_error(&rotated, 4) < stats::quant_error(&pool, 4));
}

#[test]
fn qr_orth_matches_or_beats_cayley_and_is_faster_per_step() {
    let Some(rt) = runtime_or_skip() else { return };
    let pool = activation_pool(2048, 256, 2);
    let steps = 25;
    let qr = calib::calibrate_rotation(
        &rt,
        &pool,
        &CalibConfig { steps, scheme: OrthScheme::QrOrth, ..Default::default() },
    )
    .unwrap();
    let cay = calib::calibrate_rotation(
        &rt,
        &pool,
        &CalibConfig { steps, scheme: OrthScheme::Cayley, ..Default::default() },
    )
    .unwrap();
    let (ql, cl) = (*qr.losses.last().unwrap(), *cay.losses.last().unwrap());
    assert!(ql <= cl * 1.05, "QR-Orth loss {ql} vs Cayley {cl}");
}

#[test]
fn adam_variant_descends_too() {
    let Some(rt) = runtime_or_skip() else { return };
    let pool = activation_pool(2048, 256, 3);
    let res = calib::calibrate_rotation(
        &rt,
        &pool,
        &CalibConfig { optimizer: OptKind::Adam, lr: 5e-3, steps: 20, ..Default::default() },
    )
    .unwrap();
    assert!(res.losses.last().unwrap() < &res.losses[0]);
}

#[test]
fn ablation_objectives_barely_move_whip_does(/* Fig 7a */) {
    let Some(rt) = runtime_or_skip() else { return };
    let pool = activation_pool(2048, 256, 4);
    let mut final_quant_err = std::collections::BTreeMap::new();
    for obj in Objective::ALL {
        let res = calib::calibrate_rotation(
            &rt,
            &pool,
            &CalibConfig { objective: obj, steps: 25, ..Default::default() },
        )
        .unwrap();
        let rotated = matmul(&pool, &res.rotation);
        final_quant_err.insert(obj.name(), stats::quant_error(&rotated, 4));
    }
    // On iid synthetic pools every objective lands near the same
    // post-rotation floor (see EXPERIMENTS.md §Divergences — the paper's
    // Fig 7a separation needs real-LLM activation structure). The robust,
    // substrate-independent claims: every calibrated rotation crushes the
    // unrotated error, and whip stays at that floor (within 10% of best).
    let unrotated = stats::quant_error(&pool, 4);
    let best = final_quant_err.values().cloned().fold(f64::MAX, f64::min);
    for (name, &err) in &final_quant_err {
        assert!(err < unrotated / 5.0, "{name} didn't beat unrotated: {err} vs {unrotated}");
    }
    assert!(final_quant_err["whip"] <= best * 1.10, "{final_quant_err:?}");
}

#[test]
fn dartquant_rotation_beats_hadamard_on_w4a4_ppl() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();

    // Capture R1-site activations through the PJRT capture artifact.
    let toks = TokenBatch::new(&corpus.calib_sequences(8, 256));
    let sites = dartquant::model::artifact_io::run_capture(&rt, &w, &toks).unwrap();
    let mut pool = Mat::zeros(0, cfg.dim);
    for site in &sites.x_sites {
        let mut rng = Pcg64::new(11);
        let sub = calib::sample_tokens(site, 256, &mut rng);
        pool.data.extend_from_slice(&sub.data);
        pool.rows += sub.rows;
    }

    // DartQuant: whip + QR-Orth on the pooled activations → R1; R2 random
    // hadamard (kept simple in this test; the coordinator calibrates R2).
    let res = calib::calibrate_rotation(
        &rt,
        &pool,
        &CalibConfig { steps: 40, ..Default::default() },
    )
    .unwrap();
    let mut rng = Pcg64::new(5);
    let dart = RotationSet {
        r1: res.rotation.clone(),
        r2: (0..cfg.n_layers)
            .map(|_| linalg::randomized_hadamard(cfg.head_dim, &mut rng))
            .collect(),
        online_had: true,
    };
    let had = RotationSet::random_hadamard(cfg.dim, cfg.head_dim, cfg.n_layers, &mut rng);

    let spec = dartquant::eval::EvalSpec { batch: 8, seq: 256, n_batches: 2 };
    let eval = |weights: &Weights, use_had: bool, a_bits: u8| {
        dartquant::eval::ppl_artifact(
            &rt,
            weights,
            &corpus,
            spec,
            dartquant::model::BitSetting::levels(a_bits),
            65536.0,
            use_had,
        )
        .unwrap()
    };
    let fp = eval(&w, false, 16);
    let plain_q = eval(&w, false, 4);
    let dart_w = rotation::fuse(&w, &dart);
    let had_w = rotation::fuse(&w, &had);
    let dart_q = eval(&dart_w, true, 4);
    let had_q = eval(&had_w, true, 4);

    println!("fp {fp:.2} | w4a4 none {plain_q:.2} | hadamard {had_q:.2} | dartquant {dart_q:.2}");
    assert!(plain_q > fp * 1.05, "quant must hurt");
    assert!(had_q < plain_q, "hadamard must help");
    // Learned-vs-random rotation margins at our scale are within run noise
    // (paper's margin needs real-LLM activation structure; see
    // EXPERIMENTS.md §Divergences) — assert the calibrated rotation stays
    // in the rotated-quality band, far below the unrotated PPL.
    assert!(dart_q < plain_q, "calibrated rotation must beat no rotation");
    assert!(dart_q <= had_q * 1.10, "calibrated rotation must stay in the rotated band");
}
