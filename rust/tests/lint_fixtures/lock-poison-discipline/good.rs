// Fixture: poison-tolerant locking through util::sync.
use dartquant::util::sync::lock_or_poisoned;
use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = lock_or_poisoned(counter);
    *g += 1;
    *g
}
