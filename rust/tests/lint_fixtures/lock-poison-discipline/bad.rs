// Fixture: bare lock unwraps cascade one panic into every thread.
use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = counter.lock().unwrap();
    *g += 1;
    *g
}

pub fn read(counter: &Mutex<u64>) -> u64 {
    *counter.lock().expect("counter lock")
}
