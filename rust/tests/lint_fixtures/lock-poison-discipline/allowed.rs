// Fixture: a bare unwrap justified per site.
use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) -> u64 {
    // dqlint::allow(lock-poison-discipline): lock is private to this
    // function and no code path panics while holding it.
    let mut g = counter.lock().unwrap();
    *g += 1;
    *g
}
