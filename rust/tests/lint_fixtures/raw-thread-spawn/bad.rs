// Fixture: raw spawn bypasses the pool's panic containment.
pub fn fan_out() {
    let h = std::thread::spawn(|| 2 + 2);
    let _ = h.join();
    std::thread::scope(|s| {
        s.spawn(|| ());
    });
}
