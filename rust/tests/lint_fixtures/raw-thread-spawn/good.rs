// Fixture: fan-out through the pool keeps join order deterministic.
pub fn fan_out(items: Vec<usize>) -> Vec<usize> {
    let pool = dartquant::util::threadpool::ThreadPool::new(4);
    pool.map(items, |x| x * 2)
}
