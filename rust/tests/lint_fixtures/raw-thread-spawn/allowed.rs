// Fixture: a raw thread justified per site.
pub fn watchdog() {
    // dqlint::allow(raw-thread-spawn): detached watchdog that never
    // joins into pipeline state, so pool containment buys nothing.
    std::thread::spawn(|| loop {
        std::hint::spin_loop();
    });
}
