// Fixture: a CLI readout clock, justified per site.
pub fn cli_readout() -> std::time::Duration {
    // dqlint::allow(wallclock-hygiene): CLI progress line only, never
    // reaches a canonical report.
    let t0 = std::time::Instant::now();
    t0.elapsed()
}
