// Fixture: no wall-clock reads; timing flows through the observer.
pub fn run_steps(n: usize) -> usize {
    (0..n).map(|i| i * i).sum()
}
