// Fixture: wall-clock read outside the allowlisted timing modules.
pub fn stamped_run() -> std::time::Duration {
    let t0 = std::time::Instant::now();
    t0.elapsed()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
