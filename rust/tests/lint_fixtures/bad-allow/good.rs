// Fixture: a well-formed allow (known lint + reason) is not an error,
// even when nothing on the line needs suppressing.
pub fn quiet(mags: &mut Vec<f32>) {
    // dqlint::allow(float-sort-determinism): documents a sweep tool.
    mags.sort_by(|a, b| a.total_cmp(b));
}
