// Fixture: malformed suppressions are themselves errors.
pub fn noisy(mags: &mut Vec<f32>) {
    // dqlint::allow(float-sort-determinism)
    mags.sort_by(|a, b| a.total_cmp(b));
    // dqlint::allow(not-a-real-lint): reason text
    mags.reverse();
}
