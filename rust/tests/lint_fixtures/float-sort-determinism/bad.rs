// Fixture: partial_cmp comparator — panics (or flips order) on NaN.
pub fn rank_channels(mags: &mut Vec<f32>) {
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

#[cfg(test)]
mod tests {
    // The same pattern inside a test module is exempt.
    fn helper(mags: &mut Vec<f32>) {
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
}
