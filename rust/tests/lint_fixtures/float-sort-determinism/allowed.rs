// Fixture: a justified partial_cmp survives with a reasoned allow.
pub fn top_k_jax_parity(logits: &[f32], idx: &mut Vec<usize>) {
    idx.sort_by(|&a, &b| {
        logits[b]
            // dqlint::allow(float-sort-determinism): jax top_k parity
            // needs -0.0 == +0.0 broken by index; NaN falls back below.
            .partial_cmp(&logits[a])
            .unwrap_or_else(|| logits[b].total_cmp(&logits[a]))
            .then(a.cmp(&b))
    });
}
