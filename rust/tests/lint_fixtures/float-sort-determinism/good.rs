// Fixture: total_cmp is the contract-conforming float comparator.
pub fn rank_channels(mags: &mut Vec<f32>) {
    mags.sort_by(|a, b| a.total_cmp(b));
}
