// Fixture: membership-only set use, justified per site.
pub fn dedup_count(xs: &[u64]) -> usize {
    // dqlint::allow(no-map-iteration): membership probe only, the set
    // is never iterated so its order cannot leak.
    let seen: std::collections::HashSet<u64> = xs.iter().copied().collect();
    seen.len()
}
