// Fixture: BTreeMap iterates in key order — deterministic reports.
use std::collections::BTreeMap;

pub fn tally(names: &[String]) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for n in names {
        *m.entry(n.clone()).or_insert(0) += 1;
    }
    m
}
