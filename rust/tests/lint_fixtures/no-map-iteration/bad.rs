// Fixture: HashMap in shipping code — iteration order leaks into logs.
use std::collections::HashMap;

pub fn tally(names: &[String]) -> HashMap<String, usize> {
    let mut m = HashMap::new();
    for n in names {
        *m.entry(n.clone()).or_insert(0) += 1;
    }
    m
}
