// Fixture: unsafe with the invariant stated next to it.
pub fn read_first(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty());
    // SAFETY: the assert above guarantees at least one element, so the
    // pointer read is in bounds.
    unsafe { *xs.as_ptr() }
}
