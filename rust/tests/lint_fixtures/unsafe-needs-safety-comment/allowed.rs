// Fixture: suppressing the lint (instead of a SAFETY comment) also works.
pub fn read_first(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty());
    // dqlint::allow(unsafe-needs-safety-comment): invariant documented
    // on the caller; the assert above keeps the read in bounds.
    unsafe { *xs.as_ptr() }
}
