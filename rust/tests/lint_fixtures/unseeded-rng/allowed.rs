// Fixture: an entropy source justified per site.
pub fn session_nonce() -> u64 {
    // dqlint::allow(unseeded-rng): nonce for a scratch file name only,
    // never feeds calibration or reports.
    let mut rng = thread_rng();
    rng.next_u64()
}
