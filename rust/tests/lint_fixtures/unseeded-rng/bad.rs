// Fixture: entropy-seeded randomness breaks bit-identical replay.
pub fn jitter() -> u64 {
    let mut rng = thread_rng();
    rng.next_u64()
}
