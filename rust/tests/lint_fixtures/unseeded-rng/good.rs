// Fixture: all randomness derives from the run seed.
pub fn jitter(seed: u64) -> u64 {
    let mut rng = dartquant::util::prng::Pcg64::new(seed ^ 0x1ee7);
    rng.next_u64()
}
