//! GEMM parity suite: the cache-blocked, register-tiled i8/i4 panel GEMM
//! (`tensor::gemm`) must be **bit-identical** to the retained scalar
//! integer kernel `matmul_transb_q_ref` — i32 accumulation is
//! associative, and the float epilogue is the same expression, so any
//! divergence is a packing or indexing bug, not rounding. On top of the
//! bit-identity bar, every product must sit within 1e-4 relative of the
//! dequantizing f32 oracle `matmul_transb_deq`, and the fallback routes
//! (fp/wide activation grids, grouped weight scales) must *equal* that
//! oracle bitwise.
//!
//! Also covers the [`QAct`] layer-boundary quantizer: its in-place
//! writeback is `fake_quant_rows` bitwise, its code recovery is
//! idempotent (exact), and feeding its codes to `matmul_transb_qact`
//! reproduces the per-call recovery path `matmul_transb_q` bit-for-bit.
//!
//! Runs natively (no artifacts needed).

use dartquant::tensor::{
    fake_quant_rows, matmul_transb, matmul_transb_deq, matmul_transb_q, matmul_transb_q_ref,
    matmul_transb_qact, matmul_transb_qact_with, quantize_act, Mat, QAct, QMat, QuantSpec,
};
use dartquant::util::propcheck::{gen, Runner};
use dartquant::util::prng::Pcg64;

fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
    let mut rng = Pcg64::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

/// A fake-quantized activation matrix on the `levels` grid.
fn act_mat(seed: u64, m: usize, k: usize, levels: f32) -> Mat {
    let mut x = rand_mat(seed, m, k);
    fake_quant_rows(&mut x, levels);
    x
}

/// The one assertion the suite is built on: tiled result bit-identical
/// to the scalar reference, and within 1e-4 relative of the dequantizing
/// f32 oracle.
fn assert_parity(x: &Mat, q: &QMat, a_levels: f32, label: &str) {
    let tiled = matmul_transb_q(x, q, a_levels);
    let reference = matmul_transb_q_ref(x, q, a_levels);
    assert_eq!(tiled.data, reference.data, "{label}: tiled != scalar reference");
    let oracle = matmul_transb(x, &q.dequantize());
    let d = tiled.max_abs_diff(&oracle);
    let tol = 1e-4 * oracle.max_abs().max(1.0);
    assert!(d <= tol, "{label}: |tiled - deq oracle| {d} > {tol}");
}

/// Blocking parameters of `tensor::gemm` (NR=8, MR=4, MC=64, KC=256):
/// the sweep crosses every one of them, plus the ragged remainders the
/// micro-kernel must special-case. Odd k exercises the i4 panels'
/// trailing-nibble half step.
const SHAPES: [(usize, usize, usize); 12] = [
    (1, 1, 1),      // degenerate minimum
    (3, 7, 5),      // everything below one tile
    (4, 8, 8),      // exactly one MR×NR tile, k below a nibble pair boundary test
    (5, 9, 17),     // one ragged row / odd k / partial third panel
    (6, 2, 3),      // k smaller than a nibble pair count edge
    (16, 33, 8),    // odd k crossing 32
    (63, 64, 9),    // m one short of MC
    (64, 255, 8),   // odd k one short of KC
    (65, 256, 10),  // m crosses MC, k exactly KC
    (70, 259, 19),  // ragged everything: MC+, KC+ odd, partial panel
    (9, 513, 24),   // k crosses 2×KC with an odd remainder
    (129, 31, 1),   // deep m sweep against a single output column
];

#[test]
fn tiled_gemm_is_bit_identical_to_reference_across_shape_sweep() {
    for (case, &(m, k, n)) in SHAPES.iter().enumerate() {
        let x = act_mat(100 + case as u64, m, k, 16.0);
        let w = rand_mat(200 + case as u64, n, k);
        for bits in [4u8, 8] {
            let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
            assert_parity(&x, &q, 16.0, &format!("({m},{k},{n}) {bits}b"));
        }
    }
}

#[test]
fn odd_k_exercises_the_i4_trailing_nibble() {
    // k = 1 and k = 3: every panel byte's high nibble is padding at the
    // tail; the half-step must read only the low nibble and never index
    // a non-existent activation column.
    for k in [1usize, 3, 255, 257] {
        let x = act_mat(300 + k as u64, 10, k, 16.0);
        let w = rand_mat(400 + k as u64, 12, k);
        let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
        assert_parity(&x, &q, 16.0, &format!("odd-k {k}"));
    }
}

#[test]
fn protected_columns_survive_the_panel_epilogue() {
    // QUIK mixed precision: the protected columns' f32 contribution is
    // added per output in the epilogue. Masks at the first, an interior
    // and the last column — including the odd-k last column whose i4
    // panel nibble is the padded half-byte.
    let (m, k, n) = (21, 67, 13);
    let x = act_mat(5, m, k, 16.0);
    let w = rand_mat(6, n, k);
    for protected in [vec![0usize], vec![0, 33, k - 1], vec![k - 1]] {
        let mut mask = vec![false; k];
        for &c in &protected {
            mask[c] = true;
        }
        for bits in [4u8, 8] {
            let q = QMat::quantize_protected(&w, QuantSpec::new(bits), &mask);
            assert_parity(&x, &q, 16.0, &format!("protected {protected:?} {bits}b"));
        }
    }
}

#[test]
fn constant_activation_rows_ride_in_the_offset_term() {
    // scale == 0 rows carry their value entirely in mn; their codes are
    // zero so the integer sum vanishes and the colsum term does the work.
    let k = 40;
    let mut x = Mat::from_fn(6, k, |i, j| match i {
        0 => 2.5,                       // constant positive
        1 => 0.0,                       // all zero
        2 => -1.25,                     // constant negative
        _ => ((i * k + j) as f32).sin(), // ordinary rows
    });
    fake_quant_rows(&mut x, 16.0);
    let w = rand_mat(7, 11, k);
    for bits in [4u8, 8] {
        let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
        assert_parity(&x, &q, 16.0, &format!("constant rows {bits}b"));
    }
}

#[test]
fn a8_grid_saturates_the_u8_code_range() {
    // 256 activation levels: codes span the full u8 range — the widest
    // grid the integer path accepts before falling back.
    let x = act_mat(8, 33, 96, 256.0);
    let w = rand_mat(9, 17, 96);
    let q = QMat::quantize_rtn(&w, QuantSpec::new(8));
    assert_parity(&x, &q, 256.0, "a8");
}

#[test]
fn fallback_routes_are_bit_exact_against_the_deq_oracle() {
    let x = rand_mat(10, 9, 64);
    let w = rand_mat(11, 14, 64);
    let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
    // fp / wide activation grids skip the integer path entirely.
    for a_levels in [1024.0f32, 65536.0] {
        assert_eq!(
            matmul_transb_q(&x, &q, a_levels).data,
            matmul_transb_deq(&x, &q).data,
            "a_levels {a_levels}"
        );
    }
    // Grouped weight scales always take the deq path — through both the
    // levels-based entry point and the explicit QAct one.
    let order: Vec<usize> = (0..64).rev().collect();
    let g = QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 32);
    let mut xq = x.clone();
    let qa = quantize_act(&mut xq, 16.0).unwrap();
    assert_eq!(matmul_transb_q(&xq, &g, 16.0).data, matmul_transb_deq(&xq, &g).data);
    assert_eq!(matmul_transb_qact(&xq, &qa, &g).data, matmul_transb_deq(&xq, &g).data);
}

#[test]
fn shared_qact_codes_reproduce_the_per_call_recovery() {
    // The layer-boundary path: quantize once, hand the codes to many
    // linears. Must be bit-identical to the per-call recovery path for
    // every scheme that takes the panel GEMM.
    let (m, k) = (26, 72);
    let mut x = rand_mat(12, m, k);
    let qa = quantize_act(&mut x, 16.0).unwrap();
    let mut mask = vec![false; k];
    mask[5] = true;
    let mats = [
        QMat::quantize_rtn(&rand_mat(13, 9, k), QuantSpec::new(4)),
        QMat::quantize_rtn(&rand_mat(14, 21, k), QuantSpec::new(8)),
        QMat::quantize_protected(&rand_mat(15, 12, k), QuantSpec::new(4), &mask),
    ];
    for q in &mats {
        assert_eq!(
            matmul_transb_qact(&x, &qa, q).data,
            matmul_transb_q(&x, q, 16.0).data,
            "{} {}b",
            q.scheme_label(),
            q.spec().bits()
        );
    }
}

#[test]
fn thread_count_never_changes_results() {
    // Panels partition the output columns; i32 accumulation is exact, so
    // any worker count must produce the same bits.
    let (m, k, n) = (70, 130, 29);
    let mut x = rand_mat(16, m, k);
    let qa = quantize_act(&mut x, 16.0).unwrap();
    let q = QMat::quantize_rtn(&rand_mat(17, n, k), QuantSpec::new(4));
    let serial = matmul_transb_qact_with(&x, &qa, &q, 1);
    for threads in [2usize, 4, 7] {
        assert_eq!(
            matmul_transb_qact_with(&x, &qa, &q, threads).data,
            serial.data,
            "{threads} threads"
        );
    }
}

#[test]
fn empty_activation_batch_yields_an_empty_product() {
    let x = Mat::zeros(0, 24);
    let qa = QAct::from_quantized(&x, 16.0);
    let q = QMat::quantize_rtn(&rand_mat(18, 5, 24), QuantSpec::new(4));
    let y = matmul_transb_qact(&x, &qa, &q);
    assert_eq!(y.shape(), (0, 5));
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_tiled_gemm_matches_reference_on_random_shapes() {
    Runner::new().cases(24).run("tiled GEMM == scalar reference", |rng| {
        let m = gen::size(rng, 1, 80);
        let k = gen::size(rng, 1, 300);
        let n = gen::size(rng, 1, 24);
        let bits = [4u8, 8][rng.below(2)];
        let levels = [4.0f32, 16.0, 256.0][rng.below(3)];
        let mut x = Mat::from_vec(m, k, gen::activations(rng, m * k));
        fake_quant_rows(&mut x, levels);
        let w = Mat::from_vec(n, k, gen::vec_f32(rng, n * k));
        let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
        let tiled = matmul_transb_q(&x, &q, levels);
        let reference = matmul_transb_q_ref(&x, &q, levels);
        if tiled.data != reference.data {
            return Err(format!("({m},{k},{n}) {bits}b a{levels}: bit mismatch"));
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_act_writeback_is_fake_quant_rows_bitwise() {
    Runner::new().cases(24).run("quantize_act writeback", |rng| {
        let m = gen::size(rng, 1, 12);
        let k = gen::size(rng, 1, 80);
        let levels = [4.0f32, 16.0, 256.0, 1024.0, 65536.0][rng.below(5)];
        let data = gen::activations(rng, m * k);
        let mut a = Mat::from_vec(m, k, data.clone());
        let mut b = Mat::from_vec(m, k, data);
        let qa = quantize_act(&mut a, levels);
        fake_quant_rows(&mut b, levels);
        if a.data != b.data {
            return Err(format!("({m},{k}) a{levels}: writeback diverged"));
        }
        if qa.is_some() != (levels <= 256.0) {
            return Err(format!("a{levels}: wrong integer-grid gate"));
        }
        Ok(())
    });
}

#[test]
fn prop_qact_recovery_is_idempotent_and_decode_is_bounded() {
    // Codes recovered from an already-quantized matrix are a fixed point
    // (exact, not tolerance), and decode lands within one float rounding
    // of the fake-quantized values.
    Runner::new().cases(24).run("QAct recovery idempotence", |rng| {
        let m = gen::size(rng, 1, 10);
        let k = gen::size(rng, 2, 64);
        let levels = [4.0f32, 16.0, 256.0][rng.below(3)];
        let mut x = Mat::from_vec(m, k, gen::activations(rng, m * k));
        let qa = match quantize_act(&mut x, levels) {
            Some(qa) => qa,
            None => return Err(format!("a{levels} must return codes")),
        };
        if QAct::from_quantized(&x, levels) != qa {
            return Err("re-recovery changed codes or grids".into());
        }
        let d = qa.decode().max_abs_diff(&x);
        let tol = 1e-5 * x.max_abs().max(1e-12);
        if d > tol {
            return Err(format!("decode drift {d} > {tol}"));
        }
        Ok(())
    });
}
