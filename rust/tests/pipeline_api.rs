//! Pipeline-API tests — registry round-trips, staged builder runs with
//! ordered events, out-of-tree strategy registration, and report JSON
//! round-trips. Everything here runs **without** artifacts: the builder's
//! `run_native` path uses native-capable strategies/quantizers only.

use dartquant::coordinator::{
    CalibrationPools, CollectingObserver, Method, MethodRegistry, MethodSpec, Pipeline,
    PipelineRecord, PipelineStats, RotationOutcome, RotationStrategy, RtnQuantizer, Stage,
    StageContext,
};
use dartquant::data::{Corpus, Dialect};
use dartquant::model::{BitSetting, ModelConfig, Weights};
use dartquant::rotation::RotationSet;
use dartquant::util::json::Json;
use dartquant::util::prng::Pcg64;
use std::sync::Arc;

fn tiny() -> (Weights, Corpus) {
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
    (w, corpus)
}

#[test]
fn registry_roundtrips_every_builtin_method() {
    let reg = MethodRegistry::builtin();
    assert_eq!(reg.names().len(), Method::ALL.len());
    for m in Method::ALL {
        // Display name resolves to its own spec…
        let spec = reg.resolve(m.name()).expect(m.name());
        assert_eq!(spec.name, m.name());
        // …and the legacy shim parses the spec name back to the variant.
        assert_eq!(Method::parse(&spec.name).unwrap(), m);
    }
    for alias in ["rtn", "smooth", "gptq", "omni", "quarot", "spin", "ost", "dart"] {
        assert!(reg.resolve(alias).is_ok(), "alias {alias} must resolve");
    }
    assert!(reg.resolve("awq").is_err());
}

#[test]
fn builder_emits_stage_events_in_order() {
    let (w, _corpus) = tiny();
    let obs = CollectingObserver::new();
    let report = Pipeline::builder(&w)
        .method("quarot")
        .unwrap()
        .bits(BitSetting::W4A4)
        .quantizer(Arc::new(RtnQuantizer))
        .observer(obs.clone())
        .run_native()
        .unwrap();
    assert_eq!(report.method, "QuaRot");
    assert_eq!(report.quantizer, "rtn");
    assert!(report.rotation.is_some(), "QuaRot must rotate");
    // Every stage starts and finishes, in pipeline order, exactly once.
    let want: Vec<(Stage, bool)> =
        Stage::ALL.iter().flat_map(|&s| [(s, false), (s, true)]).collect();
    assert_eq!(obs.stage_sequence(), want);
}

#[test]
fn smooth_method_runs_natively_through_builder() {
    let (w, _corpus) = tiny();
    let report = Pipeline::builder(&w)
        .method("smoothquant")
        .unwrap()
        .bits(BitSetting::W4A4)
        .run_native()
        .unwrap();
    assert_eq!(report.method, "SmoothQuant");
    assert_eq!(report.quantizer, "rtn"); // fixed by the spec
    assert!(report.rotation.is_none());
    assert_ne!(report.weights.get("l0.wq").data, w.get("l0.wq").data, "weights must quantize");
}

/// An out-of-tree rotation strategy: Haar-random orthogonal R1/R2. Lives
/// entirely in this test — registering it must be enough to run it
/// end-to-end, with zero coordinator edits.
struct HaarRotation;

impl RotationStrategy for HaarRotation {
    fn name(&self) -> &str {
        "haar-orthogonal"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> anyhow::Result<RotationOutcome> {
        let cfg = &ctx.weights.cfg;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0xaa7);
        Ok(RotationOutcome::some(RotationSet::random_orthogonal(
            cfg.dim,
            cfg.head_dim,
            cfg.n_layers,
            &mut rng,
        )))
    }
}

#[test]
fn custom_strategy_registers_and_runs_end_to_end() {
    let (w, _corpus) = tiny();
    let mut reg = MethodRegistry::builtin();
    reg.register(MethodSpec {
        name: "HaarQuant".into(),
        aliases: vec!["haar".into()],
        rotation: Arc::new(HaarRotation),
        quantizer: Some(Arc::new(RtnQuantizer)),
        smooth: false,
    });
    assert_eq!(reg.names().len(), Method::ALL.len() + 1);

    let obs = CollectingObserver::new();
    let report = Pipeline::builder(&w)
        .method_in(&reg, "haar")
        .unwrap()
        .bits(BitSetting::W4A4)
        .observer(obs.clone())
        .run_native()
        .unwrap();
    assert_eq!(report.method, "HaarQuant");
    let rot = report.rotation.as_ref().expect("custom strategy must rotate");
    assert!(rot.max_defect() < 1e-3, "rotation must stay orthogonal");
    assert_eq!(rot.r2.len(), w.cfg.n_layers);
    // All four stages ran for the custom method too.
    assert_eq!(obs.stage_sequence().len(), 2 * Stage::ALL.len());
}

#[test]
fn report_json_roundtrip_from_a_real_run() {
    let (w, _corpus) = tiny();
    let report = Pipeline::builder(&w)
        .method("rtn")
        .unwrap()
        .bits(BitSetting::W4A4)
        .run_native()
        .unwrap();
    let rec = report.record();
    let json = report.to_json().to_string();
    let back = PipelineRecord::from_json(&Json::parse(&json).unwrap()).unwrap();
    assert_eq!(back, rec);
    assert_eq!(back.method, "RTN");
    assert_eq!(back.dialect, Dialect::Wiki);
    assert!(!back.rotated);
    // Stats survive independently too.
    let stats = PipelineStats::from_json(&Json::parse(&rec.stats.to_json().to_string()).unwrap())
        .unwrap();
    assert_eq!(stats, rec.stats);
}

#[test]
fn explicit_axes_survive_method_in_any_order() {
    let (w, _corpus) = tiny();
    // Quantizer pinned BEFORE the method: resolution is by precedence
    // (explicit → method spec → config fallback), not call order, so the
    // spec must not clobber it — without the pin, "gptq"'s fallback would
    // pick the GPTQ quantizer from weight_quant.
    let report = Pipeline::builder(&w)
        .quantizer(Arc::new(RtnQuantizer))
        .method("gptq")
        .unwrap()
        .bits(BitSetting::W4A4)
        .run_native()
        .unwrap();
    assert_eq!(report.method, "GPTQ");
    assert_eq!(report.quantizer, "rtn");
}

#[test]
fn legacy_config_flows_through_the_builder() {
    use dartquant::coordinator::PipelineConfig;
    let (w, _corpus) = tiny();
    // run_pipeline itself needs a PJRT runtime; its exact construction —
    // `.config(cfg)` with every axis resolved from cfg.method — is what
    // this exercises natively.
    let mut cfg = PipelineConfig::new(Method::QuaRot, BitSetting::W4A4);
    cfg.weight_quant = dartquant::coordinator::WeightQuant::Rtn;
    cfg.calib_dialect = Dialect::Ptb;
    let report = Pipeline::builder(&w).config(cfg).run_native().unwrap();
    assert_eq!(report.method, "QuaRot");
    assert_eq!(report.quantizer, "rtn"); // honored weight_quant fallback
    assert_eq!(report.dialect, Dialect::Ptb);
    assert!(report.rotation.as_ref().unwrap().online_had);
}
