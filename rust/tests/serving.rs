//! Decode-parity suite: the KV-cached incremental path (prefill + step)
//! must reproduce the full-sequence oracle —
//!
//! * **bit-identical** in fp32 (same block body, same per-row ops),
//! * within 1e-4 relative NLL under activation/KV quantization and
//!   packed weights (in practice also bit-identical; the tolerance is
//!   the acceptance bar),
//! * token-for-token across batched sessions with staggered
//!   admit/retire, at any worker count, under a KV budget,
//! * **paged ≡ contiguous**: the paged KV backend (`serve::pager`)
//!   reproduces the contiguous engine's token streams and canonical
//!   event log at every page size and worker count, including under
//!   forced-eviction pressure with spilling enabled.
//!
//! Plus `util::propcheck` properties for the KV-cache quantizer and the
//! pager's gate accounting. Runs natively (no artifacts needed).

use dartquant::coordinator::MemoryGate;
use dartquant::model::{
    fake_quant_rows, forward_batch, forward_one, nll_from_logits, FwdOptions, ModelConfig,
    NoCapture, Weights,
};
use dartquant::serve::{
    BatchEngine, DecodeSession, EngineConfig, GenRequest, KvCache, PageLayout, PagedConfig, Pager,
};
use dartquant::tensor::Mat;
use dartquant::util::propcheck::{gen, Runner};
use std::sync::Arc;

mod common;
use common::{model, TABLE2_CONFIGS};

/// Per-position NLLs from a session fed `prefill_len` prompt tokens and
/// then stepped one token at a time — the incremental counterpart of
/// `forward_one`'s (T-1)-length NLL vector.
fn decode_nlls(w: &Arc<Weights>, toks: &[i32], prefill_len: usize, opt: FwdOptions) -> Vec<f32> {
    let mut sess = DecodeSession::new(Arc::clone(w), opt);
    let mut nll = Vec::with_capacity(toks.len() - 1);
    let logits = sess.prefill(&toks[..prefill_len]);
    for i in 0..prefill_len.min(toks.len() - 1) {
        nll.push(nll_from_logits(logits.row(i), toks[i + 1] as usize));
    }
    for p in prefill_len..toks.len() {
        let row = sess.step(toks[p]);
        if p + 1 < toks.len() {
            nll.push(nll_from_logits(&row, toks[p + 1] as usize));
        }
    }
    assert_eq!(sess.positions(), toks.len());
    nll
}

#[test]
fn fp32_decode_is_bit_identical_to_full_forward() {
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 1);
        let oracle = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        // Crossing the prefill/decode boundary at several points must not
        // change a single bit.
        for prefill_len in [1usize, 24, toks.len()] {
            let got = decode_nlls(&w, &toks, prefill_len, FwdOptions::FP);
            assert_eq!(got, oracle, "{name}: prefill {prefill_len}");
        }
        // And the batch entry point agrees with itself through decode.
        let batch = forward_batch(&w, &[toks.clone()], FwdOptions::FP);
        assert_eq!(batch[0], oracle, "{name}");
    }
}

#[test]
fn quantized_decode_matches_full_forward_within_tolerance() {
    // a_bits / kv_bits / online hadamard across the table2 configs. The
    // 4-bit KV settings exercise the cache's u8 code storage; use_had
    // exercises the online R3 on the cached K rows.
    let opts = [
        FwdOptions::quant(4, 4, false),
        FwdOptions::quant(4, 4, true),
        FwdOptions::quant(8, 8, false),
        FwdOptions::quant(16, 4, false),
    ];
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 2);
        for (oi, &opt) in opts.iter().enumerate() {
            let oracle = forward_one(&w, &toks, opt, &mut NoCapture);
            let got = decode_nlls(&w, &toks, 16, opt);
            assert_eq!(got.len(), oracle.len());
            for (a, b) in oracle.iter().zip(&got) {
                assert!(
                    (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                    "{name} opt[{oi}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn packed_decode_matches_packed_full_forward() {
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 3);
        let packed = Arc::new(dartquant::quant::rtn_quantize_model_packed(&w, 4));
        assert!(packed.has_packed());
        for opt in [FwdOptions::quant(4, 16, false), FwdOptions::quant(4, 4, false)] {
            let oracle = forward_one(&packed, &toks, opt, &mut NoCapture);
            let got = decode_nlls(&packed, &toks, 16, opt);
            for (a, b) in oracle.iter().zip(&got) {
                assert!(
                    (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                    "{name}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn w4a4_packed_greedy_decode_is_token_identical_to_full_forward() {
    // The QAct-threaded decode path (one activation quantization per
    // layer boundary inside each step) must pick the same greedy token
    // as a fresh full prefill of the whole prefix — chunk schedules
    // never change the argmax.
    let argmax = |row: &[f32]| {
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    };
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 21);
        let packed = Arc::new(dartquant::quant::rtn_quantize_model_packed(&w, 4));
        let opt = FwdOptions::quant(4, 4, false);
        let mut prefix = toks[..12].to_vec();
        let mut sess = DecodeSession::new(Arc::clone(&packed), opt);
        let logits = sess.prefill(&prefix);
        let mut next = argmax(logits.row(prefix.len() - 1));
        for _ in 0..8 {
            // Oracle: a fresh session prefills the whole extended prefix
            // in one shot (== the full forward, per chunked-prefill
            // equivalence) and must agree on the next token.
            let mut full = Vec::with_capacity(prefix.len() + 1);
            full.extend_from_slice(&prefix);
            full.push(next);
            let mut oracle = DecodeSession::new(Arc::clone(&packed), opt);
            let olog = oracle.prefill(&full);
            let want = argmax(olog.row(full.len() - 1));
            let row = sess.step(next);
            let got = argmax(&row);
            assert_eq!(got, want, "{name}: diverged at position {}", full.len());
            prefix = full;
            next = got;
        }
    }
}

#[test]
fn decode_parity_holds_on_moe_models() {
    let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
    let w = Arc::new(Weights::default_synthetic(&cfg, 5));
    let mut rng = dartquant::util::prng::Pcg64::new(6);
    let toks: Vec<i32> = (0..32).map(|_| rng.below(cfg.vocab) as i32).collect();
    let oracle = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
    assert_eq!(decode_nlls(&w, &toks, 8, FwdOptions::FP), oracle);
}

#[test]
fn chunked_prefill_is_equivalent_to_one_shot() {
    let (w, toks) = model("llama2-tiny", 4);
    let opt = FwdOptions::quant(8, 8, false);
    let mut one = DecodeSession::new(Arc::clone(&w), opt);
    let full = one.prefill(&toks[..32]);
    let mut chunked = DecodeSession::new(Arc::clone(&w), opt);
    chunked.prefill(&toks[..10]);
    chunked.prefill(&toks[10..25]);
    let tail = chunked.prefill(&toks[25..32]);
    // Chunk boundaries must not change the logits of the final chunk.
    for (i, row) in (25..32).zip(0..tail.rows) {
        assert_eq!(full.row(i), tail.row(row), "position {i}");
    }
    assert_eq!(one.cache_nbytes(), chunked.cache_nbytes());
}

/// Greedy-decode a single request in its own engine — the reference for
/// the batched/staggered runs.
fn solo_tokens(
    w: &Arc<Weights>,
    ecfg: EngineConfig,
    prompt: Vec<i32>,
    max_new: usize,
) -> Vec<i32> {
    let mut engine =
        BatchEngine::new(Arc::clone(w), EngineConfig { budget: None, workers: 1, ..ecfg });
    engine.submit(GenRequest { prompt, max_new });
    let r = engine.run().unwrap();
    assert!(r[0].error.is_none());
    r[0].tokens.clone()
}

#[test]
fn staggered_batched_sessions_match_single_sessions_token_for_token() {
    let (w, toks) = model("llama2-tiny", 7);
    let base =
        EngineConfig { opt: FwdOptions::quant(8, 8, false), seed: 11, ..Default::default() };
    // Session i holds estimate(11 + 4i) cache bytes (prompt 8+i plus
    // max_new 4+3i minus the never-cached final token); a 40-position
    // budget fits about two at a time, so admissions and retirements
    // stagger — late sessions prefill while earlier ones are mid-decode
    // — but never all four at once (Σ = 68 positions).
    let budget = KvCache::estimate_nbytes(&w.cfg, base.opt.kv_levels, 40, true) + 64;
    let requests: Vec<(Vec<i32>, usize)> = (0..4)
        .map(|i| (toks[i * 6..i * 6 + 8 + i].to_vec(), 4 + 3 * i))
        .collect();
    let mut engines = Vec::new();
    for workers in [1usize, 4] {
        let mut engine = BatchEngine::new(
            Arc::clone(&w),
            EngineConfig { budget: Some(budget), workers, ..base },
        );
        for (prompt, max_new) in &requests {
            engine.submit(GenRequest { prompt: prompt.clone(), max_new: *max_new });
        }
        let results = engine.run().unwrap().to_vec();
        assert_eq!(results.len(), requests.len());
        for (r, (prompt, max_new)) in results.iter().zip(&requests) {
            assert!(r.error.is_none(), "session {} failed: {:?}", r.id, r.error);
            assert_eq!(r.tokens.len(), *max_new);
            let solo = solo_tokens(&w, base, prompt.clone(), *max_new);
            assert_eq!(r.tokens, solo, "session {} diverged from solo decode", r.id);
        }
        // The budget actually staggered the batch, and was never exceeded.
        assert!(engine.peak_cache_bytes() <= budget);
        engines.push(engine);
    }
    // Determinism contract: identical event streams at 1 and 4 workers.
    assert_eq!(engines[0].events(), engines[1].events());
    assert_eq!(engines[0].results(), engines[1].results());
}

#[test]
fn over_budget_request_fails_while_others_complete() {
    let (w, toks) = model("llama2-tiny", 8);
    let opt = FwdOptions::FP;
    let small = KvCache::estimate_nbytes(&w.cfg, opt.kv_levels, 8 + 2, true);
    let mut engine = BatchEngine::new(
        Arc::clone(&w),
        EngineConfig { opt, budget: Some(small), workers: 2, ..Default::default() },
    );
    engine.submit(GenRequest { prompt: toks[..8].to_vec(), max_new: 2 });
    engine.submit(GenRequest { prompt: toks.clone(), max_new: 64 }); // can never fit
    let results = engine.run().unwrap().to_vec();
    assert!(results[0].error.is_none());
    assert_eq!(results[0].tokens.len(), 2);
    assert!(results[1].error.as_deref().unwrap().contains("memory budget"));
}

#[test]
fn paged_decode_is_bit_identical_to_contiguous_at_every_page_size() {
    // Page layout must be invisible: same tokens and the same canonical
    // event log as the contiguous oracle at page sizes spanning
    // one-position pages (maximal table churn) to pages larger than any
    // session (single-page degenerate case), at 1 and 4 workers.
    let (w, toks) = model("llama2-tiny", 31);
    let base = EngineConfig { opt: FwdOptions::quant(4, 4, false), seed: 5, ..Default::default() };
    let requests: Vec<(Vec<i32>, usize)> =
        (0..4).map(|i| (toks[i * 5..i * 5 + 6 + i].to_vec(), 3 + 2 * i)).collect();
    let run = |paged: Option<PagedConfig>, workers: usize| {
        let mut engine =
            BatchEngine::new(Arc::clone(&w), EngineConfig { workers, paged, ..base });
        for (prompt, max_new) in &requests {
            engine.submit(GenRequest { prompt: prompt.clone(), max_new: *max_new });
        }
        engine.run().unwrap();
        engine
    };
    let oracle = run(None, 1);
    for page_positions in [1usize, 16, 64] {
        let paged = Some(PagedConfig { page_positions, spill: false });
        let one = run(paged, 1);
        let four = run(paged, 4);
        for engine in [&one, &four] {
            assert_eq!(engine.results(), oracle.results(), "P={page_positions}");
            assert_eq!(
                engine.canonical_events(),
                oracle.canonical_events(),
                "P={page_positions}"
            );
        }
        // Within a mode the raw event stream is worker-count invariant.
        assert_eq!(one.events(), four.events(), "P={page_positions}");
        assert_eq!(
            one.pager().unwrap().charged_bytes(),
            0,
            "run over: every page released"
        );
    }
}

#[test]
fn paged_decode_under_eviction_pressure_matches_the_unbounded_oracle() {
    // Budget = one session's maximum working set: four sessions force
    // the pager to spill cold pages to disk and fault them back
    // mid-decode, and the tokens must still match a contiguous engine
    // with no budget at all. llama3-small adds GQA page geometry.
    for name in TABLE2_CONFIGS {
        let (w, toks) = model(name, 33);
        let opt = FwdOptions::quant(4, 4, false);
        let base = EngineConfig { opt, seed: 13, ..Default::default() };
        let requests: Vec<(Vec<i32>, usize)> =
            (0..4).map(|i| (toks[i * 7..i * 7 + 10 + i].to_vec(), 6)).collect();
        let lay = PageLayout::for_model(&w.cfg, opt.kv_levels, 4);
        let budget = requests
            .iter()
            .map(|(p, m)| lay.session_max_bytes(p.len() + m - 1))
            .max()
            .unwrap();
        let mut oracle = BatchEngine::new(Arc::clone(&w), base);
        for (prompt, max_new) in &requests {
            oracle.submit(GenRequest { prompt: prompt.clone(), max_new: *max_new });
        }
        oracle.run().unwrap();
        for workers in [1usize, 4] {
            let mut engine = BatchEngine::new(
                Arc::clone(&w),
                EngineConfig {
                    workers,
                    budget: Some(budget),
                    paged: Some(PagedConfig { page_positions: 4, spill: true }),
                    ..base
                },
            );
            for (prompt, max_new) in &requests {
                engine.submit(GenRequest { prompt: prompt.clone(), max_new: *max_new });
            }
            engine.run().unwrap();
            assert_eq!(engine.results(), oracle.results(), "{name} workers={workers}");
            assert_eq!(
                engine.canonical_events(),
                oracle.canonical_events(),
                "{name} workers={workers}"
            );
            let stats = engine.pager_stats().unwrap();
            assert!(stats.spilled_pages > 0, "{name}: the budget never forced an eviction");
            assert!(stats.faulted_pages > 0, "{name}: no spilled page was ever read back");
            assert!(
                engine.peak_cache_bytes() <= budget,
                "{name}: eviction failed to keep the gate under budget"
            );
        }
    }
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_kv_quantizer_roundtrip_error_is_bounded() {
    Runner::new().cases(32).run("kv fake-quant roundtrip bound", |rng| {
        let n = gen::size(rng, 2, 96);
        let levels = [4.0f32, 16.0, 256.0][rng.below(3)];
        let row = gen::vec_f32(rng, n);
        let mut q = Mat::from_vec(1, n, row.clone());
        fake_quant_rows(&mut q, levels);
        let (mn, mx) = row.iter().fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        let half_step = (mx - mn) / (levels - 1.0) / 2.0;
        let tol = half_step + 1e-6 * (mx - mn).abs().max(1.0);
        for (a, b) in row.iter().zip(&q.data) {
            if (a - b).abs() > tol {
                return Err(format!("roundtrip error {} > {tol}", (a - b).abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fake_quant_is_idempotent() {
    // Quantizing an already-quantized row is a no-op up to one float
    // rounding of the re-derived grid (≤ ~1e-6 of the row range).
    Runner::new().cases(32).run("fake-quant idempotence", |rng| {
        let n = gen::size(rng, 2, 64);
        let levels = [4.0f32, 16.0, 256.0][rng.below(3)];
        let mut once = Mat::from_vec(1, n, gen::vec_f32(rng, n));
        fake_quant_rows(&mut once, levels);
        let mut twice = once.clone();
        fake_quant_rows(&mut twice, levels);
        let range = once.max_abs().max(1e-12);
        let d = once.max_abs_diff(&twice);
        if d <= 1e-5 * range {
            Ok(())
        } else {
            Err(format!("second pass moved values by {d} (range {range})"))
        }
    });
}

#[test]
fn prop_session_cache_bytes_match_engine_accounting() {
    // The bytes a session actually holds equal the estimate the engine
    // charges the budget gate for, at every prefix length and bit mix.
    let (w, toks) = model("llama2-tiny", 9);
    Runner::new().cases(16).run("session cache accounting", |rng| {
        let len = gen::size(rng, 1, toks.len());
        let kv_bits = [4u8, 8, 16][rng.below(3)];
        let opt = FwdOptions::quant(16, kv_bits, false);
        let mut sess = DecodeSession::new(Arc::clone(&w), opt);
        sess.prefill(&toks[..len]);
        let want = KvCache::estimate_nbytes(&w.cfg, opt.kv_levels, len, true);
        if sess.cache_nbytes() != want {
            return Err(format!("cache {} != estimate {want}", sess.cache_nbytes()));
        }
        Ok(())
    });
}

#[test]
fn prop_paged_kv_cache_bytes_equal_the_gate_charge() {
    // A paged `KvCache` reports exactly what the pager charged the gate
    // (one session shares nothing, so mapped == unique), which is the
    // layout's maximum working set for its target — and releasing the
    // cache returns the charge to zero. The shared-pages-count-once side
    // of the ledger is pinned by `rust/tests/pager.rs`.
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    Runner::new().cases(16).run("paged cache gate accounting", |rng| {
        let page_positions = [1usize, 3, 8][rng.below(3)];
        let len = gen::size(rng, 1, 24);
        let pager = Arc::new(Pager::new(
            &cfg,
            16.0,
            page_positions,
            false,
            Arc::new(MemoryGate::new(None)),
        ));
        let sid = match pager.admit(&vec![1; len], len) {
            Ok(Some(sid)) => sid,
            other => return Err(format!("admit: {other:?}")),
        };
        let kv = KvCache::paged(&pager, sid);
        if kv.nbytes() != 0 {
            return Err("pages mapped before prepare_step".into());
        }
        match pager.prepare_step(sid, len, &[sid]) {
            Ok(true) => {}
            other => return Err(format!("prepare_step: {other:?}")),
        }
        if kv.nbytes() != pager.charged_bytes() {
            return Err(format!(
                "cache reports {} but the gate holds {}",
                kv.nbytes(),
                pager.charged_bytes()
            ));
        }
        if kv.nbytes() != pager.layout().session_max_bytes(len) {
            return Err(format!("cache {} != max working set", kv.nbytes()));
        }
        drop(kv);
        if pager.charged_bytes() != 0 {
            return Err("cache dropped but pages still charged".into());
        }
        Ok(())
    });
}
