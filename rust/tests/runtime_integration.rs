//! Integration tests across the AOT boundary: rust runtime executing the
//! jax/pallas-lowered artifacts and checking numerics against the native
//! rust implementations.
//!
//! These tests skip (pass vacuously, with a note) when `artifacts/` has not
//! been built yet — run `make artifacts` first for full coverage.

use dartquant::linalg;
use dartquant::runtime::{Runtime, Value};
use dartquant::tensor::Mat;
use dartquant::util::prng::Pcg64;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(Runtime::default_dir()).expect("open runtime"))
}

fn rand_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn whip_kernel_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg64::new(1);
    let x = rand_mat(&mut rng, 256, 256);
    let out = rt.run("k_whip", &[Value::from_mat(&x)]).expect("run k_whip");
    let got = out[0].to_scalar().unwrap();
    // native: mean over rows of sum exp(-|x|)
    let want: f32 = (0..x.rows)
        .map(|i| x.row(i).iter().map(|v| (-v.abs()).exp()).sum::<f32>())
        .sum::<f32>()
        / x.rows as f32;
    assert!(
        (got - want).abs() < 1e-2 * want.max(1.0),
        "whip {got} vs {want}"
    );
}

#[test]
fn rotate_kernel_matches_native_matmul() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg64::new(2);
    let x = rand_mat(&mut rng, 256, 256);
    let r = linalg::random_orthogonal(256, &mut rng);
    let out = rt
        .run("k_rotate", &[Value::from_mat(&x), Value::from_mat(&r)])
        .expect("run k_rotate");
    let got = out[0].to_mat().unwrap();
    let want = dartquant::tensor::matmul(&x, &r);
    let d = got.max_abs_diff(&want);
    assert!(d < 1e-3, "rotate mismatch {d}");
}

#[test]
fn fwht_kernel_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg64::new(3);
    let x = rand_mat(&mut rng, 128, 256);
    let out = rt.run("k_fwht", &[Value::from_mat(&x)]).expect("run k_fwht");
    let got = out[0].to_mat().unwrap();
    let mut want = x.clone();
    linalg::fwht_rows(&mut want);
    let d = got.max_abs_diff(&want);
    assert!(d < 1e-3, "fwht mismatch {d}");
}

#[test]
fn quant_kernel_is_idempotent_and_bounded() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg64::new(4);
    let x = rand_mat(&mut rng, 128, 256);
    let out = rt
        .run("k_quant", &[Value::from_mat(&x), Value::scalar(16.0)])
        .expect("run k_quant");
    let y = out[0].to_mat().unwrap();
    // Quantizing the quantized output must be a fixed point.
    let out2 = rt
        .run("k_quant", &[Value::from_mat(&y), Value::scalar(16.0)])
        .expect("requant");
    let y2 = out2[0].to_mat().unwrap();
    assert!(y.max_abs_diff(&y2) < 1e-4, "not idempotent");
    // Error bounded by step/2 per row.
    for i in 0..x.rows {
        let row = x.row(i);
        let (mn, mx) = row
            .iter()
            .fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        let step = (mx - mn) / 15.0;
        for (a, b) in row.iter().zip(y.row(i)) {
            assert!((a - b).abs() <= step / 2.0 + 1e-4);
        }
    }
}

#[test]
fn qr_kernel_matches_rust_householder() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg64::new(5);
    let z = rand_mat(&mut rng, 64, 64);
    let out = rt.run("k_qr_q", &[Value::from_mat(&z)]).expect("run k_qr_q");
    let got = out[0].to_mat().unwrap();
    let want = linalg::qr_orthogonalize(&z);
    let d = got.max_abs_diff(&want);
    // Same sign canonicalization on both sides => directly comparable.
    assert!(d < 5e-3, "QR convention mismatch between jax and rust: {d}");
    assert!(linalg::orthogonality_defect(&got) < 1e-3);
}

#[test]
fn manifest_lists_expected_artifact_families() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    for family in ["calib_whip_sgd_n256", "cayley_whip_sgd_n256", "k_whip"] {
        assert!(m.get(family).is_some(), "missing {family}");
    }
    assert!(!m.find_by_meta(&[("kind", "qr_orth")]).is_empty());
}
