//! Within-layer sharding integration: `--shards N` must never move a
//! bit. Column-parallel linears and per-kv-head attention decompose the
//! forward; GPTQ/OmniQuant per-layer jobs decompose into per-shard
//! row-range sub-jobs under the same per-job-seed + replayed-event
//! determinism contract as workers (docs/CONCURRENCY.md) —
//!
//! * canonical pipeline reports, packed weight bytes, and greedy decode
//!   token streams are byte-identical across shards ∈ {1, 2, 4, 7} ×
//!   workers ∈ {1, 4} on both table2 configs,
//! * the gate charges per-shard working sets: sharded GPTQ/OmniQuant
//!   peak job bytes sit strictly below the unsharded largest-layer
//!   checkout.
//!
//! Runs natively (no artifacts needed).

use dartquant::coordinator::{Pipeline, PipelineReport};
use dartquant::model::{forward_one, BitSetting, FwdOptions, ModelConfig, NoCapture, Weights};
use dartquant::serve::{sample_logits, BatchEngine, DecodeSession, EngineConfig, GenRequest};
use dartquant::util::prng::Pcg64;
use std::sync::Arc;

mod common;
use common::{grammar, TABLE2_CONFIGS};

/// The gate: every count must reproduce shards=1 bit-for-bit, including
/// 7 (doesn't divide any head count or row count evenly).
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

/// One quantization pipeline run at (method, shards, workers); packed
/// storage so weight bytes compare the true low-bit footprint.
fn run(w: &Weights, method: &str, shards: usize, workers: usize) -> PipelineReport {
    Pipeline::builder(w)
        .method(method)
        .unwrap()
        .bits(BitSetting::W4A4)
        .packed(true)
        .shards(shards)
        .workers(workers)
        .configure(|c| c.calib_sequences = 2)
        .run_native()
        .unwrap()
}

#[test]
fn sharded_forward_is_bit_identical() {
    // The pure forward path (column-parallel linears + per-kv-head
    // attention) at fp and quantized settings, per table2 config.
    for name in TABLE2_CONFIGS {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (w, corpus) = grammar(&cfg);
        let toks = corpus.sequence(48, 2, 0);
        for base in [FwdOptions::FP, FwdOptions::quant(4, 4, false), FwdOptions::quant(8, 16, true)]
        {
            let oracle = forward_one(&w, &toks, base, &mut NoCapture);
            for shards in SHARD_COUNTS {
                let got = forward_one(&w, &toks, base.with_shards(shards), &mut NoCapture);
                assert_eq!(got, oracle, "{name}: shards {shards} moved a bit");
            }
        }
    }
}

#[test]
fn sharded_quantize_reports_and_weights_are_byte_identical() {
    for name in TABLE2_CONFIGS {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (w, _corpus) = grammar(&cfg);
        for method in ["gptq", "omniquant"] {
            let baseline = run(&w, method, 1, 1);
            let canon = baseline.record().canonical().to_json().to_string();
            for shards in SHARD_COUNTS {
                for workers in [1usize, 4] {
                    let r = run(&w, method, shards, workers);
                    assert_eq!(
                        r.record().canonical().to_json().to_string(),
                        canon,
                        "{name}/{method}: canonical report differs at shards {shards} workers {workers}"
                    );
                    assert!(r.weights.has_packed(), "{name}/{method}");
                    for n in w.names() {
                        assert_eq!(
                            r.weights.tensor(n).to_mat().data,
                            baseline.weights.tensor(n).to_mat().data,
                            "{name}/{method}: tensor {n} differs at shards {shards} workers {workers}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_decode_token_streams_are_byte_identical() {
    // Greedy decode through both serving entry points, on the packed
    // W4A4 weights each shard count produced (so the whole
    // quantize → serve chain is covered, not just the forward).
    for name in TABLE2_CONFIGS {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (w, corpus) = grammar(&cfg);
        let mut oracle: Option<Vec<Vec<i32>>> = None;
        for shards in SHARD_COUNTS {
            let weights = Arc::new(run(&w, "gptq", shards, 2).weights);
            let opt = FwdOptions::quant(4, 4, false).with_shards(shards);

            // Single-session decode.
            let prompt = corpus.sequence(16, 2, 0);
            let mut sess = DecodeSession::new(Arc::clone(&weights), opt);
            let last = sess.prefill_last(&prompt);
            let mut tok = sample_logits(&last, 0.0, &mut Pcg64::new(0)) as i32;
            let mut single = vec![tok];
            for _ in 1..12 {
                let row = sess.step(tok);
                tok = sample_logits(&row, 0.0, &mut Pcg64::new(0)) as i32;
                single.push(tok);
            }

            // Continuous batching, staggered prompt lengths.
            let ecfg = EngineConfig { opt, ..EngineConfig::default() };
            let mut engine = BatchEngine::new(Arc::clone(&weights), ecfg);
            for i in 0..4u64 {
                engine.submit(GenRequest {
                    prompt: corpus.sequence(8 + 4 * i as usize, 2, i),
                    max_new: 10,
                });
            }
            let mut results = engine.run().unwrap().to_vec();
            results.sort_by_key(|r| r.id);
            let mut streams: Vec<Vec<i32>> = results
                .into_iter()
                .map(|r| {
                    assert!(r.error.is_none(), "{name}: shards {shards} session failed");
                    r.tokens
                })
                .collect();
            streams.push(single);

            match &oracle {
                None => oracle = Some(streams),
                Some(o) => assert_eq!(&streams, o, "{name}: streams differ at shards {shards}"),
            }
        }
    }
}

#[test]
fn sharded_calibration_charges_per_shard_working_sets() {
    // workers=1 makes peak_job_bytes the single largest checkout; at
    // shards=4 every sub-job charges ~1/4 of a layer's rows, so the peak
    // must drop strictly below the unsharded largest-layer charge.
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let (w, _corpus) = grammar(&cfg);
    for method in ["gptq", "omniquant"] {
        let whole = run(&w, method, 1, 1).stats.peak_job_bytes;
        let sharded = run(&w, method, 4, 1).stats.peak_job_bytes;
        assert!(whole > 0, "{method}: unsharded run charged nothing");
        assert!(
            sharded < whole,
            "{method}: sharded peak {sharded} not below unsharded largest-layer checkout {whole}"
        );
    }
}
