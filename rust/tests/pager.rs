//! Paged-KV pager suite (`serve::pager`), public API only:
//!
//! * page-table geometry and byte accounting are exact (mapped pages,
//!   gate charge, and `PagedKv::nbytes` reconcile — shared pages count
//!   once against the gate),
//! * copy-on-write prefix sharing maps registered prompt pages
//!   read-only and bit-identically,
//! * eviction spills cold pages to the temp file and faults them back
//!   **bit-identical** under the `MemoryGate` lease discipline,
//! * no-spill mode defers admission instead of ever needing eviction,
//! * a `util::propcheck` property pins the reconciliation across page
//!   sizes, prompt lengths, and sharing degrees.
//!
//! Engine-level gates (paged decode ≡ contiguous decode) live in
//! `rust/tests/serving.rs`; this file drives the pager directly.

use dartquant::serve::{PageLayout, PagedKv};
use dartquant::tensor::Mat;
use dartquant::util::propcheck::{gen, Runner};

mod common;
use common::{k_head, prefill_rows, tiny_cfg, tiny_pager, KV_LEVELS};

#[test]
fn layout_math_is_page_granular() {
    let cfg = tiny_cfg();
    for p in [1usize, 16, 64] {
        let lay = PageLayout::for_model(&cfg, KV_LEVELS, p);
        assert!(lay.page_bytes() > 0);
        assert_eq!(lay.pages_for(0), 0);
        assert_eq!(lay.pages_for(1), 1);
        assert_eq!(lay.pages_for(p), 1);
        assert_eq!(lay.pages_for(p + 1), 2);
        for positions in [1usize, p, 3 * p - 1, 3 * p] {
            assert_eq!(
                lay.session_max_bytes(positions),
                lay.pages_for(positions) as u64 * lay.n_layers as u64 * lay.page_bytes(),
                "P={p} positions={positions}"
            );
        }
    }
}

#[test]
fn prefix_pages_are_shared_charged_once_and_read_bit_identically() {
    // P=4, prompt 9 = 2 full pages + 1: admission shares exactly the
    // full pages, the suffix stays private.
    let pager = tiny_pager(4, false, None);
    let prompt: Vec<i32> = (0..9).collect();
    let (nl, hd) = (pager.layout().n_layers, pager.layout().hd);
    let pb = pager.layout().page_bytes();

    let a = pager.admit(&prompt, prompt.len()).unwrap().unwrap();
    assert_eq!(pager.shared_positions(a), 0, "empty index: nothing to share");
    let mut kv_a = PagedKv::new(&pager, a);
    prefill_rows(&pager, &mut kv_a, 9, 0.0);
    pager.register_prefix(a, &prompt);

    let b = pager.admit(&prompt, prompt.len()).unwrap().unwrap();
    assert_eq!(pager.shared_positions(b), 8, "two full pages inherited");
    let mut kv_b = PagedKv::new(&pager, b);
    prefill_rows(&pager, &mut kv_b, 9, 100.0); // only position 8 is written

    // Accounting: A maps 3 pages/layer, B maps the 2 shared + 1 private,
    // the gate sees 4 unique pages/layer.
    assert_eq!(kv_a.nbytes(), 3 * nl as u64 * pb);
    assert_eq!(kv_b.nbytes(), 3 * nl as u64 * pb);
    assert_eq!(pager.charged_bytes(), 4 * nl as u64 * pb, "shared pages charged once");
    let stats = pager.stats();
    assert_eq!(stats.prefix_pages_hit, 2);
    assert_eq!(stats.cow_forks, 0, "append-only decode never forks");

    // Shared positions read back bit-identical through B; the private
    // suffix position differs (different write seed).
    for l in 0..nl {
        let ka = k_head(&mut kv_a, l, 0, hd);
        let kb = k_head(&mut kv_b, l, 0, hd);
        for pos in 0..8 {
            assert_eq!(ka.row(pos), kb.row(pos), "layer {l} shared position {pos}");
        }
        assert_ne!(ka.row(8), kb.row(8), "layer {l} private suffix");
    }

    // A's release keeps the shared pages alive for B; B's frees the rest.
    drop(kv_a);
    assert_eq!(pager.charged_bytes(), 3 * nl as u64 * pb);
    drop(kv_b);
    assert_eq!(pager.charged_bytes(), 0);
    assert_eq!(pager.resident_pages(), 0);
}

#[test]
fn spill_and_fault_back_are_bit_identical() {
    // Budget = exactly one session's working set: preparing the second
    // session must evict the first's pages to the spill file, and
    // re-preparing the first must fault them back unchanged.
    let cfg = tiny_cfg();
    let lay = PageLayout::for_model(&cfg, KV_LEVELS, 2);
    let budget = lay.session_max_bytes(4);
    let pager = tiny_pager(2, true, Some(budget));
    let (nl, nkv, hd) = (lay.n_layers, lay.nkv, lay.hd);
    let session_pages = (lay.pages_for(4) * nl) as u64;

    let a = pager.admit(&[1, 2, 3, 4], 4).unwrap().unwrap();
    let mut kv_a = PagedKv::new(&pager, a);
    prefill_rows(&pager, &mut kv_a, 4, 0.0);
    let snapshot: Vec<Mat> = (0..nl)
        .flat_map(|l| (0..nkv).map(move |h| (l, h)))
        .map(|(l, h)| k_head(&mut kv_a, l, h, hd))
        .collect();

    let b = pager.admit(&[9, 8, 7, 6], 4).unwrap().unwrap();
    let mut kv_b = PagedKv::new(&pager, b);
    prefill_rows(&pager, &mut kv_b, 4, 50.0);
    assert_eq!(
        pager.stats().spilled_pages,
        session_pages,
        "B's working set displaced every one of A's pages"
    );
    assert!(pager.charged_bytes() <= budget, "eviction kept the gate under budget");

    // Fault A back (0 new positions — pure residency restore) and
    // verify every row survived the disk round trip bit-for-bit.
    assert!(pager.prepare_step(a, 0, &[a]).unwrap());
    assert_eq!(pager.stats().faulted_pages, session_pages);
    for (i, (l, h)) in
        (0..nl).flat_map(|l| (0..nkv).map(move |h| (l, h))).enumerate()
    {
        let back = k_head(&mut kv_a, l, h, hd);
        assert_eq!(back.data, snapshot[i].data, "layer {l} head {h} changed across spill");
    }
    assert!(pager.charged_bytes() <= budget);
}

#[test]
fn admission_rejects_sessions_that_can_never_fit() {
    let cfg = tiny_cfg();
    let lay = PageLayout::for_model(&cfg, KV_LEVELS, 2);
    let pager = tiny_pager(2, true, Some(lay.session_max_bytes(4) - 1));
    let err = pager.admit(&[1, 2, 3, 4], 4).unwrap_err();
    assert_eq!(err.need, lay.session_max_bytes(4));
    assert_eq!(err.budget, lay.session_max_bytes(4) - 1);
}

#[test]
fn no_spill_mode_defers_admission_instead_of_evicting() {
    // Commitment accounting: with spill off, a second session waits
    // (Ok(None)) while the first holds the budget, and admits cleanly
    // once it releases — page charges can then never fail mid-flight.
    let cfg = tiny_cfg();
    let lay = PageLayout::for_model(&cfg, KV_LEVELS, 2);
    let budget = lay.session_max_bytes(4);
    let pager = tiny_pager(2, false, Some(budget));

    let a = pager.admit(&[1, 2, 3, 4], 4).unwrap().unwrap();
    let mut kv_a = PagedKv::new(&pager, a);
    prefill_rows(&pager, &mut kv_a, 4, 0.0);
    assert_eq!(pager.admit(&[9, 8, 7, 6], 4).unwrap(), None, "no headroom: wait");
    drop(kv_a);
    assert!(pager.admit(&[9, 8, 7, 6], 4).unwrap().is_some(), "release freed the budget");
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_session_bytes_reconcile_with_the_gate_charge() {
    // Σ PagedKv::nbytes() == gate charge + one page_bytes per shared
    // mapping (prefix_pages_hit × n_layers), at every page size, prompt
    // length, and sharing degree — and the gate charge is exactly
    // page_bytes × unique resident pages.
    Runner::new().cases(12).run("paged bytes reconcile with the gate", |rng| {
        let p = [1usize, 2, 4, 8][rng.below(4)];
        let len = gen::size(rng, 2.max(p), 4 * p + 1);
        let n = 1 + rng.below(3); // 1..=3 sessions over one prompt
        let pager = tiny_pager(p, false, None);
        let prompt: Vec<i32> = (0..len as i32).map(|i| i + 7).collect();
        let mut kvs = Vec::new();
        for s in 0..n {
            let sid = match pager.admit(&prompt, len) {
                Ok(Some(sid)) => sid,
                other => return Err(format!("admit: {other:?}")),
            };
            let mut kv = PagedKv::new(&pager, sid);
            prefill_rows(&pager, &mut kv, len, s as f32);
            if s == 0 {
                pager.register_prefix(sid, &prompt);
            }
            kvs.push(kv);
        }
        let lay = pager.layout();
        let (pb, nl) = (lay.page_bytes(), lay.n_layers as u64);
        let shared_k = ((len - 1) / p) as u64; // full pages short of the prompt end
        let stats = pager.stats();
        if stats.prefix_pages_hit != (n as u64 - 1) * shared_k {
            return Err(format!(
                "hits {} != {} sessions × {shared_k} full pages",
                stats.prefix_pages_hit,
                n - 1
            ));
        }
        let mapped: u64 = kvs.iter().map(|kv| kv.nbytes()).sum();
        let want = pager.charged_bytes() + stats.prefix_pages_hit * nl * pb;
        if mapped != want {
            return Err(format!("Σ nbytes {mapped} != charged + shared-once {want}"));
        }
        if pager.charged_bytes() != pager.resident_pages() as u64 * pb {
            return Err("gate charge is not page_bytes × resident pages".into());
        }
        drop(kvs);
        if pager.charged_bytes() != 0 {
            return Err("sessions released but pages still charged".into());
        }
        Ok(())
    });
}
