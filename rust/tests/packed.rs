//! Packed-storage pipeline integration: `--packed` runs must (a) shrink
//! 4-bit weight residency to ≤ 1/6 of f32 bytes, (b) dequantize
//! bit-identically to the dense fake-quant pipeline, and (c) evaluate
//! through the native integer forward to the same perplexity as the
//! dense fake-quant forward (within 1e-4 relative — the integer path's
//! only divergence from the oracle is f32 reassociation).
//!
//! Runs natively (no artifacts needed).

use dartquant::coordinator::Pipeline;
use dartquant::eval::{ppl_native, EvalSpec};
use dartquant::model::{BitSetting, FwdOptions, ModelConfig};

mod common;
use common::{grammar, TABLE2_CONFIGS};

#[test]
fn packed_pipeline_shrinks_weights_and_matches_dense_ppl() {
    for name in TABLE2_CONFIGS {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (w, corpus) = grammar(&cfg);
        let dense = Pipeline::builder(&w)
            .method("rtn")
            .unwrap()
            .bits(BitSetting::W4A4)
            .run_native()
            .unwrap();
        let packed = Pipeline::builder(&w)
            .method("rtn")
            .unwrap()
            .bits(BitSetting::W4A4)
            .packed(true)
            .run_native()
            .unwrap();
        assert!(!dense.weights.has_packed());
        assert!(packed.weights.has_packed());

        // (a) true weight residency: 4-bit codes + scales ≤ 1/6 of f32.
        assert!(
            packed.compression_ratio() >= 6.0,
            "{name}: linear compression {:.2}x < 6x",
            packed.compression_ratio()
        );
        assert!(packed.model_bytes < dense.model_bytes, "{name}");
        assert_eq!(dense.compression_ratio(), 1.0, "{name}: dense output is f32");

        // (b) the packed representation dequantizes bit-identically to
        // the dense fake-quant output.
        for n in w.names() {
            assert_eq!(
                packed.weights.tensor(n).to_mat().data,
                dense.weights.tensor(n).to_mat().data,
                "{name}: {n}"
            );
        }

        // (c) quantized-forward perplexity through the integer path
        // matches the dense fake-quant forward within 1e-4.
        let spec = EvalSpec { batch: 2, seq: 64, n_batches: 1 };
        let opt = FwdOptions::quant(4, 16, false);
        let ppl_dense = ppl_native(&dense.weights, &corpus, spec, opt);
        let ppl_packed = ppl_native(&packed.weights, &corpus, spec, opt);
        assert!(
            (ppl_dense - ppl_packed).abs() <= 1e-4 * ppl_dense,
            "{name}: dense ppl {ppl_dense} vs packed ppl {ppl_packed}"
        );
        // And with fp activations both forwards are bit-exact (the deq
        // kernel is the dense oracle), so the PPLs are equal.
        let fp_dense = ppl_native(&dense.weights, &corpus, spec, FwdOptions::FP);
        let fp_packed = ppl_native(&packed.weights, &corpus, spec, FwdOptions::FP);
        assert_eq!(fp_dense, fp_packed, "{name}");
    }
}

#[test]
fn true_w4a4_native_eval_matches_dense_fake_quant_oracle() {
    // The full W4A4 gate: 4-bit packed weights AND 4-bit activations
    // (plus a 4-bit KV cache) through the tiled integer GEMM, against
    // the dense fake-quant f32 forward. The integer path's only
    // divergence from the oracle is f32 reassociation in the epilogue,
    // so perplexity must agree to 1e-4 relative on the table2 configs.
    for name in TABLE2_CONFIGS {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (w, corpus) = grammar(&cfg);
        let mk = |packed: bool| {
            Pipeline::builder(&w)
                .method("rtn")
                .unwrap()
                .bits(BitSetting::W4A4)
                .packed(packed)
                .run_native()
                .unwrap()
        };
        let dense = mk(false);
        let packed = mk(true);
        let spec = EvalSpec { batch: 2, seq: 64, n_batches: 1 };
        for opt in [FwdOptions::quant(4, 4, false), FwdOptions::quant(4, 16, false)] {
            let ppl_dense = ppl_native(&dense.weights, &corpus, spec, opt);
            let ppl_packed = ppl_native(&packed.weights, &corpus, spec, opt);
            assert!(
                (ppl_dense - ppl_packed).abs() <= 1e-4 * ppl_dense,
                "{name} a{}: dense ppl {ppl_dense} vs packed ppl {ppl_packed}",
                opt.a_levels
            );
        }
    }
}

#[test]
fn packed_gptq_pipeline_matches_dense_and_shrinks() {
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let (w, _corpus) = grammar(&cfg);
    let mk = |packed: bool| {
        Pipeline::builder(&w)
            .method("gptq")
            .unwrap()
            .bits(BitSetting::W4A4)
            .packed(packed)
            .configure(|c| c.calib_sequences = 2)
            .run_native()
            .unwrap()
    };
    let dense = mk(false);
    let packed = mk(true);
    assert!(packed.weights.has_packed());
    assert!(packed.compression_ratio() >= 6.0);
    for n in w.names() {
        assert_eq!(
            packed.weights.tensor(n).to_mat().data,
            dense.weights.tensor(n).to_mat().data,
            "{n}"
        );
    }
}

#[test]
fn packed_report_row_serializes_byte_accounting() {
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let (w, _corpus) = grammar(&cfg);
    let report = Pipeline::builder(&w)
        .method("rtn")
        .unwrap()
        .bits(BitSetting::W4A4)
        .packed(true)
        .run_native()
        .unwrap();
    let json = report.to_json().to_string();
    let parsed = dartquant::util::json::Json::parse(&json).unwrap();
    assert_eq!(parsed.get_f64("model_bytes").unwrap() as u64, report.model_bytes);
    let ratio = parsed.get_f64("compression_ratio").unwrap();
    assert!(ratio >= 6.0, "serialized ratio {ratio}");
    // The canonical row keeps the (deterministic) byte accounting.
    let canon = report.record().canonical();
    assert_eq!(canon.model_bytes, report.model_bytes);
}

#[test]
fn packed_is_a_no_op_at_fp_widths() {
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let (w, _corpus) = grammar(&cfg);
    let report = Pipeline::builder(&w)
        .method("rtn")
        .unwrap()
        .bits(BitSetting::FP)
        .packed(true)
        .run_native()
        .unwrap();
    // W16 skips quantization entirely; nothing to pack.
    assert!(!report.weights.has_packed());
    assert_eq!(report.compression_ratio(), 1.0);
}
