//! Integration tests for `dqlint`: every lint fires on its bad fixture,
//! stays quiet on the good fixture, suppresses through a reasoned allow
//! directive, and — the gate that matters — the real tree is clean.

use dartquant::lint::{self, Diagnostic, Lint, Severity};
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(lint_dir: &str, which: &str) -> Vec<Diagnostic> {
    let path = repo_root()
        .join("rust/tests/lint_fixtures")
        .join(lint_dir)
        .join(format!("{which}.rs"));
    lint::scan_file(&path).unwrap_or_else(|e| panic!("reading fixture {path:?}: {e}"))
}

/// The seven suppressible lints with their fixture directories.
const CASES: [Lint; 7] = Lint::ALL;

#[test]
fn every_lint_fires_on_its_bad_fixture() {
    for lint in CASES {
        let diags = fixture(lint.name(), "bad");
        assert!(!diags.is_empty(), "{}: bad fixture produced no diagnostics", lint.name());
        for d in &diags {
            assert_eq!(d.lint, lint, "{}: unexpected cross-fire: {d}", lint.name());
            assert_eq!(d.severity, Severity::Error);
            assert!(d.line > 0, "lines are 1-based: {d}");
            assert!(!d.message.is_empty());
        }
    }
}

#[test]
fn every_lint_passes_its_good_fixture() {
    for lint in CASES {
        let diags = fixture(lint.name(), "good");
        assert!(
            diags.is_empty(),
            "{}: good fixture should be clean, got: {:?}",
            lint.name(),
            diags
        );
    }
}

#[test]
fn every_lint_suppresses_through_a_reasoned_allow() {
    for lint in CASES {
        let diags = fixture(lint.name(), "allowed");
        assert!(
            diags.is_empty(),
            "{}: reasoned allow should suppress, got: {:?}",
            lint.name(),
            diags
        );
    }
}

#[test]
fn cfg_test_code_is_exempt_in_fixtures() {
    // The float fixture plants the same violation in a #[cfg(test)]
    // module; only the shipping-code copy may fire.
    let diags = fixture("float-sort-determinism", "bad");
    assert_eq!(diags.len(), 1, "test-module copy must not fire: {diags:?}");
}

#[test]
fn bad_allow_directives_are_errors() {
    let diags = fixture("bad-allow", "bad");
    assert_eq!(diags.len(), 2, "bare + unknown-lint allows: {diags:?}");
    for d in &diags {
        assert_eq!(d.lint, Lint::BadAllow);
        assert_eq!(d.severity, Severity::Error);
    }
    assert!(diags[0].message.contains("without a reason"), "{}", diags[0].message);
    assert!(diags[1].message.contains("unknown lint"), "{}", diags[1].message);

    let clean = fixture("bad-allow", "good");
    assert!(clean.is_empty(), "well-formed allow is not an error: {clean:?}");
}

#[test]
fn seeded_violation_fails_a_scan() {
    // What `ci.sh` relies on: reintroducing a partial_cmp comparator
    // into any scanned file turns the scan red.
    let seeded = "pub fn f(xs: &mut Vec<f32>) {\n    \
                  xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let diags = lint::scan_source("rust/src/seeded.rs", seeded);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].lint, Lint::FloatSortDeterminism);
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    assert!(errors > 0, "the error count is what gates the exit code");
}

#[test]
fn json_report_roundtrips_through_util_json() {
    let diags = fixture("wallclock-hygiene", "bad");
    let report = lint::report_json(&diags, 1).to_string();
    let parsed = dartquant::util::json::Json::parse(&report).expect("valid JSON");
    assert_eq!(parsed.get_usize("count"), Some(diags.len()));
    assert_eq!(parsed.get_usize("errors"), Some(diags.len()));
    assert_eq!(parsed.get_usize("files_scanned"), Some(1));
    let arr = parsed.get("diagnostics").unwrap().as_arr().unwrap();
    assert_eq!(arr.len(), diags.len());
    assert_eq!(arr[0].get_str("lint"), Some("wallclock-hygiene"));
    assert_eq!(arr[0].get_str("severity"), Some("error"));
}

#[test]
fn the_real_tree_is_clean() {
    // The tier-1 gate: rust/src/** and rust/benches/** carry zero
    // diagnostics — every suppression in the tree has a reason.
    let roots: Vec<PathBuf> =
        lint::DEFAULT_ROOTS.iter().map(|r| repo_root().join(r)).collect();
    for root in &roots {
        assert!(Path::new(root).is_dir(), "missing scan root {root:?}");
    }
    let (diags, files) = lint::scan_paths(&roots).expect("scan the tree");
    assert!(files > 40, "expected the whole tree, scanned only {files} files");
    assert!(
        diags.is_empty(),
        "the tree must be dqlint-clean, found {}:\n{}",
        diags.len(),
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}
