//! Scheduler integration tests — the determinism contract end-to-end,
//! without artifacts:
//!
//! * workers=1 and workers=4 produce byte-identical canonical report
//!   JSON, identical weights/rotations, and identical event streams
//!   (ordered delivery + per-job seeding),
//! * a panicking job fails the run with the job's id and label in the
//!   error chain instead of deadlocking the join,
//! * the memory budget bounds jobs in flight at any worker count.
//!
//! A scheduler-driven out-of-tree strategy stands in for DartQuant's
//! artifact-backed jobs (`DartCalibrated` shares the same `Scheduler`
//! path); the OmniQuant method exercises the quantize-stage fan-out.

use dartquant::coordinator::{
    CalibJob, CalibrationPools, CollectingObserver, MethodRegistry, MethodSpec, Pipeline,
    PipelineEvent, PipelineReport, RotationOutcome, RotationStrategy, RtnQuantizer, Scheduler,
    StageContext,
};
use dartquant::data::{Corpus, Dialect};
use dartquant::linalg;
use dartquant::model::{BitSetting, ModelConfig, Weights};
use dartquant::rotation::RotationSet;
use dartquant::util::prng::Pcg64;
use std::sync::Arc;

fn tiny() -> Weights {
    let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap()
}

/// Render an event stream without its run-varying fields (durations), so
/// serial and parallel streams can be compared exactly.
fn summarize(events: &[PipelineEvent]) -> Vec<String> {
    events
        .iter()
        .map(|e| match e {
            PipelineEvent::StageStarted { stage } => format!("stage+{}", stage.name()),
            PipelineEvent::StageFinished { stage, .. } => format!("stage-{}", stage.name()),
            PipelineEvent::JobStarted { job, label } => format!("job+{job}:{label}"),
            PipelineEvent::JobAdmitted { job, bytes } => format!("admit:{job}:{bytes}"),
            PipelineEvent::LossTick { job, step, loss } => format!("loss:{job}:{step}:{loss}"),
            PipelineEvent::JobFinished { job, ok, .. } => format!("job-{job}:{ok}"),
        })
        .collect()
}

/// A scheduler-driven rotation strategy: R1 (job 0) + one R2 job per
/// layer (job l + 1), each drawing randomness only from its per-job seed
/// — the same decomposition `DartCalibrated` uses for its artifact jobs,
/// runnable without artifacts.
struct ShardedHadamard {
    job_bytes: u64,
}

impl RotationStrategy for ShardedHadamard {
    fn name(&self) -> &str {
        "sharded-hadamard"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> anyhow::Result<RotationOutcome> {
        let cfg = ctx.weights.cfg.clone();
        let base_seed = ctx.cfg.seed;
        let jobs: Vec<CalibJob<usize>> = (0..cfg.n_layers + 1)
            .map(|id| {
                let label =
                    if id == 0 { "r1".to_string() } else { format!("r2[{}]", id - 1) };
                let dim = if id == 0 { cfg.dim } else { cfg.head_dim };
                CalibJob::new(id, label, self.job_bytes, dim)
            })
            .collect();
        let results = Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, sink| {
                let mut rng = Pcg64::new(job.seed(base_seed));
                let rot = linalg::randomized_hadamard(job.payload, &mut rng);
                for step in 0..3 {
                    sink.emit(PipelineEvent::LossTick {
                        job: job.id,
                        step,
                        loss: ((job.id + 1) * (step + 1)) as f32,
                    });
                }
                Ok(rot)
            },
        )?;
        let mut results = results.into_iter();
        let r1 = results.next().expect("scheduler returns R1 first");
        let loss_curves = (0..cfg.n_layers + 1)
            .map(|id| (1..=3).map(|s| ((id + 1) * s) as f32).collect())
            .collect();
        Ok(RotationOutcome {
            rotation: Some(RotationSet { r1, r2: results.collect(), online_had: true }),
            loss_curves,
        })
    }
}

fn sharded_registry(job_bytes: u64) -> MethodRegistry {
    let mut reg = MethodRegistry::builtin();
    reg.register(MethodSpec {
        name: "ShardedQuant".into(),
        aliases: vec!["sharded".into()],
        rotation: Arc::new(ShardedHadamard { job_bytes }),
        quantizer: Some(Arc::new(RtnQuantizer)),
        smooth: false,
    });
    reg
}

fn run_sharded(w: &Weights, workers: usize, budget: Option<u64>) -> (PipelineReport, Vec<String>) {
    let obs = CollectingObserver::new();
    let report = Pipeline::builder(w)
        .method_in(&sharded_registry(1000), "sharded")
        .unwrap()
        .bits(BitSetting::W4A4)
        .budget(budget)
        .workers(workers)
        .observer(obs.clone())
        .run_native()
        .unwrap();
    (report, summarize(&obs.events()))
}

fn assert_same_weights(a: &Weights, b: &Weights) {
    for n in a.names() {
        assert_eq!(a.get(n).data, b.get(n).data, "weight {n} diverged");
    }
}

#[test]
fn reports_are_byte_identical_across_worker_counts() {
    let w = tiny();
    let (serial, serial_events) = run_sharded(&w, 1, None);
    let (parallel, parallel_events) = run_sharded(&w, 4, None);

    // Byte-identical canonical report JSON (loss curves included).
    assert_eq!(
        serial.record().canonical().to_json().to_string(),
        parallel.record().canonical().to_json().to_string()
    );
    assert!(!serial.stats.loss_curves.is_empty());

    // Bit-identical rotations and quantized weights.
    let (ra, rb) = (serial.rotation.as_ref().unwrap(), parallel.rotation.as_ref().unwrap());
    assert_eq!(ra.r1.data, rb.r1.data);
    assert_eq!(ra.r2.len(), rb.r2.len());
    for (a, b) in ra.r2.iter().zip(&rb.r2) {
        assert_eq!(a.data, b.data);
    }
    assert_same_weights(&serial.weights, &parallel.weights);

    // Identical event streams: ordered delivery makes worker count
    // unobservable (modulo durations, stripped by summarize()).
    assert_eq!(serial_events, parallel_events);
}

#[test]
fn events_arrive_in_job_order_even_when_parallel() {
    let w = tiny();
    let n_layers = w.cfg.n_layers;
    let obs = CollectingObserver::new();
    Pipeline::builder(&w)
        .method_in(&sharded_registry(1000), "sharded")
        .unwrap()
        .bits(BitSetting::W4A4)
        .workers(4)
        .observer(obs.clone())
        .run_native()
        .unwrap();
    let want: Vec<(usize, bool)> =
        (0..n_layers + 1).flat_map(|id| [(id, false), (id, true)]).collect();
    assert_eq!(obs.job_sequence(), want);
}

#[test]
fn omniquant_quantize_stage_is_deterministic_across_worker_counts() {
    let w = tiny();
    let run = |workers: usize| {
        Pipeline::builder(&w)
            .method("omniquant")
            .unwrap()
            .bits(BitSetting::W4A4)
            .workers(workers)
            .run_native()
            .unwrap()
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.quantizer, "omniquant");
    assert_eq!(
        serial.record().canonical().to_json().to_string(),
        parallel.record().canonical().to_json().to_string()
    );
    assert_same_weights(&serial.weights, &parallel.weights);
    // The parallel run actually quantized something.
    assert_ne!(parallel.weights.get("l0.wq").data, w.get("l0.wq").data);
}

/// A strategy whose third scheduler job panics.
struct Sabotaged;

impl RotationStrategy for Sabotaged {
    fn name(&self) -> &str {
        "sabotaged"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> anyhow::Result<RotationOutcome> {
        let jobs: Vec<CalibJob<()>> =
            (0..4).map(|id| CalibJob::new(id, format!("r2[{id}]"), 0, ())).collect();
        Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, _sink| {
                if job.id == 2 {
                    panic!("sabotaged optimizer step");
                }
                Ok(())
            },
        )?;
        Ok(RotationOutcome::none())
    }
}

#[test]
fn panicking_job_fails_the_run_with_context() {
    let w = tiny();
    let mut reg = MethodRegistry::builtin();
    reg.register(MethodSpec {
        name: "Sabotaged".into(),
        aliases: vec![],
        rotation: Arc::new(Sabotaged),
        quantizer: Some(Arc::new(RtnQuantizer)),
        smooth: false,
    });
    let err = Pipeline::builder(&w)
        .method_in(&reg, "sabotaged")
        .unwrap()
        .bits(BitSetting::W4A4)
        .workers(4)
        .run_native()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("job 2 (r2[2])"), "error must name the job, got: {msg}");
    assert!(msg.contains("sabotaged optimizer step"), "error must carry the panic, got: {msg}");
}

#[test]
fn budget_bounds_jobs_in_flight_at_any_worker_count() {
    let w = tiny();
    // Budget fits one 1000-byte job but never two: with 4 workers the
    // gate must serialize admissions, and peak accounting must agree.
    let (report, _) = run_sharded(&w, 4, Some(1500));
    assert_eq!(report.stats.peak_job_bytes, 1000);

    // A job bigger than the whole budget is rejected with its label.
    let err = Pipeline::builder(&w)
        .method_in(&sharded_registry(99_999), "sharded")
        .unwrap()
        .bits(BitSetting::W4A4)
        .budget(Some(1500))
        .workers(4)
        .run_native()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("(r1)") || msg.contains("(r2["), "got: {msg}");
    assert!(msg.contains("memory budget"), "got: {msg}");
}
