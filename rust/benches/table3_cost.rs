//! Table 3 + Figure 1: rotation-optimization cost — wall time and memory
//! for SpinQuant-sim / OSTQuant-sim (end-to-end Cayley) vs DartQuant
//! (local QR-Orth calibration), across the llama2 size ladder, plus the
//! memory-budgeted "3090 mode" rows. Peak memory is reported both as the
//! coordinator's logical job bytes (the GPU-memory model) and process RSS.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, spin_job_bytes, Method, PipelineConfig};
use dartquant::model::ModelConfig;
use dartquant::util::bench::{fnum, Table};
use dartquant::util::mem::{gib, peak_rss_bytes};

fn main() {
    let rt = common::runtime();
    let models = ["llama2-tiny", "llama2-small", "llama2-large"];
    let mut table = Table::new(&[
        "Model", "Method", "calib time (s)", "job bytes (MiB)", "RSS (GiB)", "status",
    ]);
    let mut dart_times = Vec::new();
    let mut spin_times = Vec::new();

    for name in models {
        let cfg = ModelConfig::builtin(name).unwrap();
        let (weights, _corpus) = common::grammar_model(&cfg);
        for (method, steps) in [(Method::SpinQuant, 8), (Method::OstQuant, 8), (Method::DartQuant, 40)] {
            let mut pcfg = PipelineConfig::new(method, dartquant::model::BitSetting::W4A4);
            pcfg.workers = common::workers();
            pcfg.weight_quant = dartquant::coordinator::WeightQuant::Rtn; // isolate calib cost
            pcfg.calib_sequences = 16;
            pcfg.calib.steps = steps;
            pcfg.spin.steps = steps;
            match run_pipeline(&rt, &weights, &pcfg) {
                Ok(report) => {
                    let t = report.stats.calibrate_time.as_secs_f64();
                    if method == Method::DartQuant {
                        dart_times.push(t);
                    } else if method == Method::SpinQuant {
                        spin_times.push(t);
                    }
                    table.row(&[
                        name.into(),
                        method.name().into(),
                        fnum(t, 2),
                        fnum(report.stats.peak_job_bytes as f64 / (1 << 20) as f64, 1),
                        fnum(gib(peak_rss_bytes()), 2),
                        "ok".into(),
                    ]);
                }
                Err(e) => table.row(&[
                    name.into(),
                    method.name().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("{e}"),
                ]),
            }
        }
        // 3090-mode rows: budget admits DartQuant, rejects e2e fine-tuning.
        for method in [Method::SpinQuant, Method::DartQuant] {
            let mut pcfg = PipelineConfig::new(method, dartquant::model::BitSetting::W4A4);
            pcfg.workers = common::workers();
            pcfg.weight_quant = dartquant::coordinator::WeightQuant::Rtn;
            pcfg.calib_sequences = 16;
            pcfg.calib.steps = 40;
            pcfg.spin.steps = 8;
            pcfg.memory_budget = Some(24 << 20);
            let label = format!("{}₍₃₀₉₀₎", method.name());
            match run_pipeline(&rt, &weights, &pcfg) {
                Ok(report) => table.row(&[
                    name.into(),
                    label,
                    fnum(report.stats.calibrate_time.as_secs_f64(), 2),
                    fnum(report.stats.peak_job_bytes as f64 / (1 << 20) as f64, 1),
                    fnum(gib(peak_rss_bytes()), 2),
                    "ok (fits 24 MiB scaled budget)".into(),
                ]),
                Err(e) => table.row(&[
                    name.into(),
                    label,
                    "-".into(),
                    fnum(spin_job_bytes(&cfg) as f64 / (1 << 20) as f64, 1),
                    "-".into(),
                    format!("REJECTED: {e}").chars().take(70).collect(),
                ]),
            }
        }
    }
    table.print("Table 3 / Fig 1 — rotation optimization cost");
    if !dart_times.is_empty() && !spin_times.is_empty() {
        let speedup = spin_times.last().unwrap() / dart_times.last().unwrap();
        println!(
            "\nlargest-model calibration speedup (SpinQuant-sim / DartQuant): {:.1}×",
            speedup
        );
        println!("paper reports 47× at 70B with 10× memory savings; the shape to match is\n'DartQuant much cheaper, gap grows with model size, e2e rejected at 24GiB'.");
    }
}
