//! Figure 3 (and appendix Figure 10): number of outliers and mean
//! quantization error of captured activations under different transforms —
//! none / random orthogonal / random Hadamard / whip-calibrated (DartQuant)
//! — per model.

#[path = "common.rs"]
mod common;

use dartquant::calib::{calibrate_rotation, CalibConfig};
use dartquant::coordinator::capture_pools_native;
use dartquant::eval::stats;
use dartquant::linalg;
use dartquant::tensor::matmul;
use dartquant::util::bench::{fnum, Table};
use dartquant::util::prng::Pcg64;

fn main() {
    let rt = common::runtime();
    for cfg in common::bench_models() {
        let (weights, corpus) = common::grammar_model(&cfg);
        // 1000-activation sample from the mid layer (paper: layer 20).
        let seqs = corpus.calib_sequences(4, 256);
        let pools = capture_pools_native(&weights, &seqs, 0.25, 3);
        let mut rng = Pcg64::new(4);
        let pool = dartquant::calib::sample_tokens(&pools.r1_pool, 1000, &mut rng);

        let tau = stats::outlier_threshold(&pool, 0.995);
        let mut table = Table::new(&["Transform", "#outliers (|x|>τ)", "quant error (4-bit)"]);
        let report = |name: &str, x: &dartquant::tensor::Mat, table: &mut Table| {
            table.row(&[
                name.into(),
                format!("{}", stats::count_outliers(x, tau)),
                fnum(stats::quant_error(x, 4), 5),
            ]);
        };
        report("none", &pool, &mut table);
        let q = linalg::random_orthogonal(cfg.dim, &mut rng);
        report("random orthogonal", &matmul(&pool, &q), &mut table);
        let h = linalg::randomized_hadamard(cfg.dim, &mut rng);
        report("random Hadamard (QuaRot)", &matmul(&pool, &h), &mut table);
        let res = calibrate_rotation(
            &rt,
            &pools.r1_pool,
            &CalibConfig { steps: if common::full() { 60 } else { 30 }, ..Default::default() },
        )
        .expect("calibrate");
        report("DartQuant (whip)", &matmul(&pool, &res.rotation), &mut table);
        table.print(&format!(
            "Fig 3 — outliers & quant error on 1000 activations ({}, τ=99.5%)",
            cfg.name
        ));
    }
}
