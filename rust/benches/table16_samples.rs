//! Table 16 (Appendix D): sample-size sensitivity — DartQuant calibrated
//! with {8, 16, 32, 64} sequences (10% token sampling), PPL per dialect.
//! Paper shape: rows are flat — calibration is robust to tiny sample sets.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let cfg = dartquant::model::ModelConfig::builtin("llama2-tiny").unwrap();
    let (weights, _c) = common::grammar_model(&cfg);
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    let sizes: &[usize] = if common::full() { &[8, 16, 32, 64] } else { &[8, 32] };
    let mut table = Table::new(&["#sequences", "Wiki", "PTB", "C4", "Avg"]);
    for &n in sizes {
        let mut pcfg = PipelineConfig::new(Method::DartQuant, BitSetting::W4A4);
        pcfg.workers = common::workers();
        pcfg.calib_sequences = n;
        pcfg.calib.steps = if common::full() { 60 } else { 30 };
        let report = run_pipeline(&rt, &weights, &pcfg).expect("pipeline");
        let mut row = vec![format!("{n}")];
        let mut total = 0.0;
        for d in Dialect::ALL {
            let corpus = Corpus::new(d, cfg.vocab, 7);
            let ppl = eval::ppl_artifact(
                &rt,
                &report.weights,
                &corpus,
                spec,
                BitSetting::levels(4),
                65536.0,
                true,
            )
            .unwrap();
            total += ppl;
            row.push(fnum(ppl, 2));
        }
        row.push(fnum(total / 3.0, 2));
        table.row(&row);
    }
    table.print("Table 16 — DartQuant sample-size sensitivity (llama2-tiny, W4A4, 10% tokens)");
}
