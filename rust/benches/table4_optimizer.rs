//! Table 4: wall time of 100 optimizer iterations — Cayley vs QR-Orth,
//! SGD and Adam — plus the convergence-derived effective speedup (paper:
//! 1.4× per-iteration, 41× overall when matching loss levels).

#[path = "common.rs"]
mod common;

use dartquant::calib::{calibrate_rotation, CalibConfig, OptKind, OrthScheme};
use dartquant::tensor::Mat;
use dartquant::util::bench::{fnum, Table};
use dartquant::util::prng::Pcg64;

fn pool(n: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::from_fn(2048, n, |_, _| rng.laplace(1.0));
    for &c in &rng.sample_indices(n, n / 32) {
        for i in 0..m.rows {
            *m.at_mut(i, c) *= 12.0;
        }
    }
    m
}

fn main() {
    let rt = common::runtime();
    let iters = if common::full() { 100 } else { 40 };
    let n = 256;
    let p = pool(n, 1);
    let mut table = Table::new(&["Optimizer", "Scheme", &format!("{iters} iters (s)"), "per-iter (ms)", "final loss"]);

    let mut times = std::collections::BTreeMap::new();
    for (opt, scheme) in [
        (OptKind::Sgd, OrthScheme::Cayley),
        (OptKind::Sgd, OrthScheme::QrOrth),
        (OptKind::Adam, OrthScheme::Cayley),
        (OptKind::Adam, OrthScheme::QrOrth),
    ] {
        let cfg = CalibConfig { optimizer: opt, scheme, steps: iters, ..Default::default() };
        let res = calibrate_rotation(&rt, &p, &cfg).expect("calibrate");
        let secs = res.wall.as_secs_f64();
        times.insert((opt.name(), format!("{scheme:?}")), secs);
        table.row(&[
            opt.name().to_uppercase(),
            format!("{scheme:?}"),
            fnum(secs, 2),
            fnum(secs * 1000.0 / iters as f64, 1),
            fnum(*res.losses.last().unwrap() as f64, 3),
        ]);
    }
    table.print(&format!("Table 4 — time for {iters} iterations (n={n})"));
    let s = times[&("sgd", "Cayley".to_string())] / times[&("sgd", "QrOrth".to_string())];
    let a = times[&("adam", "Cayley".to_string())] / times[&("adam", "QrOrth".to_string())];
    println!("\nper-iteration speedup  SGD: {:.2}×   Adam: {:.2}×   (paper: 1.44× / 1.42×)", s, a);

    // Effective speedup: steps Cayley-SGD needs to reach QR-SGD's loss
    // after `probe` steps (paper: 6 vs 100 ⇒ 41×).
    let probe = 6;
    let qr = calibrate_rotation(
        &rt,
        &p,
        &CalibConfig { steps: probe, ..Default::default() },
    )
    .unwrap();
    let target = *qr.losses.last().unwrap();
    let cay = calibrate_rotation(
        &rt,
        &p,
        &CalibConfig { scheme: OrthScheme::Cayley, steps: iters, ..Default::default() },
    )
    .unwrap();
    let reached = cay.losses.iter().position(|&l| l <= target);
    match reached {
        Some(k) => println!(
            "QR-SGD loss after {probe} steps ({target:.3}) reached by Cayley-SGD at step {k} \
             ⇒ effective speedup ≈ {:.1}× (× the {s:.2}× per-iter factor)",
            (k + 1) as f64 / probe as f64
        ),
        None => println!(
            "Cayley-SGD did not reach QR-SGD's {probe}-step loss ({target:.3}) within {iters} \
             steps — effective speedup > {:.0}× (paper: 41×)",
            iters as f64 / probe as f64
        ),
    }
}
