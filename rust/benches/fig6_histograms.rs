//! Figures 2 / 6 / 11: activation-distribution histograms under different
//! rotations, rendered as ASCII. The paper's shape: the raw distribution
//! has a sharp Laplace peak with extreme outliers; Hadamard compresses the
//! range; the whip-calibrated rotation is the most uniform.

#[path = "common.rs"]
mod common;

use dartquant::calib::{calibrate_rotation, CalibConfig, Objective};
use dartquant::coordinator::capture_pools_native;
use dartquant::eval::stats;
use dartquant::linalg;
use dartquant::tensor::{matmul, Mat};
use dartquant::util::bench::fnum;
use dartquant::util::prng::Pcg64;

fn show(name: &str, x: &Mat) {
    let s = stats::activation_stats(x);
    println!(
        "\n--- {name}:  range ±{:.2}  var {:.3}  kurtosis {:.1} ---",
        s.max_abs, s.variance, s.kurtosis
    );
    let lim = (s.max_abs as f32).max(1e-3);
    let h = stats::histogram(x, -lim, lim, 21);
    print!("{}", stats::render_histogram(&h, -lim, lim, 48));
}

fn main() {
    let rt = common::runtime();
    let cfg = dartquant::model::ModelConfig::builtin("llama2-tiny").unwrap();
    let (weights, corpus) = common::grammar_model(&cfg);
    let seqs = corpus.calib_sequences(4, 256);
    let pools = capture_pools_native(&weights, &seqs, 0.25, 3);
    let mut rng = Pcg64::new(4);
    let pool = dartquant::calib::sample_tokens(&pools.r1_pool, 1000, &mut rng);

    show("(a) original (no rotation)", &pool);
    let h = linalg::randomized_hadamard(cfg.dim, &mut rng);
    show("(b) random Hadamard", &matmul(&pool, &h));
    for (label, obj) in [
        ("(c) quant-loss objective", Objective::Quant),
        ("(d) variance objective", Objective::Variance),
        ("(e) kurtosis objective", Objective::Kurtosis),
        ("(f) Whip objective (DartQuant)", Objective::Whip),
    ] {
        let res = calibrate_rotation(
            &rt,
            &pools.r1_pool,
            &CalibConfig {
                objective: obj,
                steps: if common::full() { 60 } else { 25 },
                ..Default::default()
            },
        )
        .expect("calibrate");
        show(label, &matmul(&pool, &res.rotation));
    }
    println!(
        "\n(range ratio original/whip should be large; uniformity greatest in (f)) — \
         paper Figs 2/6. 4-bit quant error of the original pool: {}",
        fnum(stats::quant_error(&pool, 4), 4)
    );
}
