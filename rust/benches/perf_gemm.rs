//! §Perf: the tiled integer GEMM vs the f32 and dequantizing baselines —
//! the ledger behind `BENCH_gemm.json` (see `make bench-json`).
//!
//! Table2-shaped products on pinned configs: activations of `M` = 256
//! tokens against each model's `dim × dim` attention projection and
//! `ffn_dim × dim` FFN projection, plus a square roofline point. Rows:
//!
//!   * `f32`      — `matmul_transb_with`, the dense baseline,
//!   * `deq-i4`   — streaming dequantize + f32 dot (the former packed path),
//!   * `i8`/`i4`  — the cache-blocked panel GEMM over prepacked codes and
//!                  a layer-boundary `QAct` (the serving hot path),
//!   * `qact`     — the per-boundary activation quantization the GEMM
//!                  amortizes across every linear that shares it.
//!
//! Runs natively — no artifacts needed. Honors `DQ_WORKERS` (thread pin)
//! and, when `DQ_BENCH_JSON` names a directory, writes the canonical
//! receipt with `gflops_f32` / `gflops_i8` / `gflops_i4` /
//! `weight_bytes`. Acceptance: `gflops_i8 >= gflops_f32` — the packed
//! path must beat the f32 baseline, not just shrink it.

#[path = "common.rs"]
mod common;

use dartquant::model::ModelConfig;
use dartquant::tensor::{
    matmul_transb_deq_with, matmul_transb_qact_rowpar, matmul_transb_qact_sharded,
    matmul_transb_qact_with, matmul_transb_sharded, matmul_transb_with, quantize_act, Mat, QMat,
    QuantSpec,
};
use dartquant::util::bench::{fnum, time, write_receipt, Table};
use dartquant::util::json::Json;
use dartquant::util::prng::Pcg64;

struct Shape {
    config: String,
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

fn shapes() -> Vec<Shape> {
    let mut out = Vec::new();
    for name in ["llama2-tiny", "llama3-small"] {
        let cfg = ModelConfig::builtin(name).unwrap();
        out.push(Shape {
            config: cfg.name.clone(),
            label: "attn dim×dim",
            m: 256,
            k: cfg.dim,
            n: cfg.dim,
        });
        out.push(Shape {
            config: cfg.name.clone(),
            label: "ffn ffn_dim×dim",
            m: 256,
            k: cfg.dim,
            n: cfg.ffn_dim,
        });
    }
    out.push(Shape { config: "roofline".into(), label: "square", m: 512, k: 512, n: 512 });
    out
}

fn main() {
    let threads = common::workers();
    let iters = if common::full() { 12 } else { 6 };
    let mut table = Table::new(&["config", "shape", "path", "median", "GFLOP/s", "weight bytes"]);
    let mut receipt_shapes: Vec<Json> = Vec::new();
    let mut shard_shapes: Vec<Json> = Vec::new();
    // Canonical top-level numbers come from the largest (last) shape.
    let (mut gflops_f32, mut gflops_i8, mut gflops_i4, mut weight_bytes) = (0.0, 0.0, 0.0, 0u64);
    let (mut gflops_f32_sh, mut gflops_i4_sh, mut gflops_i4_rp) = (0.0, 0.0, 0.0);
    // Bit-identity is the shard plan's contract: verify every count
    // before timing any sharded row.
    const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];
    const BENCH_SHARDS: usize = 4;

    for s in shapes() {
        let (m, k, n) = (s.m, s.k, s.n);
        let mut rng = Pcg64::new(11);
        let x = Mat::from_fn(m, k, |_, _| rng.normal());
        let w = Mat::from_fn(n, k, |_, _| rng.normal());
        let mut xq = x.clone();
        // The layer-boundary activation quantization the linears share.
        let qa = quantize_act(&mut xq, 16.0).expect("W4A4 activation grid");
        let q8 = QMat::quantize_rtn(&w, QuantSpec::new(8));
        let q4 = QMat::quantize_rtn(&w, QuantSpec::new(4));
        q8.prepack();
        q4.prepack();
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let gflops = |median: std::time::Duration| flops / median.as_secs_f64() / 1e9;
        let shape_label = format!("{m}×{k}·{n} ({})", s.label);
        let mut row = |path: &str, median: std::time::Duration, bytes: u64| -> f64 {
            let g = gflops(median);
            table.row(&[
                s.config.clone(),
                shape_label.clone(),
                path.to_string(),
                dartquant::util::fmt_duration(median),
                fnum(g, 1),
                format!("{bytes}"),
            ]);
            g
        };

        let meas = time("f32", 2, iters, || {
            std::hint::black_box(matmul_transb_with(&x, &w, threads));
        });
        let g_f32 = row("f32", meas.median, w.nbytes());
        let meas = time("deq i4", 2, iters, || {
            std::hint::black_box(matmul_transb_deq_with(&x, &q4, threads));
        });
        row("deq-i4", meas.median, q4.nbytes());
        let meas = time("tiled i8", 2, iters, || {
            std::hint::black_box(matmul_transb_qact_with(&xq, &qa, &q8, threads));
        });
        let g_i8 = row("i8", meas.median, q8.nbytes());
        let meas = time("tiled i4", 2, iters, || {
            std::hint::black_box(matmul_transb_qact_with(&xq, &qa, &q4, threads));
        });
        let g_i4 = row("i4", meas.median, q4.nbytes());
        // The boundary quantization the GEMM rows presuppose: O(m·k),
        // amortized over every linear sharing the codes.
        let meas = time("quantize_act", 2, iters, || {
            let mut a = x.clone();
            std::hint::black_box(quantize_act(&mut a, 16.0));
        });
        table.row(&[
            s.config.clone(),
            shape_label.clone(),
            "qact boundary".into(),
            dartquant::util::fmt_duration(meas.median),
            "-".into(),
            format!("{}", qa.nbytes()),
        ]);

        // --- sharded rows: column-parallel f32/i4 and the i32 row-
        // parallel (k-split) reduce, all gated on bit-identity first.
        let f32_ref = matmul_transb_with(&x, &w, threads);
        let i4_ref = matmul_transb_qact_with(&xq, &qa, &q4, threads);
        for shards in SHARD_COUNTS {
            assert_eq!(
                matmul_transb_sharded(&x, &w, shards).data,
                f32_ref.data,
                "f32 column-parallel moved a bit at {shards} shards"
            );
            assert_eq!(
                matmul_transb_qact_sharded(&xq, &qa, &q4, shards).data,
                i4_ref.data,
                "i4 column-parallel moved a bit at {shards} shards"
            );
            assert_eq!(
                matmul_transb_qact_rowpar(&xq, &qa, &q4, shards).data,
                i4_ref.data,
                "i4 row-parallel reduce moved a bit at {shards} shards"
            );
        }
        let mut srow = |path: &str, median: std::time::Duration, bytes: u64| -> f64 {
            let g = gflops(median);
            table.row(&[
                s.config.clone(),
                shape_label.clone(),
                path.to_string(),
                dartquant::util::fmt_duration(median),
                fnum(g, 1),
                format!("{bytes}"),
            ]);
            g
        };
        let meas = time("f32 sharded", 2, iters, || {
            std::hint::black_box(matmul_transb_sharded(&x, &w, BENCH_SHARDS));
        });
        let g_f32_sh = srow("f32-shard4", meas.median, w.nbytes());
        let meas = time("i4 sharded", 2, iters, || {
            std::hint::black_box(matmul_transb_qact_sharded(&xq, &qa, &q4, BENCH_SHARDS));
        });
        let g_i4_sh = srow("i4-shard4", meas.median, q4.nbytes());
        let meas = time("i4 rowpar", 2, iters, || {
            std::hint::black_box(matmul_transb_qact_rowpar(&xq, &qa, &q4, BENCH_SHARDS));
        });
        let g_i4_rp = srow("i4-rowpar4", meas.median, q4.nbytes());
        shard_shapes.push(Json::obj(vec![
            ("config", Json::Str(s.config.clone())),
            ("label", Json::Str(s.label.to_string())),
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("gflops_f32_sharded", Json::Num(g_f32_sh)),
            ("gflops_i4_sharded", Json::Num(g_i4_sh)),
            ("gflops_i4_rowpar", Json::Num(g_i4_rp)),
        ]));
        gflops_f32_sh = g_f32_sh;
        gflops_i4_sh = g_i4_sh;
        gflops_i4_rp = g_i4_rp;

        receipt_shapes.push(Json::obj(vec![
            ("config", Json::Str(s.config.clone())),
            ("label", Json::Str(s.label.to_string())),
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("gflops_f32", Json::Num(g_f32)),
            ("gflops_i8", Json::Num(g_i8)),
            ("gflops_i4", Json::Num(g_i4)),
            ("weight_bytes_f32", Json::Num(w.nbytes() as f64)),
            ("weight_bytes_i8", Json::Num(q8.nbytes() as f64)),
            ("weight_bytes_i4", Json::Num(q4.nbytes() as f64)),
            ("panel_bytes_i4", Json::Num(q4.panel_nbytes() as f64)),
        ]));
        gflops_f32 = g_f32;
        gflops_i8 = g_i8;
        gflops_i4 = g_i4;
        weight_bytes = q4.nbytes();
    }

    table.print("perf_gemm — tiled i8/i4 panel GEMM vs baselines");
    println!(
        "\nacceptance: the i8 row's GFLOP/s must be ≥ the f32 row's on every shape —\n\
         the packed path has ~1/4 the weight traffic and exact integer accumulation,\n\
         so parity or better is the bar, not a consolation ratio."
    );

    write_receipt(
        "gemm",
        &Json::obj(vec![
            ("bench", Json::Str("perf_gemm".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(threads as f64)),
            ("gflops_f32", Json::Num(gflops_f32)),
            ("gflops_i8", Json::Num(gflops_i8)),
            ("gflops_i4", Json::Num(gflops_i4)),
            ("weight_bytes", Json::Num(weight_bytes as f64)),
            ("shapes", Json::Arr(receipt_shapes)),
        ]),
    );
    write_receipt(
        "shard",
        &Json::obj(vec![
            ("bench", Json::Str("perf_gemm".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(threads as f64)),
            ("bench_shards", Json::Num(BENCH_SHARDS as f64)),
            (
                "shard_counts_verified_bit_identical",
                Json::Arr(SHARD_COUNTS.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("gflops_f32_sharded", Json::Num(gflops_f32_sh)),
            ("gflops_i4_sharded", Json::Num(gflops_i4_sh)),
            ("gflops_i4_rowpar", Json::Num(gflops_i4_rp)),
            ("shapes", Json::Arr(shard_shapes)),
        ]),
    );
}
