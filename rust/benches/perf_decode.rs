//! §Perf: serving-path benches — prefill vs decode tokens/s, per-token
//! decode cost vs prefix length (the KV-cache win: a decode step does
//! O(prefix) attention + O(1) linears where the pre-serving code
//! recomputed the whole O(prefix²) sequence per token), and fp32 vs
//! packed-i4 weights through the same sessions.
//!
//! Runs natively — no artifacts needed. Honors `DQ_MODELS` / `DQ_FULL`
//! (model grid) and `DQ_WORKERS` (engine worker threads for the batched
//! continuous-batching row).

#[path = "common.rs"]
mod common;

use dartquant::model::{forward_one, FwdOptions, NoCapture, Weights};
use dartquant::serve::{BatchEngine, DecodeSession, EngineConfig, GenRequest};
use dartquant::util::bench::{fnum, write_receipt, Table};
use dartquant::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

const PREFILL_LEN: usize = 128;
const DECODE_STEPS: usize = 32;

fn per_token_us(wall: std::time::Duration, tokens: usize) -> f64 {
    wall.as_secs_f64() * 1e6 / tokens.max(1) as f64
}

fn main() {
    let prefixes: &[usize] = if common::full() { &[32, 128, 256, 512] } else { &[32, 128, 256] };
    let mut table = Table::new(&["model", "weights", "path", "prefix", "µs/token", "tokens/s"]);
    let mut receipt_rows: Vec<Json> = Vec::new();
    let mut row = |model: &str, weights: &str, path: &str, prefix: usize, us: f64| {
        table.row(&[
            model.to_string(),
            weights.to_string(),
            path.to_string(),
            prefix.to_string(),
            fnum(us, 1),
            fnum(1e6 / us, 0),
        ]);
        receipt_rows.push(Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("weights", Json::Str(weights.to_string())),
            ("path", Json::Str(path.to_string())),
            ("prefix", Json::Num(prefix as f64)),
            ("us_per_token", Json::Num(us)),
        ]));
    };

    for cfg in common::bench_models() {
        let (w, corpus) = common::grammar_model(&cfg);
        let packed = dartquant::quant::rtn_quantize_model_packed(&w, 4);
        let variants: [(&str, Weights, FwdOptions); 2] = [
            ("fp32", w, FwdOptions::FP),
            ("packed w4a4kv4", packed, FwdOptions::quant(4, 4, false)),
        ];
        for (wlabel, weights, opt) in variants {
            let weights = Arc::new(weights);
            let toks = corpus.sequence(prefixes[prefixes.len() - 1] + DECODE_STEPS + 1, 2, 1);

            // Prefill throughput: all positions in one shot.
            let t0 = Instant::now();
            let mut sess = DecodeSession::new(Arc::clone(&weights), opt);
            sess.prefill(&toks[..PREFILL_LEN]);
            row(&cfg.name, wlabel, "prefill", PREFILL_LEN, per_token_us(t0.elapsed(), PREFILL_LEN));

            // Decode: per-token step cost at growing prefix lengths. The
            // near-flat µs/token column across prefixes is the KV-cache
            // acceptance criterion (cost ≉ f(prefix)).
            for &prefix in prefixes {
                let mut sess = DecodeSession::new(Arc::clone(&weights), opt);
                sess.prefill(&toks[..prefix]);
                let t0 = Instant::now();
                for s in 0..DECODE_STEPS {
                    sess.step(toks[prefix + s]);
                }
                let us = per_token_us(t0.elapsed(), DECODE_STEPS);
                row(&cfg.name, wlabel, "decode step", prefix, us);
            }

            // The pre-serving alternative: recompute the full sequence to
            // get one next-token distribution. At seq_len ≥ 128 this is
            // the ≫ baseline the decode rows beat.
            let prefix = PREFILL_LEN;
            let t0 = Instant::now();
            let reps = 4;
            for r in 0..reps {
                forward_one(&weights, &toks[r..prefix + 1 + r], opt, &mut NoCapture);
            }
            row(&cfg.name, wlabel, "full recompute", prefix, per_token_us(t0.elapsed(), reps));

            // Continuous batching: aggregate decode throughput over
            // concurrent sessions on DQ_WORKERS threads.
            let sessions = 4;
            let mut engine = BatchEngine::new(
                Arc::clone(&weights),
                EngineConfig { opt, workers: common::workers(), ..EngineConfig::default() },
            );
            for i in 0..sessions {
                engine.submit(GenRequest {
                    prompt: corpus.sequence(32, 2, 10 + i as u64),
                    max_new: DECODE_STEPS,
                });
            }
            let t0 = Instant::now();
            engine.run().expect("engine run");
            let total = sessions * DECODE_STEPS;
            row(
                &cfg.name,
                wlabel,
                &format!("batched x{sessions} (workers {})", common::workers()),
                32,
                per_token_us(t0.elapsed(), total),
            );
        }
    }
    table.print("perf_decode — KV-cached serving path");
    println!(
        "\nacceptance: 'decode step' µs/token should be ~flat across prefixes and ≪ the\n\
         'full recompute' row at prefix {PREFILL_LEN} (which pays the whole O(prefix²) forward\n\
         per token)."
    );

    write_receipt(
        "decode",
        &Json::obj(vec![
            ("bench", Json::Str("perf_decode".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(common::workers() as f64)),
            ("rows", Json::Arr(receipt_rows)),
        ]),
    );
}
