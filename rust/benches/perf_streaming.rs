//! Table-3-style out-of-core cost comparison: streamed (`--streaming`,
//! resident weight bytes bounded by `--resident-budget`) vs in-memory
//! pipeline runs across the table2 models, at 1..N workers — wall time,
//! peak resident weight bytes vs the budget, and the canonical-report
//! byte-identity check of `docs/STREAMING.md`.
//!
//! Runs natively (no artifacts needed): QuaRot rotations + packed RTN
//! weights isolate the weight-streaming cost from artifact execution.
//! Knobs: `DQ_MODELS`, `DQ_WORKERS`, `DQ_DIALECT`, `DQ_FULL` (common.rs).

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{Pipeline, PipelineReport, WeightQuant};
use dartquant::model::{suggested_resident_budget, BitSetting};
use dartquant::util::bench::{fnum, write_receipt, Table};
use dartquant::util::json::Json;

fn mib(b: u64) -> f64 {
    b as f64 / (1 << 20) as f64
}

fn main() {
    let models = common::bench_models();
    let workers_grid: Vec<usize> = match common::workers() {
        0 => vec![1, 4],
        w => vec![1, w.max(1)],
    };
    let mut table = Table::new(&[
        "Model",
        "Mode",
        "Workers",
        "wall (s)",
        "peak wt (MiB)",
        "budget (MiB)",
        "model (MiB)",
        "canonical",
    ]);
    let mut receipt_rows: Vec<Json> = Vec::new();
    for cfg in &models {
        if cfg.is_moe() {
            continue; // keep the table to the dense table2 ladder
        }
        let (weights, _corpus) = common::grammar_model(cfg);
        let budget = suggested_resident_budget(cfg);
        let model_bytes = weights.nbytes();
        for &wk in &workers_grid {
            let run = |streamed: bool| -> PipelineReport {
                let mut b = Pipeline::builder(&weights)
                    .method("quarot")
                    .unwrap()
                    .bits(BitSetting::W4A4)
                    .packed(true)
                    .workers(wk)
                    .configure(|c| {
                        c.weight_quant = WeightQuant::Rtn;
                        c.calib_dialect = common::dialect();
                    });
                if streamed {
                    b = b.streaming(true).resident_budget(Some(budget));
                }
                b.run_native().expect("native pipeline run")
            };
            let inmem = run(false);
            let streamed = run(true);
            let identical = streamed.record().canonical().to_json().to_string()
                == inmem.record().canonical().to_json().to_string();
            assert!(
                streamed.stats.peak_weight_bytes <= budget,
                "{}: peak {} exceeds the {budget} budget",
                cfg.name,
                streamed.stats.peak_weight_bytes
            );
            table.row(&[
                cfg.name.clone(),
                "in-memory".into(),
                wk.to_string(),
                fnum(inmem.stats.total_time.as_secs_f64(), 3),
                "-".into(),
                "-".into(),
                fnum(mib(model_bytes), 1),
                "-".into(),
            ]);
            table.row(&[
                cfg.name.clone(),
                "streamed".into(),
                wk.to_string(),
                fnum(streamed.stats.total_time.as_secs_f64(), 3),
                fnum(mib(streamed.stats.peak_weight_bytes), 1),
                fnum(mib(budget), 1),
                fnum(mib(model_bytes), 1),
                if identical { "byte-identical".into() } else { "MISMATCH".into() },
            ]);
            receipt_rows.push(Json::obj(vec![
                ("model", Json::Str(cfg.name.clone())),
                ("workers", Json::Num(wk as f64)),
                ("inmem_wall_s", Json::Num(inmem.stats.total_time.as_secs_f64())),
                ("streamed_wall_s", Json::Num(streamed.stats.total_time.as_secs_f64())),
                ("peak_weight_bytes", Json::Num(streamed.stats.peak_weight_bytes as f64)),
                ("resident_budget_bytes", Json::Num(budget as f64)),
                ("model_bytes", Json::Num(model_bytes as f64)),
                ("canonical_identical", Json::Bool(identical)),
            ]));
        }
    }
    table.print("perf_streaming — out-of-core vs in-memory pipeline cost (Table-3 style)");
    write_receipt(
        "streaming",
        &Json::obj(vec![
            ("bench", Json::Str("perf_streaming".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(common::workers() as f64)),
            ("rows", Json::Arr(receipt_rows)),
        ]),
    );
    if let Some(cfg) = models.iter().filter(|c| !c.is_moe()).max_by_key(|c| c.n_params()) {
        let budget = suggested_resident_budget(cfg);
        let model = cfg.n_params() as u64 * 4;
        println!(
            "\nlargest config {}: resident budget {:.1} MiB = {:.0}% of the {:.1} MiB model\n\
             (the paper's resource story: calibration never holds the whole model — \
             a 70B fits a single 24 GiB card)",
            cfg.name,
            mib(budget),
            100.0 * budget as f64 / model as f64,
            mib(model)
        );
    }
}
