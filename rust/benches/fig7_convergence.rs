//! Figure 7a: activation quantization loss over calibration steps per
//! objective. Figure 7b: whip-loss convergence of QR-Orth vs Cayley with
//! SGD and Adam. Printed as step series (plot-ready CSV-ish rows).

#[path = "common.rs"]
mod common;

use dartquant::calib::{sample_tokens, CalibConfig, Objective, OptKind, OrthScheme};
use dartquant::eval::stats;
use dartquant::runtime::Value;
use dartquant::tensor::Mat;
use dartquant::util::prng::Pcg64;

fn pool(n: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::from_fn(2048, n, |_, _| rng.laplace(1.0));
    for &c in &rng.sample_indices(n, n / 32) {
        for i in 0..m.rows {
            *m.at_mut(i, c) *= 12.0;
        }
    }
    m
}

/// Manual loop so we can track the *quantization* loss (Fig 7a's y-axis)
/// after every step of each objective.
fn quant_loss_trajectory(
    rt: &dartquant::runtime::Runtime,
    p: &Mat,
    obj: Objective,
    steps: usize,
) -> Vec<f64> {
    let n = p.cols;
    let exe = rt.load(&format!("calib_{}_sgd_n{n}", obj.name())).expect("artifact");
    let mut rng = Pcg64::new(0xf16);
    let mut z = dartquant::linalg::randomized_hadamard(n, &mut rng);
    let mut m = Mat::zeros(n, n);
    let mut out = Vec::with_capacity(steps + 1);
    let lr = CalibConfig::default().lr;
    for _ in 0..steps {
        let r = dartquant::linalg::qr_orthogonalize(&z);
        out.push(stats::quant_error(&dartquant::tensor::matmul(p, &r), 4));
        let x = sample_tokens(p, dartquant::calib::CALIB_TOKENS, &mut rng);
        let o = exe
            .run(&[Value::from_mat(&z), Value::from_mat(&m), Value::from_mat(&x), Value::scalar(lr)])
            .expect("step");
        z = o[0].to_mat().unwrap();
        m = o[1].to_mat().unwrap();
    }
    out
}

fn main() {
    let rt = common::runtime();
    let steps = if common::full() { 40 } else { 20 };
    let p = pool(256, 1);

    println!("== Fig 7a — activation quant loss by optimization objective ==");
    println!("step, quant, variance, kurtosis, whip");
    let series: Vec<Vec<f64>> = [Objective::Quant, Objective::Variance, Objective::Kurtosis, Objective::Whip]
        .iter()
        .map(|&o| quant_loss_trajectory(&rt, &p, o, steps))
        .collect();
    for i in 0..steps {
        println!(
            "{i}, {:.5}, {:.5}, {:.5}, {:.5}",
            series[0][i], series[1][i], series[2][i], series[3][i]
        );
    }

    println!("\n== Fig 7b — whip-loss convergence: QR-Orth vs Cayley ==");
    println!("step, cayley-sgd, qr-sgd, cayley-adam, qr-adam");
    let mut curves = Vec::new();
    for (scheme, opt) in [
        (OrthScheme::Cayley, OptKind::Sgd),
        (OrthScheme::QrOrth, OptKind::Sgd),
        (OrthScheme::Cayley, OptKind::Adam),
        (OrthScheme::QrOrth, OptKind::Adam),
    ] {
        let cfg = CalibConfig { scheme, optimizer: opt, steps, ..Default::default() };
        let res = dartquant::calib::calibrate_rotation(&rt, &p, &cfg).expect("calibrate");
        curves.push(res.losses);
    }
    for i in 0..steps {
        println!(
            "{i}, {:.4}, {:.4}, {:.4}, {:.4}",
            curves[0][i], curves[1][i], curves[2][i], curves[3][i]
        );
    }
    let last = |k: usize| curves[k].last().unwrap();
    println!(
        "\nfinal whip loss — cayley-sgd {:.3} vs qr-sgd {:.3}; cayley-adam {:.3} vs qr-adam {:.3}",
        last(0),
        last(1),
        last(2),
        last(3)
    );
    println!("paper shape: QR variants converge faster and end lower.");
}
