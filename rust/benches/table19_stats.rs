//! Table 19 (Appendix G): activation statistics per model — mean, variance
//! and excess kurtosis of 1000 sampled activations. Paper shape: mean ≈ 0,
//! variance ≈ 1, kurtosis in the tens-to-hundreds (heavy tails).

#[path = "common.rs"]
mod common;

use dartquant::coordinator::capture_pools_native;
use dartquant::eval::stats;
use dartquant::util::bench::{fnum, Table};
use dartquant::util::prng::Pcg64;

fn main() {
    let mut table = Table::new(&["Model", "Kurtosis", "Mean", "Variance"]);
    for cfg in common::bench_models() {
        let (weights, corpus) = common::grammar_model(&cfg);
        let seqs = corpus.calib_sequences(2, 256);
        let pools = capture_pools_native(&weights, &seqs, 0.25, 3);
        let mut rng = Pcg64::new(4);
        let pool = dartquant::calib::sample_tokens(&pools.r1_pool, 1000, &mut rng);
        // Paper stats are on RMS-normalized activations (mean~0, var~1).
        let s = stats::activation_stats(&stats::normalize_rows_rms(&pool));
        table.row(&[
            cfg.name.clone(),
            fnum(s.kurtosis, 2),
            format!("{:.2e}", s.mean),
            format!("{:.3}", s.variance),
        ]);
    }
    table.print("Table 19 — activation statistics (1000 samples, RMS-normalized)");
    println!("\npaper shape: mean≈0, variance≈1, kurtosis ≫ 0 (Laplace-like heavy tails).");
}
