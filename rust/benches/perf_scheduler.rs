//! Scheduler scaling: calibrate-stage wall clock at workers=1 vs
//! workers=N over the Table 2 model grid, for the methods whose stages
//! decompose into per-layer jobs (DartQuant's R1+R2 calibration,
//! OmniQuant's per-layer clip grid search).
//!
//! Also verifies the determinism contract on every pair of runs: the
//! canonical report JSON (timings stripped) must be byte-identical
//! between the serial and the parallel run.
//!
//! Knobs: DQ_WORKERS (parallel worker count, default = all cores),
//! DQ_FULL / DQ_MODELS / DQ_DIALECT as in every bench.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{MethodRegistry, Pipeline, PipelineConfig, PipelineReport};
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, write_receipt, Table};
use dartquant::util::json::Json;
use dartquant::util::threadpool::ThreadPool;

fn run(
    rt: &dartquant::runtime::Runtime,
    weights: &dartquant::model::Weights,
    method: &str,
    workers: usize,
) -> anyhow::Result<PipelineReport> {
    let mut pcfg =
        PipelineConfig::new(dartquant::coordinator::Method::DartQuant, BitSetting::W4A4);
    pcfg.calib_dialect = common::dialect();
    pcfg.calib_sequences = if common::full() { 32 } else { 16 };
    pcfg.calib.steps = if common::full() { 60 } else { 25 };
    Pipeline::builder(weights)
        .config(pcfg)
        .method_in(&MethodRegistry::builtin(), method)?
        .workers(workers)
        .run(rt)
}

fn main() {
    let rt = common::runtime();
    let par = match common::workers() {
        0 => ThreadPool::default_parallelism(),
        n => n,
    };
    let methods = ["dartquant", "omniquant"];

    let mut table = Table::new(&[
        "Model", "Method", "Workers", "calibrate (s)", "quantize (s)", "total (s)", "speedup",
        "identical",
    ]);
    let mut receipt_rows: Vec<Json> = Vec::new();
    for cfg in common::bench_models() {
        let (weights, _corpus) = common::grammar_model(&cfg);
        for method in methods {
            // The parallelizable stage differs by method: DartQuant fans
            // out in calibrate, OmniQuant in quantize.
            let stage_time = |r: &PipelineReport| {
                if method == "dartquant" {
                    r.stats.calibrate_time.as_secs_f64()
                } else {
                    r.stats.quantize_time.as_secs_f64()
                }
            };
            let serial = match run(&rt, &weights, method, 1) {
                Ok(r) => r,
                Err(e) => {
                    table.row(&[
                        cfg.name.clone(),
                        method.into(),
                        "1".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        format!("err: {e}"),
                    ]);
                    continue;
                }
            };
            let parallel = match run(&rt, &weights, method, par) {
                Ok(r) => r,
                Err(e) => {
                    table.row(&[
                        cfg.name.clone(),
                        method.into(),
                        format!("{par}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        format!("err: {e}"),
                    ]);
                    continue;
                }
            };
            // Determinism contract: canonical reports byte-identical.
            let same = serial.record().canonical().to_json().to_string()
                == parallel.record().canonical().to_json().to_string();
            let speedup = stage_time(&serial) / stage_time(&parallel).max(1e-9);
            for (w, r) in [(1usize, &serial), (par, &parallel)] {
                table.row(&[
                    cfg.name.clone(),
                    r.method.clone(),
                    format!("{w}"),
                    fnum(r.stats.calibrate_time.as_secs_f64(), 3),
                    fnum(r.stats.quantize_time.as_secs_f64(), 3),
                    fnum(r.stats.total_time.as_secs_f64(), 3),
                    if w == 1 { "1.00".into() } else { fnum(speedup, 2) },
                    if same { "yes".into() } else { "MISMATCH".into() },
                ]);
            }
            if !same {
                eprintln!(
                    "DETERMINISM VIOLATION: {} {method} workers=1 vs {par} reports differ",
                    cfg.name
                );
            }
            receipt_rows.push(Json::obj(vec![
                ("model", Json::Str(cfg.name.clone())),
                ("method", Json::Str(method.to_string())),
                ("workers", Json::Num(par as f64)),
                ("serial_stage_s", Json::Num(stage_time(&serial))),
                ("parallel_stage_s", Json::Num(stage_time(&parallel))),
                ("speedup", Json::Num(speedup)),
                ("canonical_identical", Json::Bool(same)),
            ]));
        }
    }
    table.print(&format!("perf_scheduler — calibrate-stage scaling (1 vs {par} workers)"));
    write_receipt(
        "scheduler",
        &Json::obj(vec![
            ("bench", Json::Str("perf_scheduler".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(par as f64)),
            ("rows", Json::Arr(receipt_rows)),
        ]),
    );
}
