//! Table 1: overfitting of end-to-end fine-tuning — SpinQuant-sim
//! calibrated on each dialect, evaluated on all three. The paper's shape:
//! e2e fine-tuning improves most on the dialect it calibrated on and
//! regresses elsewhere (vs the method-free quantized baseline).

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let models: Vec<&str> =
        if common::full() { vec!["llama2-tiny", "llama2-small"] } else { vec!["llama2-tiny"] };
    for name in models {
        let cfg = dartquant::model::ModelConfig::builtin(name).unwrap();
        let (weights, _c) = common::grammar_model(&cfg);
        let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
        let mut table = Table::new(&["Calib set", "Wiki", "PTB", "C4"]);

        // Baseline: fp16 PPL on each eval dialect.
        let mut base = Vec::new();
        for d in Dialect::ALL {
            let corpus = Corpus::new(d, cfg.vocab, 7);
            base.push(
                eval::ppl_artifact(&rt, &weights, &corpus, spec, 65536.0, 65536.0, false).unwrap(),
            );
        }
        table.row(&[
            "Baseline (fp)".into(),
            fnum(base[0], 2),
            fnum(base[1], 2),
            fnum(base[2], 2),
        ]);

        for calib_d in Dialect::ALL {
            let mut pcfg = PipelineConfig::new(Method::SpinQuant, BitSetting::W4A4);
            pcfg.workers = common::workers();
            pcfg.calib_dialect = calib_d;
            pcfg.spin.steps = if common::full() { 12 } else { 6 };
            pcfg.calib_sequences = 16;
            let report = run_pipeline(&rt, &weights, &pcfg).expect("spin pipeline");
            let mut row = vec![format!("e2e on {}", calib_d.label())];
            for d in Dialect::ALL {
                let corpus = Corpus::new(d, cfg.vocab, 7);
                let ppl = eval::ppl_artifact(
                    &rt,
                    &report.weights,
                    &corpus,
                    spec,
                    BitSetting::levels(4),
                    65536.0,
                    true,
                )
                .unwrap();
                row.push(fnum(ppl, 2));
            }
            table.row(&row);
        }
        table.print(&format!(
            "Table 1 — e2e fine-tuning calibration-set sensitivity ({name}, W4A4)"
        ));
    }
}
