//! Tables 20/21 (Appendix H): MoE results — the tiny Mixtral-like config
//! under RTN / QuaRot / DartQuant at 4-4-16 and 4-4-4. The rotation fusion
//! must commute with expert routing (R1 enters every expert's wg/wu and
//! R4 every expert's wd).

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::{BitSetting, ModelConfig};
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
    let (weights, _corpus) = common::grammar_model(&cfg);
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    let mut table = Table::new(&["Bits", "Method", "Wiki PPL", "0-shot9"]);

    // FP baseline row.
    let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
    let fp = eval::ppl_artifact(&rt, &weights, &corpus, spec, 65536.0, 65536.0, false).unwrap();
    let (_t, zs_fp) = eval::zeroshot::suite_accuracy_artifact(
        &rt, &weights, Dialect::Wiki, common::zs_items(), 256, 99, 65536.0, 65536.0, false,
    )
    .unwrap();
    table.row(&["FP16".into(), "Baseline".into(), fnum(fp, 2), fnum(zs_fp * 100.0, 2)]);

    for bits in [BitSetting::W4A4, BitSetting::W4A4KV4] {
        for method in [Method::Rtn, Method::QuaRot, Method::DartQuant] {
            let mut pcfg = PipelineConfig::new(method, bits);
            pcfg.workers = common::workers();
            pcfg.calib.steps = if common::full() { 60 } else { 30 };
            pcfg.calib_sequences = 16;
            // GPTQ Hessian capture hooks are dense-only; use RTN weights on
            // the MoE (the rotation effect is what Tables 20/21 isolate).
            pcfg.weight_quant = dartquant::coordinator::WeightQuant::Rtn;
            let report = match run_pipeline(&rt, &weights, &pcfg) {
                Ok(r) => r,
                Err(e) => {
                    table.row(&[bits.label(), method.name().into(), format!("err {e}"), "-".into()]);
                    continue;
                }
            };
            let use_had = report.rotation.as_ref().map(|r| r.online_had).unwrap_or(false);
            let ppl = eval::ppl_artifact(
                &rt,
                &report.weights,
                &corpus,
                spec,
                BitSetting::levels(bits.a),
                BitSetting::levels(bits.kv),
                use_had,
            )
            .unwrap();
            let (_t, zs) = eval::zeroshot::suite_accuracy_artifact(
                &rt,
                &report.weights,
                Dialect::Wiki,
                common::zs_items(),
                256,
                99,
                BitSetting::levels(bits.a),
                BitSetting::levels(bits.kv),
                use_had,
            )
            .unwrap();
            table.row(&[bits.label(), method.name().into(), fnum(ppl, 2), fnum(zs * 100.0, 2)]);
        }
    }
    table.print("Tables 20/21 — MoE (mixtral-tiny)");
    println!("\npaper shape: rotations recover most of RTN's collapse on MoE too.");
}
