//! Tables 17/18 (Appendix E): comparison with mixed-precision baselines —
//! QUIK-like (fp-protected top channels) and Atom-like (grouped, reordered)
//! weight quantization vs DartQuant's uniform 4-bit after rotation.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::{BitSetting, Weights};
use dartquant::quant;
use dartquant::util::bench::{fnum, Table};

/// Per-channel activation abs-max at each linear's input (for the mixed-
/// precision channel selection).
fn act_absmax(weights: &Weights, corpus: &Corpus) -> std::collections::BTreeMap<String, Vec<f32>> {
    use dartquant::model::{forward_one, CaptureHook, FwdOptions};
    struct Hook(std::collections::BTreeMap<String, Vec<f32>>);
    impl CaptureHook for Hook {
        fn on_linear_input(&mut self, name: &str, x: &dartquant::tensor::Mat) {
            let e = self.0.entry(name.to_string()).or_insert_with(|| vec![0.0; x.cols]);
            for i in 0..x.rows {
                for (c, m) in e.iter_mut().enumerate() {
                    *m = m.max(x.at(i, c).abs());
                }
            }
        }
    }
    let mut hook = Hook(Default::default());
    for seq in corpus.calib_sequences(2, 128) {
        forward_one(weights, &seq, FwdOptions::FP, &mut hook);
    }
    hook.0
}

fn mixed_quantize(weights: &Weights, corpus: &Corpus, atom: bool) -> Weights {
    let absmax = act_absmax(weights, corpus);
    let mut out = weights.clone();
    let shared: Vec<(String, String)> = {
        let mut v = Vec::new();
        for l in 0..weights.cfg.n_layers {
            v.push((format!("l{l}.wq"), format!("l{l}.wq")));
            v.push((format!("l{l}.wk"), format!("l{l}.wq")));
            v.push((format!("l{l}.wv"), format!("l{l}.wq")));
            v.push((format!("l{l}.wo"), format!("l{l}.wo")));
            v.push((format!("l{l}.wg"), format!("l{l}.wg")));
            v.push((format!("l{l}.wu"), format!("l{l}.wg")));
            v.push((format!("l{l}.wd"), format!("l{l}.wd")));
        }
        v
    };
    for (target, site) in shared {
        let Some(a) = absmax.get(&site) else { continue };
        let w = out.get(&target);
        let q = if atom {
            quant::atom_quantize_mat(w, a, 4)
        } else {
            // QUIK protects 256/4096 channels on real Llamas — 1/16.
            quant::quik_quantize_mat(w, a, (w.cols / 16).max(2), 4)
        };
        out.set(&target, q);
    }
    out
}

fn main() {
    let rt = common::runtime();
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    for cfg in common::bench_models() {
        let (weights, corpus) = common::grammar_model(&cfg);
        let mut table = Table::new(&["Method", "Wiki", "PTB", "C4", "Avg"]);
        let eval_w = |w: &Weights, use_had: bool, table: &mut Table, label: &str| {
            let mut row = vec![label.to_string()];
            let mut total = 0.0;
            for d in Dialect::ALL {
                let c = Corpus::new(d, cfg.vocab, 7);
                let ppl = eval::ppl_artifact(&rt, w, &c, spec, BitSetting::levels(4), 65536.0, use_had)
                    .unwrap();
                total += ppl;
                row.push(fnum(ppl, 2));
            }
            row.push(fnum(total / 3.0, 2));
            table.row(&row);
        };
        eval_w(&mixed_quantize(&weights, &corpus, false), false, &mut table, "QUIK-like (4+fp16 mixed)");
        eval_w(&mixed_quantize(&weights, &corpus, true), false, &mut table, "Atom-like (grouped 4/8)");
        let mut pcfg = PipelineConfig::new(Method::DartQuant, BitSetting::W4A4);
        pcfg.calib.steps = if common::full() { 60 } else { 30 };
        pcfg.calib_sequences = 16;
        let report = run_pipeline(&rt, &weights, &pcfg).expect("pipeline");
        eval_w(&report.weights, true, &mut table, "DartQuant (uniform 4-bit)");
        table.print(&format!("Tables 17/18 — mixed-precision comparison ({}, A4)", cfg.name));
    }
}
