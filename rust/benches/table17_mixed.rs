//! Tables 17/18 (Appendix E): comparison with mixed-precision baselines —
//! QUIK-like (fp-protected top channels) and Atom-like (grouped, reordered)
//! weight quantization vs DartQuant's uniform 4-bit after rotation.
//!
//! The mixed baselines run through the registry's `WeightQuantizer` impls
//! (`QuikQuantizer` / `AtomQuantizer`) composed with `NoRotation` — the
//! same pipeline surface every other method uses.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{
    AtomQuantizer, NoRotation, Pipeline, PipelineConfig, QuikQuantizer, WeightQuantizer,
};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::{BitSetting, Weights};
use dartquant::util::bench::{fnum, Table};
use std::sync::Arc;

fn main() {
    let rt = common::runtime();
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    for cfg in common::bench_models() {
        let (weights, _corpus) = common::grammar_model(&cfg);
        let mut table = Table::new(&["Method", "Wiki", "PTB", "C4", "Avg"]);
        let eval_w = |w: &Weights, use_had: bool, table: &mut Table, label: &str| {
            let mut row = vec![label.to_string()];
            let mut total = 0.0;
            for d in Dialect::ALL {
                let c = Corpus::new(d, cfg.vocab, 7);
                let ppl = eval::ppl_artifact(&rt, w, &c, spec, BitSetting::levels(4), 65536.0, use_had)
                    .unwrap();
                total += ppl;
                row.push(fnum(ppl, 2));
            }
            row.push(fnum(total / 3.0, 2));
            table.row(&row);
        };

        let mixed = |q: Arc<dyn WeightQuantizer>| -> Weights {
            Pipeline::builder(&weights)
                .rotation(Arc::new(NoRotation))
                .quantizer(q)
                .bits(BitSetting::W4A4)
                .configure(|c| c.calib_dialect = common::dialect())
                .run(&rt)
                .expect("mixed-precision pipeline")
                .weights
        };
        eval_w(&mixed(Arc::new(QuikQuantizer::default())), false, &mut table, "QUIK-like (4+fp16 mixed)");
        eval_w(&mixed(Arc::new(AtomQuantizer)), false, &mut table, "Atom-like (grouped 4/8)");

        let mut pcfg = PipelineConfig::new(dartquant::coordinator::Method::DartQuant, BitSetting::W4A4);
        pcfg.workers = common::workers();
        pcfg.calib_dialect = common::dialect();
        pcfg.calib.steps = if common::full() { 60 } else { 30 };
        pcfg.calib_sequences = 16;
        let report = Pipeline::builder(&weights).config(pcfg).run(&rt).expect("pipeline");
        eval_w(&report.weights, true, &mut table, "DartQuant (uniform 4-bit)");
        table.print(&format!("Tables 17/18 — mixed-precision comparison ({}, A4)", cfg.name));
    }
}
