//! §Perf: hot-path throughput microbenches — the before/after ledger for
//! EXPERIMENTS.md §Perf.
//!
//! L3-visible costs measured here:
//!   * one QR-Orth calibration step (PJRT executable) per dim,
//!   * one Cayley step per dim (the 4/3·n³ vs 6n³ story),
//!   * eval forward throughput (tokens/s) via fwd artifact vs native rust,
//!   * native matmul GFLOP/s (the capture/GPTQ substrate),
//!   * f32 vs packed-i8/i4 `matmul_transb` (the quantized linear path:
//!     GFLOP/s and true weight bytes; honors `DQ_WORKERS` like the
//!     pipeline benches),
//!   * capture artifact throughput.

#[path = "common.rs"]
mod common;

use dartquant::calib::{sample_tokens, CALIB_TOKENS};
use dartquant::model::{TokenBatch, Weights};
use dartquant::runtime::Value;
use dartquant::tensor::{
    matmul, matmul_transb_deq_with, matmul_transb_q_with, matmul_transb_qact_rowpar,
    matmul_transb_qact_sharded, matmul_transb_qact_with, matmul_transb_sharded,
    matmul_transb_with, quantize_act, Mat, QMat, QuantSpec,
};
use dartquant::util::bench::{fnum, time, Table};
use dartquant::util::prng::Pcg64;

fn main() {
    let rt = common::runtime();
    let mut table = Table::new(&["path", "median", "throughput"]);

    // --- calibration step per dim --------------------------------------
    for n in [64usize, 256, 512, 640] {
        let mut rng = Pcg64::new(1);
        let pool = Mat::from_fn(CALIB_TOKENS * 2, n, |_, _| rng.laplace(1.0));
        for kind in ["calib", "cayley"] {
            let name = format!("{kind}_whip_sgd_n{n}");
            let Ok(exe) = rt.load(&name) else { continue };
            let z = dartquant::linalg::randomized_hadamard(n, &mut rng);
            let m0 = Mat::zeros(n, n);
            let x = sample_tokens(&pool, CALIB_TOKENS, &mut rng);
            let meas = time(&name, 1, if common::full() { 10 } else { 4 }, || {
                let _ = exe
                    .run(&[
                        Value::from_mat(&z),
                        Value::from_mat(&m0),
                        Value::from_mat(&x),
                        Value::scalar(1e-2),
                    ])
                    .unwrap();
            });
            table.row(&[
                format!("{kind} step n={n}"),
                dartquant::util::fmt_duration(meas.median),
                format!("{:.1} steps/s", 1.0 / meas.median.as_secs_f64()),
            ]);
        }
    }

    // --- eval forward: artifact vs native -------------------------------
    let cfg = dartquant::model::ModelConfig::builtin("llama2-tiny").unwrap();
    let (weights, corpus) = common::grammar_model(&cfg);
    let toks = TokenBatch::new(&corpus.valid_batch(8, 256, 0));
    let meas = time("fwd artifact (8x256)", 1, 5, || {
        let _ = dartquant::model::artifact_io::run_fwd(&rt, &weights, &toks).unwrap();
    });
    let tok_s = 8.0 * 256.0 / meas.median.as_secs_f64();
    table.row(&[
        "eval fwd artifact (8×256)".into(),
        dartquant::util::fmt_duration(meas.median),
        format!("{:.0} tok/s", tok_s),
    ]);
    let rows = toks.rows();
    let meas = time("fwd native (8x256)", 0, 2, || {
        let _ = dartquant::model::forward_batch(&weights, &rows, dartquant::model::FwdOptions::FP);
    });
    table.row(&[
        "eval fwd native (8×256)".into(),
        dartquant::util::fmt_duration(meas.median),
        format!("{:.0} tok/s", 8.0 * 256.0 / meas.median.as_secs_f64()),
    ]);

    // --- capture artifact ------------------------------------------------
    let meas = time("capture artifact", 1, 3, || {
        let _ = dartquant::model::artifact_io::run_capture(&rt, &weights, &toks).unwrap();
    });
    table.row(&[
        "capture artifact (8×256)".into(),
        dartquant::util::fmt_duration(meas.median),
        format!("{:.0} tok/s", 8.0 * 256.0 / meas.median.as_secs_f64()),
    ]);

    // --- native matmul roofline -----------------------------------------
    for n in [256usize, 512] {
        let mut rng = Pcg64::new(2);
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let meas = time("matmul", 2, 8, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / meas.median.as_secs_f64() / 1e9;
        table.row(&[
            format!("native matmul {n}³"),
            dartquant::util::fmt_duration(meas.median),
            format!("{} GFLOP/s", fnum(gflops, 1)),
        ]);
    }

    // --- packed weight matmul: f32 vs i8 vs i4 ---------------------------
    // DQ_WORKERS pins the thread count of every row (0 = the kernels'
    // flops-based default), mirroring the pipeline benches.
    let threads = common::workers();
    let mut ptable = Table::new(&["packed path", "median", "GFLOP/s", "weight bytes"]);
    for n in [256usize, 512] {
        let mut rng = Pcg64::new(7);
        let x = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut xq = x.clone();
        // The layer-boundary activation quantization (W4A4 grid): the
        // `qact` rows below reuse these codes, like the forward does.
        let qa = quantize_act(&mut xq, 16.0).expect("W4A4 activation grid");
        let w = Mat::from_fn(n, n, |_, _| rng.normal());
        let q8 = QMat::quantize_rtn(&w, QuantSpec::new(8));
        let q4 = QMat::quantize_rtn(&w, QuantSpec::new(4));
        q8.prepack();
        q4.prepack();
        let gflops = |median: std::time::Duration| {
            fnum(2.0 * (n as f64).powi(3) / median.as_secs_f64() / 1e9, 1)
        };
        let meas = time("transb f32", 2, 8, || {
            std::hint::black_box(matmul_transb_with(&x, &w, threads));
        });
        ptable.row(&[
            format!("f32 transb {n}³"),
            dartquant::util::fmt_duration(meas.median),
            gflops(meas.median),
            format!("{}", w.nbytes()),
        ]);
        for (label, q) in [("i8", &q8), ("i4", &q4)] {
            let meas = time("transb deq", 2, 8, || {
                std::hint::black_box(matmul_transb_deq_with(&x, q, threads));
            });
            ptable.row(&[
                format!("packed-{label} deq {n}³"),
                dartquant::util::fmt_duration(meas.median),
                gflops(meas.median),
                format!("{}", q.nbytes()),
            ]);
            let meas = time("transb int", 2, 8, || {
                std::hint::black_box(matmul_transb_q_with(&xq, q, 16.0, threads));
            });
            ptable.row(&[
                format!("packed-{label} int {n}³"),
                dartquant::util::fmt_duration(meas.median),
                gflops(meas.median),
                format!("{}", q.nbytes()),
            ]);
            // The forward's actual hot path: boundary codes computed
            // once (QAct), prepacked panels — no per-call recovery.
            let meas = time("transb qact", 2, 8, || {
                std::hint::black_box(matmul_transb_qact_with(&xq, &qa, q, threads));
            });
            ptable.row(&[
                format!("packed-{label} qact {n}³"),
                dartquant::util::fmt_duration(meas.median),
                gflops(meas.median),
                format!("{}", q.nbytes() + q.panel_nbytes()),
            ]);
        }
        // --- within-layer sharding (the `--shards` plan): column-
        // parallel f32/i4 and the i32 row-parallel reduce, gated on
        // bit-identity at every count before any timing.
        let f32_ref = matmul_transb_with(&x, &w, threads);
        let i4_ref = matmul_transb_qact_with(&xq, &qa, &q4, threads);
        for shards in [1usize, 2, 4, 7] {
            assert_eq!(matmul_transb_sharded(&x, &w, shards).data, f32_ref.data, "f32 shard");
            assert_eq!(
                matmul_transb_qact_sharded(&xq, &qa, &q4, shards).data,
                i4_ref.data,
                "i4 shard"
            );
            assert_eq!(
                matmul_transb_qact_rowpar(&xq, &qa, &q4, shards).data,
                i4_ref.data,
                "i4 rowpar"
            );
        }
        let meas = time("transb f32 sharded", 2, 8, || {
            std::hint::black_box(matmul_transb_sharded(&x, &w, 4));
        });
        ptable.row(&[
            format!("f32 transb shard4 {n}³"),
            dartquant::util::fmt_duration(meas.median),
            gflops(meas.median),
            format!("{}", w.nbytes()),
        ]);
        let meas = time("transb i4 sharded", 2, 8, || {
            std::hint::black_box(matmul_transb_qact_sharded(&xq, &qa, &q4, 4));
        });
        ptable.row(&[
            format!("packed-i4 qact shard4 {n}³"),
            dartquant::util::fmt_duration(meas.median),
            gflops(meas.median),
            format!("{}", q4.nbytes() + q4.panel_nbytes()),
        ]);
        let meas = time("transb i4 rowpar", 2, 8, || {
            std::hint::black_box(matmul_transb_qact_rowpar(&xq, &qa, &q4, 4));
        });
        ptable.row(&[
            format!("packed-i4 rowpar4 {n}³"),
            dartquant::util::fmt_duration(meas.median),
            gflops(meas.median),
            format!("{}", q4.nbytes()),
        ]);
    }

    // --- GPTQ -------------------------------------------------------------
    let w = Weights::default_synthetic(&cfg, 3);
    let seqs = corpus.calib_sequences(2, 128);
    let meas = time("gptq model", 0, 2, || {
        let _ = dartquant::quant::gptq_quantize_model(&w, &seqs, Default::default());
    });
    table.row(&[
        "GPTQ full model (tiny)".into(),
        dartquant::util::fmt_duration(meas.median),
        "-".into(),
    ]);

    table.print("§Perf — hot-path measurements");
    ptable.print("§Perf — packed quantized-weight matmul");
}
