//! Table 22 (Appendix I): end-to-end ablation of the calibration loss —
//! quant / variance / kurtosis / whip objectives through the full pipeline,
//! reporting PPL per dialect and zero-shot accuracy.

#[path = "common.rs"]
mod common;

use dartquant::calib::Objective;
use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let cfg = dartquant::model::ModelConfig::builtin("llama2-tiny").unwrap();
    let (weights, _c) = common::grammar_model(&cfg);
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    let mut table = Table::new(&["Loss", "Wiki", "PTB", "C4", "0-shot9"]);
    for obj in Objective::ALL {
        let mut pcfg = PipelineConfig::new(Method::DartQuant, BitSetting::W4A4);
        pcfg.workers = common::workers();
        pcfg.calib.objective = obj;
        pcfg.calib.steps = if common::full() { 60 } else { 30 };
        pcfg.calib_sequences = 16;
        let report = run_pipeline(&rt, &weights, &pcfg).expect("pipeline");
        let mut row = vec![obj.name().to_string()];
        for d in Dialect::ALL {
            let corpus = Corpus::new(d, cfg.vocab, 7);
            let ppl = eval::ppl_artifact(
                &rt,
                &report.weights,
                &corpus,
                spec,
                BitSetting::levels(4),
                65536.0,
                true,
            )
            .unwrap();
            row.push(fnum(ppl, 2));
        }
        let (_t, zs) = eval::zeroshot::suite_accuracy_artifact(
            &rt,
            &report.weights,
            Dialect::Wiki,
            common::zs_items(),
            256,
            99,
            BitSetting::levels(4),
            65536.0,
            true,
        )
        .unwrap();
        row.push(fnum(zs * 100.0, 2));
        table.row(&row);
    }
    table.print("Table 22 — calibration-loss ablation (llama2-tiny, W4A4, R2 via whip)");
    println!("\nnote: the R1 objective varies; R2 jobs always use whip (as in the paper).");
}
