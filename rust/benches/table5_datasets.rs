//! Table 5: DartQuant's robustness to the calibration dataset — calibrate
//! R1/R2 on each dialect, evaluate on all three. The paper's shape: the
//! three rows are nearly identical (distribution calibration does not
//! overfit the calibration set), in contrast with Table 1.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{run_pipeline, Method, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let models: Vec<&str> =
        if common::full() { vec!["llama2-tiny", "llama2-small"] } else { vec!["llama2-tiny"] };
    for name in models {
        let cfg = dartquant::model::ModelConfig::builtin(name).unwrap();
        let (weights, _c) = common::grammar_model(&cfg);
        let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
        let mut table = Table::new(&["Calib set", "Wiki", "PTB", "C4", "Avg"]);
        let mut spreads: Vec<f64> = Vec::new();
        for calib_d in Dialect::ALL {
            let mut pcfg = PipelineConfig::new(Method::DartQuant, BitSetting::W4A4);
            pcfg.workers = common::workers();
            pcfg.calib_dialect = calib_d;
            pcfg.calib.steps = if common::full() { 60 } else { 30 };
            pcfg.calib_sequences = 16;
            let report = run_pipeline(&rt, &weights, &pcfg).expect("dartquant pipeline");
            let mut row = vec![calib_d.label().to_string()];
            let mut ppls = Vec::new();
            for d in Dialect::ALL {
                let corpus = Corpus::new(d, cfg.vocab, 7);
                let ppl = eval::ppl_artifact(
                    &rt,
                    &report.weights,
                    &corpus,
                    spec,
                    BitSetting::levels(4),
                    65536.0,
                    true,
                )
                .unwrap();
                ppls.push(ppl);
                row.push(fnum(ppl, 2));
            }
            spreads.push(ppls.iter().sum::<f64>() / 3.0);
            row.push(fnum(ppls.iter().sum::<f64>() / 3.0, 2));
            table.row(&row);
        }
        table.print(&format!("Table 5 — DartQuant calibration-set robustness ({name}, W4A4)"));
        let mx = spreads.iter().cloned().fold(f64::MIN, f64::max);
        let mn = spreads.iter().cloned().fold(f64::MAX, f64::min);
        println!(
            "\nrow spread (max/min avg PPL): {:.3} — the paper's shape is ≈1.0 (rows identical)",
            mx / mn
        );
    }
}
