//! Shared bench scaffolding (criterion is unavailable offline; every bench
//! is a `harness = false` binary printing paper-style tables through
//! `util::bench::Table`).
//!
//! Environment knobs:
//!   DQ_FULL=1        run the full grid (all models / more batches) instead
//!                    of the quick default
//!   DQ_MODELS=a,b    restrict to specific configs
//!   DQ_DIALECT=wiki  calibration dialect (wiki|ptb|c4)
//!   DQ_WORKERS=n     scheduler worker threads for pipeline runs
//!                    (0/unset = available parallelism)

#![allow(dead_code)]

use dartquant::data::{Corpus, Dialect};
use dartquant::model::{ModelConfig, Weights};
use dartquant::runtime::Runtime;

pub fn runtime() -> Runtime {
    if !Runtime::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }
    Runtime::open(Runtime::default_dir()).expect("open runtime")
}

pub fn full() -> bool {
    std::env::var("DQ_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Dialect override (`DQ_DIALECT=wiki|ptb|c4`), through the shared
/// `Dialect::parse`. Drives both `grammar_model`'s grammar planting and —
/// in the benches that honor it — `PipelineConfig::calib_dialect`, so the
/// model and its calibration data stay matched.
pub fn dialect() -> Dialect {
    match std::env::var("DQ_DIALECT") {
        Ok(s) => Dialect::parse(&s).expect("DQ_DIALECT"),
        Err(_) => Dialect::Wiki,
    }
}

/// Scheduler worker threads for pipeline runs (`DQ_WORKERS=n`;
/// 0/unset = available parallelism, the `PipelineConfig` convention).
/// Panics on an unparsable value rather than silently benchmarking the
/// wrong worker count.
pub fn workers() -> usize {
    match std::env::var("DQ_WORKERS") {
        Ok(s) => s.parse().expect("DQ_WORKERS must be an integer"),
        Err(_) => 0,
    }
}

/// Models to exercise: quick mode uses the tiny + small llama2 pair, full
/// mode all five dense stand-ins.
pub fn bench_models() -> Vec<ModelConfig> {
    if let Ok(names) = std::env::var("DQ_MODELS") {
        return names
            .split(',')
            .map(|n| ModelConfig::builtin(n.trim()).expect("model name"))
            .collect();
    }
    let names: &[&str] = if full() {
        &["llama2-tiny", "llama2-small", "llama2-large", "llama3-small", "llama3-large"]
    } else {
        &["llama2-tiny", "llama3-small"]
    };
    names.iter().map(|n| ModelConfig::builtin(n).unwrap()).collect()
}

/// The standard "pretrained" model for a config: grammar planted from its
/// calibration dialect (Wiki unless DQ_DIALECT overrides), with the
/// default outlier channels.
pub fn grammar_model(cfg: &ModelConfig) -> (Weights, Corpus) {
    let corpus = Corpus::new(dialect(), cfg.vocab, 7);
    let w = Weights::default_grammar(cfg, 1, corpus.successor()).expect("grammar weights");
    (w, corpus)
}

pub fn eval_batches() -> usize {
    if full() {
        4
    } else {
        2
    }
}

pub fn zs_items() -> usize {
    if full() {
        16
    } else {
        10
    }
}
