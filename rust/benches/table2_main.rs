//! Table 2 (and appendix Tables 6–15): main results — average PPL across
//! the three dialects and the 9-task zero-shot average, for every method ×
//! bit setting. Rotations and W4 weights are bit-setting independent, so
//! each (model, method) pipeline runs once and is evaluated at 4-8-16,
//! 4-4-16 and 4-4-4. Quick mode: 2 models × 4 methods; DQ_FULL=1 runs all
//! 5 dense models × all 7 methods.

#[path = "common.rs"]
mod common;

use dartquant::coordinator::{MethodRegistry, Pipeline, PipelineConfig};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval;
use dartquant::model::BitSetting;
use dartquant::util::bench::{fnum, Table};

fn main() {
    let rt = common::runtime();
    let bit_settings = [BitSetting::W4A8, BitSetting::W4A4, BitSetting::W4A4KV4];
    // The method grid comes straight from the registry: every registered
    // spec is a row. Quick mode keeps the four headline methods.
    let registry = MethodRegistry::builtin();
    let methods: Vec<String> = if common::full() {
        registry.names().iter().map(|n| n.to_string()).collect()
    } else {
        vec!["rtn".into(), "quarot".into(), "spinquant".into(), "dartquant".into()]
    };

    for cfg in common::bench_models() {
        let (weights, _corpus) = common::grammar_model(&cfg);
        // Wiki is the model's own dialect (the paper's models fit all
        // three eval sets; ours fit one) — method ordering reads off the
        // Wiki column; avg3 is reported for completeness but mismatched
        // dialects add noise there.
        let mut table = Table::new(&["Bits", "Method", "Wiki PPL", "PPL(avg3)", "0-shot9"]);
        let (wiki, ppl, zs) = eval_cell(&rt, &weights, BitSetting::FP, false);
        table.row(&["16-16-16".into(), "FloatingPoint".into(), fnum(wiki, 2), fnum(ppl, 2), fnum(zs, 2)]);

        for m in &methods {
            let mut pcfg = PipelineConfig::new(dartquant::coordinator::Method::DartQuant, BitSetting::W4A4);
            pcfg.workers = common::workers();
            pcfg.calib_dialect = common::dialect();
            pcfg.calib_sequences = if common::full() { 32 } else { 16 };
            pcfg.calib.steps = if common::full() { 60 } else { 25 };
            pcfg.spin.steps = if common::full() { 12 } else { 6 };
            let run = Pipeline::builder(&weights)
                .config(pcfg)
                .method_in(&registry, m)
                .and_then(|b| b.run(&rt));
            let report = match run {
                Ok(r) => r,
                Err(e) => {
                    table.row(&["*".into(), m.clone(), "-".into(), format!("err: {e}"), "-".into()]);
                    continue;
                }
            };
            let use_had = report.rotation.as_ref().map(|r| r.online_had).unwrap_or(false);
            for bits in bit_settings {
                let (wiki, ppl, zs) = eval_cell(&rt, &report.weights, bits, use_had);
                table.row(&[bits.label(), report.method.clone(), fnum(wiki, 2), fnum(ppl, 2), fnum(zs, 2)]);
            }
        }
        table.print(&format!("Table 2 — {} ({})", cfg.name, cfg.paper_name()));
    }
}

fn eval_cell(
    rt: &dartquant::runtime::Runtime,
    w: &dartquant::model::Weights,
    bits: BitSetting,
    use_had: bool,
) -> (f64, f64, f64) {
    let spec = eval::EvalSpec { batch: 8, seq: 256, n_batches: common::eval_batches() };
    let (a, kv) = (BitSetting::levels(bits.a), BitSetting::levels(bits.kv));
    let mut total = 0.0;
    let mut wiki = 0.0;
    for d in Dialect::ALL {
        let corpus = Corpus::new(d, w.cfg.vocab, 7);
        let p = eval::ppl_artifact(rt, w, &corpus, spec, a, kv, use_had).expect("ppl");
        if d == Dialect::Wiki {
            wiki = p;
        }
        total += p;
    }
    let (_tasks, zs) = eval::zeroshot::suite_accuracy_artifact(
        rt, w, Dialect::Wiki, common::zs_items(), 256, 99, a, kv, use_had,
    )
    .expect("zeroshot");
    (wiki, total / 3.0, zs * 100.0)
}
