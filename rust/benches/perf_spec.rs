//! §Perf: self-speculative decoding bench (`serve::spec`) — accept rate,
//! per-precision step cost, and effective tokens/round when a packed
//! W4A4 draft of the checkpoint proposes for an A8 verifier.
//!
//! Per model × k ∈ {2, 4, 8}:
//!
//! * greedy speculative decode is asserted token-for-token identical to
//!   the verifier decoding alone *before any number is reported* — the
//!   correctness contract `rust/tests/spec.rs` gates,
//! * a draft ≡ verifier pair is asserted to accept 100% of proposals
//!   (the protocol's self-consistency acceptance),
//! * reported: accept rate, effective tokens/round, per-token step µs
//!   for each precision alone, and end-to-end decode speedup over the
//!   plain verifier.
//!
//! Runs natively (no artifacts); honors `DQ_MODELS` / `DQ_FULL`, and
//! writes `BENCH_spec.json` when `DQ_BENCH_JSON` is set.

#[path = "common.rs"]
mod common;

use dartquant::model::{FwdOptions, Weights};
use dartquant::serve::{sample_logits, DecodeSession, SpecSession};
use dartquant::util::bench::{fnum, write_receipt, Table};
use dartquant::util::json::Json;
use dartquant::util::prng::Pcg64;
use std::sync::Arc;
use std::time::Instant;

const KS: [usize; 3] = [2, 4, 8];

/// Plain greedy decode: the oracle stream plus its per-token step cost
/// (prefill excluded — speculation changes nothing about the prefill).
fn plain_decode(
    weights: &Arc<Weights>,
    opt: FwdOptions,
    prompt: &[i32],
    max_new: usize,
) -> (Vec<i32>, f64, f64) {
    let mut sess = DecodeSession::new(Arc::clone(weights), opt);
    let row = sess.prefill_last(prompt);
    let t0 = Instant::now();
    let mut tok = sample_logits(&row, 0.0, &mut Pcg64::new(0)) as i32;
    let mut out = vec![tok];
    while out.len() < max_new {
        let next = sess.step(tok);
        tok = sample_logits(&next, 0.0, &mut Pcg64::new(0)) as i32;
        out.push(tok);
    }
    let wall = t0.elapsed().as_secs_f64();
    (out, wall, wall * 1e6 / (max_new.saturating_sub(1).max(1)) as f64)
}

fn main() {
    let max_new = if common::full() { 64 } else { 32 };
    let mut table = Table::new(&[
        "model",
        "k",
        "accept",
        "tok/round",
        "rounds",
        "draft µs/tok",
        "verify µs/tok",
        "plain tok/s",
        "spec tok/s",
        "speedup",
    ]);
    let mut receipt_rows: Vec<Json> = Vec::new();
    let mut worst_accept = f64::INFINITY;
    let mut best_speedup = 0.0f64;

    for cfg in common::bench_models() {
        let (w, corpus) = common::grammar_model(&cfg);
        let verifier = Arc::new(w);
        let draft = Arc::new(dartquant::quant::rtn_quantize_model_packed(&verifier, 4));
        let vopt = FwdOptions::quant(8, 4, false);
        let dopt = FwdOptions::quant(4, 4, false);
        let prompt = corpus.sequence(24, 2, 0);

        let (oracle, plain_wall, verify_us) = plain_decode(&verifier, vopt, &prompt, max_new);
        let (_, _, draft_us) = plain_decode(&draft, dopt, &prompt, max_new);

        // Protocol self-consistency: a draft at the verifier's own
        // precision must accept every proposal.
        let mut same = SpecSession::new(
            DecodeSession::new(Arc::clone(&verifier), vopt),
            DecodeSession::new(Arc::clone(&verifier), vopt),
            4,
        );
        let out = same
            .generate(&prompt, max_new, 0.0, &mut Pcg64::new(0))
            .expect("identity speculation");
        assert_eq!(out, oracle, "{}: identity pair diverged from plain decode", cfg.name);
        let s = same.stats();
        assert_eq!(s.accepted, s.proposed, "{}: identity pair rejected a proposal", cfg.name);

        for k in KS {
            let mut spec = SpecSession::new(
                DecodeSession::new(Arc::clone(&draft), dopt),
                DecodeSession::new(Arc::clone(&verifier), vopt),
                k,
            );
            let t0 = Instant::now();
            let out = spec
                .generate(&prompt, max_new, 0.0, &mut Pcg64::new(0))
                .expect("speculative decode");
            let spec_wall = t0.elapsed().as_secs_f64();
            assert_eq!(
                out, oracle,
                "{} k={k}: speculative stream diverged from the verifier's",
                cfg.name
            );
            let stats = spec.stats();
            let speedup = plain_wall / spec_wall;
            worst_accept = worst_accept.min(stats.accept_rate());
            best_speedup = best_speedup.max(speedup);
            table.row(&[
                cfg.name.clone(),
                k.to_string(),
                format!("{:.0}%", 100.0 * stats.accept_rate()),
                fnum(stats.tokens_per_round(), 2),
                stats.rounds.to_string(),
                fnum(draft_us, 1),
                fnum(verify_us, 1),
                fnum(max_new as f64 / plain_wall, 0),
                fnum(max_new as f64 / spec_wall, 0),
                fnum(speedup, 2),
            ]);
            receipt_rows.push(Json::obj(vec![
                ("model", Json::Str(cfg.name.clone())),
                ("k", Json::Num(k as f64)),
                ("accept_rate", Json::Num(stats.accept_rate())),
                ("tokens_per_round", Json::Num(stats.tokens_per_round())),
                ("rounds", Json::Num(stats.rounds as f64)),
                ("plain_steps", Json::Num(stats.plain_steps as f64)),
                ("draft_step_us", Json::Num(draft_us)),
                ("verify_step_us", Json::Num(verify_us)),
                ("plain_tok_s", Json::Num(max_new as f64 / plain_wall)),
                ("spec_tok_s", Json::Num(max_new as f64 / spec_wall)),
                ("speedup", Json::Num(speedup)),
            ]));
        }
    }

    table.print(&format!(
        "perf_spec — self-speculative decode, packed W4A4 draft vs A8 verifier ({max_new} tokens)"
    ));
    println!(
        "\nacceptance: every speculative stream above was asserted token-identical to the\n\
         plain verifier's, and a draft ≡ verifier pair accepted 100% of proposals.\n\
         worst accept rate {} | best end-to-end speedup {}x",
        fnum(100.0 * worst_accept, 0),
        fnum(best_speedup, 2)
    );

    write_receipt(
        "spec",
        &Json::obj(vec![
            ("bench", Json::Str("perf_spec".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("max_new", Json::Num(max_new as f64)),
            ("worst_accept_rate", Json::Num(worst_accept)),
            ("best_speedup", Json::Num(best_speedup)),
            ("runs", Json::Arr(receipt_rows)),
        ]),
    );
}
