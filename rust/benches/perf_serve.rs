//! §Perf: paged-KV serving bench — sessions/GB, prefix-page hit rate,
//! and p99 step latency for the paged cache (`serve::pager`) vs the
//! contiguous baseline, under the same `--budget`-style gate.
//!
//! Two seeded scenarios per model:
//!
//! * `zipf-tail` — unique prompts, heavy-tailed (Zipf) continuation
//!   lengths: page-granular charging alone admits more concurrent
//!   sessions than full-lifetime reservation, because short sessions
//!   never pay for their worst case.
//! * `shared-prefix` — every session opens with the same system prompt:
//!   prefix pages are mapped once and shared, compounding with paging.
//!   Acceptance: ≥ 2× the contiguous baseline's peak concurrent
//!   sessions under the same budget.
//!
//! Both scenarios assert the paged token streams are identical to the
//! contiguous oracle's before reporting any number. Runs natively (no
//! artifacts); honors `DQ_MODELS` / `DQ_FULL` / `DQ_WORKERS`, and
//! writes `BENCH_serve.json` when `DQ_BENCH_JSON` is set.

#[path = "common.rs"]
mod common;

use dartquant::serve::{BatchEngine, EngineConfig, GenRequest, GenResult, PagedConfig};
use dartquant::util::bench::{fnum, percentile, write_receipt, Table};
use dartquant::util::json::Json;
use dartquant::util::mem::gib;
use dartquant::util::prng::{Pcg64, Zipf};
use std::sync::Arc;
use std::time::Instant;

const PAGE_POSITIONS: usize = 16;

/// One engine run: drive step-by-step so per-step latency is visible.
struct RunStats {
    results: Vec<GenResult>,
    peak_concurrent: usize,
    peak_bytes: u64,
    steps: usize,
    p99_step_us: f64,
    wall_s: f64,
    prefix_hit_rate: Option<f64>,
    spilled_pages: u64,
}

/// The first request is submitted alone and stepped once before the rest
/// arrive — a warm cache, so shared-prefix scenarios have registered
/// prompt pages to hit (admission-time sharing needs a prior prefill).
/// Token streams are schedule-independent, so the oracle comparison is
/// unaffected as long as both modes use the same arrival order.
fn drive(mut engine: BatchEngine, reqs: &[GenRequest]) -> RunStats {
    let mut step_us: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    engine.submit(reqs[0].clone());
    engine.step().expect("warmup step");
    for r in &reqs[1..] {
        engine.submit(r.clone());
    }
    let mut seen = engine.steps();
    loop {
        let s0 = Instant::now();
        let more = engine.step().expect("engine step");
        // Idle admission-only ticks don't advance the step counter and
        // are excluded from the latency distribution.
        if engine.steps() > seen {
            seen = engine.steps();
            step_us.push(s0.elapsed().as_secs_f64() * 1e6);
        }
        if !more {
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    step_us.sort_by(f64::total_cmp);
    let p99_step_us = percentile(&step_us, 0.99).unwrap_or(0.0);
    let mut results = engine.results().to_vec();
    results.sort_by_key(|r| r.id);
    RunStats {
        results,
        peak_concurrent: engine.peak_concurrent(),
        peak_bytes: engine.peak_cache_bytes(),
        steps: engine.steps(),
        p99_step_us,
        wall_s,
        prefix_hit_rate: engine.pager_stats().map(|s| s.prefix_hit_rate()),
        spilled_pages: engine.pager_stats().map(|s| s.spilled_pages).unwrap_or(0),
    }
}

fn main() {
    let sessions = if common::full() { 24 } else { 12 };
    let mut table = Table::new(&[
        "model",
        "scenario",
        "mode",
        "sessions",
        "peak conc",
        "sess/GB",
        "p99 step µs",
        "prefix hit",
        "spilled",
        "wall (s)",
    ]);
    let mut receipt_scenarios: Vec<Json> = Vec::new();
    let mut headline_ratio = f64::INFINITY;
    let mut headline_hit = 0.0f64;
    let mut headline_p99 = 0.0f64;

    for cfg in common::bench_models() {
        let (w, corpus) = common::grammar_model(&cfg);
        let weights = Arc::new(w);
        let kv_levels = dartquant::model::FwdOptions::quant(4, 4, false).kv_levels;

        // Heavy-tailed continuation lengths, seeded: rank 0 is the
        // common short chat turn, the tail the rare long generation.
        let zipf = Zipf::new(24, 1.1);
        let mut rng = Pcg64::new(42);
        let lengths: Vec<usize> = (0..sessions).map(|_| 4 + 2 * zipf.sample(&mut rng)).collect();

        let system_prompt = corpus.sequence(3 * PAGE_POSITIONS, 2, 99);
        let scenarios: [(&str, Vec<GenRequest>); 2] = [
            (
                "zipf-tail",
                (0..sessions)
                    .map(|i| GenRequest {
                        prompt: corpus.sequence(24, 2, i as u64),
                        max_new: lengths[i],
                    })
                    .collect(),
            ),
            (
                "shared-prefix",
                (0..sessions)
                    .map(|i| {
                        let mut prompt = system_prompt.clone();
                        prompt.extend(corpus.sequence(4, 2, 1000 + i as u64));
                        GenRequest { prompt, max_new: lengths[i] }
                    })
                    .collect(),
            ),
        ];

        for (scenario, reqs) in scenarios {
            // Budget: every session must fit alone (no rejections — the
            // runs must decode identical streams), but far below the sum
            // of full-lifetime reservations, so admission policy is what
            // differs. ~3 average contiguous sessions' worth.
            let per_session: Vec<u64> = reqs
                .iter()
                .map(|r| {
                    dartquant::serve::request_cache_bytes(
                        &cfg,
                        kv_levels,
                        r.prompt.len(),
                        r.max_new,
                    )
                })
                .collect();
            let max_one = *per_session.iter().max().expect("non-empty");
            let avg = per_session.iter().sum::<u64>() / per_session.len() as u64;
            // Paged sessions round up to page granularity; double the
            // worst case so neither mode ever rejects.
            let budget = (2 * max_one).max(3 * avg);

            let ecfg = EngineConfig {
                opt: dartquant::model::FwdOptions::quant(4, 4, false),
                workers: common::workers(),
                budget: Some(budget),
                ..EngineConfig::default()
            };
            let contiguous = drive(BatchEngine::new(Arc::clone(&weights), ecfg), &reqs);
            let paged = drive(
                BatchEngine::new(
                    Arc::clone(&weights),
                    EngineConfig {
                        paged: Some(PagedConfig {
                            page_positions: PAGE_POSITIONS,
                            spill: true,
                        }),
                        ..ecfg
                    },
                ),
                &reqs,
            );
            assert_eq!(
                contiguous.results, paged.results,
                "{} {scenario}: paged decode diverged from the contiguous oracle",
                cfg.name
            );

            let spg = |r: &RunStats| r.peak_concurrent as f64 / gib(budget);
            let ratio = spg(&paged) / spg(&contiguous);
            let mut row = |mode: &str, r: &RunStats| {
                table.row(&[
                    cfg.name.clone(),
                    scenario.to_string(),
                    mode.to_string(),
                    sessions.to_string(),
                    r.peak_concurrent.to_string(),
                    fnum(spg(r), 0),
                    fnum(r.p99_step_us, 1),
                    r.prefix_hit_rate
                        .map(|h| format!("{:.0}%", 100.0 * h))
                        .unwrap_or_else(|| "-".into()),
                    r.spilled_pages.to_string(),
                    fnum(r.wall_s, 3),
                ]);
            };
            row("contiguous", &contiguous);
            row("paged+spill", &paged);

            if scenario == "shared-prefix" {
                headline_ratio = headline_ratio.min(ratio);
                headline_hit = paged.prefix_hit_rate.unwrap_or(0.0);
                headline_p99 = paged.p99_step_us;
            }
            let run_json = |r: &RunStats| {
                Json::obj(vec![
                    ("peak_concurrent", Json::Num(r.peak_concurrent as f64)),
                    ("sessions_per_gb", Json::Num(spg(r))),
                    ("p99_step_us", Json::Num(r.p99_step_us)),
                    ("peak_gate_bytes", Json::Num(r.peak_bytes as f64)),
                    ("steps", Json::Num(r.steps as f64)),
                    ("spilled_pages", Json::Num(r.spilled_pages as f64)),
                ])
            };
            receipt_scenarios.push(Json::obj(vec![
                ("model", Json::Str(cfg.name.clone())),
                ("scenario", Json::Str(scenario.to_string())),
                ("sessions", Json::Num(sessions as f64)),
                ("budget_bytes", Json::Num(budget as f64)),
                ("contiguous", run_json(&contiguous)),
                ("paged", run_json(&paged)),
                (
                    "prefix_hit_rate",
                    Json::Num(paged.prefix_hit_rate.unwrap_or(0.0)),
                ),
                ("sessions_per_gb_ratio", Json::Num(ratio)),
            ]));
        }
    }

    table.print(&format!(
        "perf_serve — paged KV vs contiguous under one budget (P={PAGE_POSITIONS}, workers {})",
        common::workers()
    ));
    println!(
        "\nacceptance: shared-prefix sessions/GB ratio (paged/contiguous) = {} — must be ≥ 2,\n\
         with bit-identical token streams (asserted above) at every page size.",
        fnum(headline_ratio, 2)
    );
    assert!(
        headline_ratio >= 2.0,
        "shared-prefix paged mode admitted only {headline_ratio:.2}x the contiguous sessions"
    );

    write_receipt(
        "serve",
        &Json::obj(vec![
            ("bench", Json::Str("perf_serve".into())),
            ("provenance", Json::Str("measured (make bench-json)".into())),
            ("workers", Json::Num(common::workers() as f64)),
            ("page_positions", Json::Num(PAGE_POSITIONS as f64)),
            ("sessions_per_gb_ratio_shared_prefix", Json::Num(headline_ratio)),
            ("prefix_hit_rate", Json::Num(headline_hit)),
            ("p99_step_us_paged", Json::Num(headline_p99)),
            ("scenarios", Json::Arr(receipt_scenarios)),
        ]),
    );
}
