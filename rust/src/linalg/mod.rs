//! Dense linear-algebra substrate: Householder QR (the QR-Orth projection,
//! mirroring the paper's Algorithm 2), Cholesky (GPTQ's Hessian inverse),
//! Hadamard matrix constructions (QuaRot/R3/R4 baselines), and orthogonality
//! utilities.

mod cholesky;
mod hadamard;
mod qr;

pub use cholesky::{cholesky, cholesky_inverse};
pub use hadamard::{fwht_row, fwht_rows, hadamard_matrix, hadamard_supported, randomized_hadamard};
pub use qr::{householder_qr, qr_orthogonalize};

use crate::tensor::Mat;
use crate::util::prng::Pcg64;

/// max |QᵀQ − I| — the orthogonality defect used by tests and calibration
/// sanity checks.
pub fn orthogonality_defect(q: &Mat) -> f32 {
    assert_eq!(q.rows, q.cols);
    let qtq = crate::tensor::matmul(&q.t(), q);
    qtq.max_abs_diff(&Mat::eye(q.rows))
}

/// Random orthogonal matrix: QR of a Gaussian matrix with the sign-fixed Q
/// (Haar-ish; exact Haar needs the sign fix we apply).
pub fn random_orthogonal(n: usize, rng: &mut Pcg64) -> Mat {
    let z = Mat::from_fn(n, n, |_, _| rng.normal());
    qr_orthogonalize(&z)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Pcg64::new(1);
        for n in [2usize, 3, 17, 64] {
            let q = random_orthogonal(n, &mut rng);
            assert!(orthogonality_defect(&q) < 2e-4, "n={n}");
        }
    }

    #[test]
    fn orthogonality_defect_detects_nonorthogonal() {
        let m = Mat::from_fn(4, 4, |i, j| if i == j { 2.0 } else { 0.0 });
        assert!(orthogonality_defect(&m) > 1.0);
    }
}
