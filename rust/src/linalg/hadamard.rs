//! Hadamard transforms — the rotation workhorse of QuaRot and the online
//! R3/R4 transforms of the DartQuant inference graph (Appendix A).
//!
//! Supported orders: n = m·2^k with m ∈ {1, 12, 20}. The 12 and 20 bases
//! come from the Paley-I construction (q = 11, 19 ≡ 3 mod 4), matching the
//! had12/had20 blocks QuaRot uses for non-power-of-two LLM dims.
//! All matrices returned are **orthonormal** (scaled by 1/√n) so they are
//! valid rotation matrices R with R·Rᵀ = I.

use crate::tensor::{matmul, Mat};
use crate::util::prng::Pcg64;

/// In-place fast Walsh–Hadamard transform of one row (len must be 2^k),
/// normalized by 1/√n — i.e. multiplication by the orthonormal H_{2^k}.
pub fn fwht_row(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT needs a power-of-two length, got {n}");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= scale;
    }
}

/// Apply the orthonormal Hadamard transform of order `cols` to every row.
/// Fast butterfly path for powers of two; dense multiply for 12·2^k / 20·2^k.
pub fn fwht_rows(x: &mut Mat) {
    if x.cols.is_power_of_two() {
        for i in 0..x.rows {
            fwht_row(x.row_mut(i));
        }
    } else {
        let h = hadamard_matrix(x.cols);
        *x = matmul(x, &h);
    }
}

/// Whether an orthonormal Hadamard of this order is constructible here.
pub fn hadamard_supported(n: usize) -> bool {
    if n == 0 {
        return false;
    }
    let mut m = n;
    while m % 2 == 0 {
        m /= 2;
    }
    matches!(m, 1 | 3 | 5) && n % 4 == 0 || m == 1
    // m==3 → 12·2^k (k≥2 folded into the evenness check), m==5 → 20·2^k.
}

/// Legendre symbol χ(a) in GF(q), χ(0) = 0.
fn legendre(a: i64, q: i64) -> i64 {
    let a = a.rem_euclid(q);
    if a == 0 {
        return 0;
    }
    // Euler's criterion by fast modular exponentiation.
    let mut base = a;
    let mut exp = (q - 1) / 2;
    let mut acc = 1i64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = acc * base % q;
        }
        base = base * base % q;
        exp >>= 1;
    }
    if acc == 1 {
        1
    } else {
        -1
    }
}

/// Paley-I Hadamard matrix of order q+1 (entries ±1), q ≡ 3 mod 4 prime.
fn paley1(q: i64) -> Mat {
    let n = (q + 1) as usize;
    // S[0][j]=1 (j≥1), S[i][0]=-1 (i≥1), S[i][j]=χ(i-j), H = S + I.
    Mat::from_fn(n, n, |i, j| {
        let s = if i == 0 && j == 0 {
            0
        } else if i == 0 {
            1
        } else if j == 0 {
            -1
        } else {
            legendre(i as i64 - j as i64, q)
        };
        (s + if i == j { 1 } else { 0 }) as f32
    })
}

/// Orthonormal Hadamard matrix of order n = m·2^k, m ∈ {1, 12, 20}.
pub fn hadamard_matrix(n: usize) -> Mat {
    assert!(n > 0);
    let mut m = n;
    let mut k = 0u32;
    while m % 2 == 0 {
        m /= 2;
        k += 1;
    }
    let base = match (m, n) {
        (1, _) => Mat::from_vec(1, 1, vec![1.0]),
        (3, _) if n % 12 == 0 => {
            // reinterpret factorization as 12 · 2^(k-2)
            k -= 2;
            paley1(11)
        }
        (5, _) if n % 20 == 0 => {
            k -= 2;
            paley1(19)
        }
        _ => panic!("no Hadamard construction for order {n} (need m·2^k, m ∈ {{1,12,20}})"),
    };
    // Sylvester doubling: H_{2s} = [[H, H], [H, -H]].
    let mut h = base;
    for _ in 0..k {
        let s = h.rows;
        let mut h2 = Mat::zeros(2 * s, 2 * s);
        for i in 0..s {
            for j in 0..s {
                let v = h.at(i, j);
                *h2.at_mut(i, j) = v;
                *h2.at_mut(i, j + s) = v;
                *h2.at_mut(i + s, j) = v;
                *h2.at_mut(i + s, j + s) = -v;
            }
        }
        h = h2;
    }
    assert_eq!(h.rows, n);
    let scale = 1.0 / (n as f32).sqrt();
    h.scale(scale);
    h
}

/// QuaRot-style randomized Hadamard rotation: H · diag(s), s ∈ {±1}ⁿ.
/// Still orthogonal; the random signs decorrelate it from weight structure.
pub fn randomized_hadamard(n: usize, rng: &mut Pcg64) -> Mat {
    let mut h = hadamard_matrix(n);
    for j in 0..n {
        if rng.below(2) == 1 {
            for i in 0..n {
                *h.at_mut(i, j) = -h.at(i, j);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;
    use crate::util::propcheck::{gen, Runner};

    #[test]
    fn legendre_basics() {
        // QRs mod 11: {1,3,4,5,9}
        for (a, want) in [(1, 1), (3, 1), (4, 1), (5, 1), (9, 1), (2, -1), (6, -1), (0, 0)] {
            assert_eq!(legendre(a, 11), want, "χ({a}) mod 11");
        }
    }

    #[test]
    fn paley_bases_are_hadamard() {
        for q in [11i64, 19] {
            let h = paley1(q);
            let n = h.rows;
            // entries ±1 and H·Hᵀ = n·I
            assert!(h.data.iter().all(|&v| v == 1.0 || v == -1.0));
            let hht = matmul(&h, &h.t());
            let mut scaled = Mat::eye(n);
            scaled.scale(n as f32);
            assert!(hht.max_abs_diff(&scaled) < 1e-3, "q={q}");
        }
    }

    #[test]
    fn orthonormal_for_all_supported_orders() {
        for n in [1usize, 2, 4, 8, 64, 128, 12, 24, 48, 96, 768, 20, 40, 320, 1280] {
            let h = hadamard_matrix(n);
            assert!(orthogonality_defect(&h) < 5e-4, "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "no Hadamard construction")]
    fn unsupported_order_panics() {
        let _ = hadamard_matrix(36); // 9·4 — m=9 unsupported
    }

    #[test]
    fn fwht_matches_dense_matrix() {
        let mut rng = crate::util::prng::Pcg64::new(1);
        for n in [2usize, 8, 64, 256] {
            let x = Mat::from_fn(3, n, |_, _| rng.normal());
            let mut fast = x.clone();
            fwht_rows(&mut fast);
            let dense = matmul(&x, &hadamard_matrix(n));
            // FWHT computes x·H with H symmetric for Sylvester matrices.
            assert!(fast.max_abs_diff(&dense) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn fwht_rows_dense_path_for_had12() {
        let mut rng = crate::util::prng::Pcg64::new(2);
        let x = Mat::from_fn(4, 24, |_, _| rng.normal());
        let mut y = x.clone();
        fwht_rows(&mut y);
        let before: f32 = x.fro_norm();
        assert!((y.fro_norm() - before).abs() < 1e-3, "norm preserved");
    }

    #[test]
    fn prop_fwht_is_norm_preserving_involution() {
        Runner::new().cases(24).run("fwht involution", |rng| {
            let k = gen::size(rng, 1, 8);
            let n = 1usize << k;
            let x = gen::activations(rng, n);
            let mut y = x.clone();
            fwht_row(&mut y);
            let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            let n2: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
            if (n1 - n2).abs() > 1e-2 * n1.max(1.0) {
                return Err(format!("norm {n1} -> {n2}"));
            }
            // Sylvester H is symmetric and orthonormal ⇒ H·H = I.
            fwht_row(&mut y);
            let d = x.iter().zip(&y).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            if d < 1e-2 {
                Ok(())
            } else {
                Err(format!("involution defect {d}"))
            }
        });
    }

    #[test]
    fn randomized_hadamard_is_orthogonal_and_random() {
        let mut rng = crate::util::prng::Pcg64::new(3);
        let a = randomized_hadamard(64, &mut rng);
        let b = randomized_hadamard(64, &mut rng);
        assert!(orthogonality_defect(&a) < 5e-4);
        assert!(a.max_abs_diff(&b) > 0.01, "different sign draws");
    }

    #[test]
    fn supported_predicate_matches_constructor() {
        for n in 1..=64usize {
            let ok = std::panic::catch_unwind(|| hadamard_matrix(n)).is_ok();
            assert_eq!(
                hadamard_supported(n),
                ok,
                "hadamard_supported({n}) disagrees with constructor"
            );
        }
    }
}
