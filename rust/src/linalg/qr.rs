//! Householder QR — the paper's Algorithm 2, and the rust-native mirror of
//! the jnp scan implementation in `python/compile/model.py` (the AOT HLO
//! path). Both sign-canonicalize Q so that diag(R) ≥ 0, making the rust and
//! jax factors directly comparable in integration tests.
//!
//! Cost: ≈ 4/3·n³ FLOPs (Appendix B.1), vs the ≈6n³ overhead of a Cayley
//! step (Appendix B.2) — the asymmetry QR-Orth exploits.

use crate::tensor::Mat;

/// Full QR of a square matrix via Householder reflections.
/// Returns (Q, R) with A = Q·R, Q orthogonal, R upper-triangular with
/// non-negative diagonal (sign-canonical form).
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    assert_eq!(a.rows, a.cols, "square QR only (rotation matrices)");
    let n = a.rows;
    let mut r = a.clone();
    let mut qt = Mat::eye(n); // accumulates H_{n-1}…H_0 = Qᵀ
    let mut v = vec![0.0f32; n];

    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut norm2 = 0.0f32;
        for i in k..n {
            let x = r.at(i, k);
            v[i] = x;
            norm2 += x * x;
        }
        let alpha = norm2.sqrt();
        if alpha < 1e-30 {
            continue; // column already zero below diagonal
        }
        let sign = if v[k] >= 0.0 { 1.0 } else { -1.0 };
        v[k] += sign * alpha;
        let vnorm2: f32 = v[k..n].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-30 {
            continue;
        }
        let inv = 2.0 / vnorm2;

        // R <- (I - 2vvᵀ/‖v‖²) R, only columns k..n are affected.
        for j in k..n {
            let mut dot = 0.0f32;
            for i in k..n {
                dot += v[i] * r.at(i, j);
            }
            let dot = dot * inv;
            for i in k..n {
                *r.at_mut(i, j) -= dot * v[i];
            }
        }
        // Qᵀ <- (I - 2vvᵀ/‖v‖²) Qᵀ, all columns affected.
        for j in 0..n {
            let mut dot = 0.0f32;
            for i in k..n {
                dot += v[i] * qt.at(i, j);
            }
            let dot = dot * inv;
            for i in k..n {
                *qt.at_mut(i, j) -= dot * v[i];
            }
        }
    }

    // Sign canonicalization: flip columns of Q (rows of Qᵀ) so diag(R) ≥ 0.
    for k in 0..n {
        if r.at(k, k) < 0.0 {
            for j in k..n {
                *r.at_mut(k, j) = -r.at(k, j);
            }
            for j in 0..n {
                *qt.at_mut(k, j) = -qt.at(k, j);
            }
        }
        // Zero the strictly-lower triangle exactly (numerical dust).
        for i in (k + 1)..n {
            *r.at_mut(i, k) = 0.0;
        }
    }

    (qt.t(), r)
}

/// The QR-Orth projection: latent Z ↦ orthogonal R = qr(Z).Q.
pub fn qr_orthogonalize(z: &Mat) -> Mat {
    householder_qr(z).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;
    use crate::tensor::matmul;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    #[test]
    fn reconstructs_a() {
        let mut rng = Pcg64::new(1);
        for n in [1usize, 2, 5, 32, 64] {
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            let (q, r) = householder_qr(&a);
            let d = matmul(&q, &r).max_abs_diff(&a);
            assert!(d < 1e-3 * (n as f32).sqrt(), "n={n} d={d}");
        }
    }

    #[test]
    fn q_is_orthogonal_r_is_upper() {
        let mut rng = Pcg64::new(2);
        let a = Mat::from_fn(48, 48, |_, _| rng.normal());
        let (q, r) = householder_qr(&a);
        assert!(orthogonality_defect(&q) < 2e-4);
        for i in 0..48 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
            assert!(r.at(i, i) >= 0.0, "sign-canonical diag");
        }
    }

    #[test]
    fn identity_fixed_point() {
        let (q, r) = householder_qr(&Mat::eye(8));
        assert!(q.max_abs_diff(&Mat::eye(8)) < 1e-6);
        assert!(r.max_abs_diff(&Mat::eye(8)) < 1e-6);
    }

    #[test]
    fn handles_rank_deficient_without_nan() {
        // Two identical columns.
        let a = Mat::from_fn(4, 4, |i, j| if j < 2 { (i + 1) as f32 } else { (i * j) as f32 });
        let (q, r) = householder_qr(&a);
        assert!(q.data.iter().all(|x| x.is_finite()));
        assert!(matmul(&q, &r).max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn prop_qr_orthogonalize_always_orthogonal() {
        Runner::new().cases(32).run("qr orth", |rng| {
            let n = gen::size(rng, 2, 40);
            let z = Mat::from_vec(n, n, gen::vec_f32(rng, n * n));
            let q = qr_orthogonalize(&z);
            let d = orthogonality_defect(&q);
            if d < 5e-4 {
                Ok(())
            } else {
                Err(format!("defect {d} at n={n}"))
            }
        });
    }

    #[test]
    fn prop_rotation_preserves_norms() {
        Runner::new().cases(32).run("norm invariance", |rng| {
            let n = gen::size(rng, 2, 32);
            let z = Mat::from_vec(n, n, gen::vec_f32(rng, n * n));
            let q = qr_orthogonalize(&z);
            let x = Mat::from_vec(1, n, gen::activations(rng, n));
            let xr = matmul(&x, &q);
            let a = x.fro_norm();
            let b = xr.fro_norm();
            if (a - b).abs() <= 1e-3 * a.max(1.0) {
                Ok(())
            } else {
                Err(format!("‖x‖={a} vs ‖xR‖={b}"))
            }
        });
    }
}
