//! Cholesky factorization + SPD inverse — the numerical core GPTQ needs
//! (H⁻¹ of the dampened activation Hessian, consumed column-by-column).

use crate::tensor::Mat;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Returns None if A is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // Accumulate in f64: GPTQ Hessians are ill-conditioned and f32
            // accumulation loses PD-ness at n ≥ a few hundred.
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = (sum.sqrt()) as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ·L⁻¹.
pub fn cholesky_inverse(a: &Mat) -> Option<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Forward-solve L·X = I → X = L⁻¹ (lower triangular).
    let mut linv = Mat::zeros(n, n);
    for col in 0..n {
        for i in col..n {
            let mut sum = if i == col { 1.0f64 } else { 0.0 };
            for k in col..i {
                sum -= l.at(i, k) as f64 * linv.at(k, col) as f64;
            }
            *linv.at_mut(i, col) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    // A⁻¹ = L⁻ᵀ L⁻¹.
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0f64;
            for k in i.max(j)..n {
                sum += linv.at(k, i) as f64 * linv.at(k, j) as f64;
            }
            *inv.at_mut(i, j) = sum as f32;
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::prng::Pcg64;

    fn random_spd(n: usize, rng: &mut Pcg64) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = matmul(&b.t(), &b);
        for i in 0..n {
            *a.at_mut(i, i) += n as f32 * 0.1; // damp
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::new(1);
        for n in [1usize, 2, 8, 33] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).expect("SPD");
            let d = matmul(&l, &l.t()).max_abs_diff(&a);
            assert!(d < 1e-3 * n as f32, "n={n} d={d}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Pcg64::new(2);
        for n in [1usize, 3, 16, 40] {
            let a = random_spd(n, &mut rng);
            let inv = cholesky_inverse(&a).expect("SPD");
            let d = matmul(&a, &inv).max_abs_diff(&Mat::eye(n));
            assert!(d < 5e-3, "n={n} d={d}");
        }
    }

    #[test]
    fn factor_is_lower_triangular() {
        let mut rng = Pcg64::new(3);
        let a = random_spd(6, &mut rng);
        let l = cholesky(&a).unwrap();
        for i in 0..6 {
            for j in (i + 1)..6 {
                assert_eq!(l.at(i, j), 0.0);
            }
            assert!(l.at(i, i) > 0.0);
        }
    }
}
