//! The `dqlint::allow` suppression engine.
//!
//! A lint hit is suppressed per-site with a comment directive:
//!
//! ```text
//! // dqlint::allow(<lint-name>): <reason>
//! ```
//!
//! The directive suppresses matching diagnostics on its own line
//! (trailing form) and, when it sits on a line with no code of its own,
//! on the next code line below (stacked directives and blank lines in
//! between are fine). The reason is mandatory: a bare
//! `dqlint::allow(<lint>)` — or one naming an unknown lint — is itself
//! a [`Lint::BadAllow`] error, so every suppression in the tree carries
//! its justification. See `docs/LINTS.md` for the catalog.

use super::diag::{Diagnostic, Lint, Severity};
use super::lexer::Scrubbed;

/// A parsed `dqlint::allow` directive (well-formed or not).
#[derive(Clone, Debug)]
pub struct Directive {
    /// 0-indexed line the directive appears on.
    pub line: usize,
    /// The named lint, if it parsed and is a known suppressible lint.
    pub lint: Option<Lint>,
    /// The raw name as written (for error messages).
    pub name: String,
    /// The justification after the `:` (None or empty = bad allow).
    pub reason: Option<String>,
}

impl Directive {
    /// A directive only suppresses if it names a known lint and carries
    /// a non-empty reason.
    pub fn is_effective(&self) -> bool {
        self.lint.is_some() && self.reason.as_deref().is_some_and(|r| !r.trim().is_empty())
    }
}

const MARKER: &str = "dqlint::allow";

/// Extract every `dqlint::allow` directive from a scrubbed file's
/// comments (multiple directives per comment are honored).
///
/// Two comment shapes are deliberately *not* directives, so docs can
/// talk about the mechanism: a marker with no `(` after it (prose like
/// "suppress with a dqlint::allow comment") and a `<placeholder>` lint
/// name (syntax examples). Ignoring a would-be suppression is the safe
/// direction — the underlying lint still fires.
pub fn parse_directives(scrub: &Scrubbed) -> Vec<Directive> {
    let mut out = Vec::new();
    for (line, meta) in scrub.lines.iter().enumerate() {
        for comment in &meta.comments {
            let mut rest: &str = comment;
            while let Some(pos) = rest.find(MARKER) {
                let after = &rest[pos + MARKER.len()..];
                out.extend(parse_one(line, after));
                rest = after;
            }
        }
    }
    out
}

/// Parse the `(<name>): <reason>` tail of one directive occurrence.
/// `None` = prose/doc mention, not a directive.
fn parse_one(line: usize, after: &str) -> Option<Directive> {
    let open = after.trim_start().strip_prefix('(')?;
    let Some(close) = open.find(')') else {
        return Some(Directive { line, lint: None, name: String::new(), reason: None });
    };
    let name = open[..close].trim().to_string();
    if name.starts_with('<') {
        return None;
    }
    let tail = &open[close + 1..];
    let reason = tail
        .trim_start()
        .strip_prefix(':')
        .map(|r| {
            // A later directive in the same comment ends this reason.
            let r = r.split(MARKER).next().unwrap_or(r);
            r.trim().trim_end_matches("//").trim().to_string()
        })
        .filter(|r| !r.is_empty());
    Some(Directive { line, lint: Lint::from_name(&name), name, reason })
}

/// Diagnostics for malformed directives (unknown lint name or missing
/// reason). These are [`Lint::BadAllow`] errors and are never
/// suppressible — "every suppression carries a reason" is itself part
/// of the contract.
pub fn bad_allow_diagnostics(path: &str, directives: &[Directive]) -> Vec<Diagnostic> {
    directives
        .iter()
        .filter(|d| !d.is_effective())
        .map(|d| {
            let message = if d.name.is_empty() {
                format!("malformed directive — expected `dqlint::allow(<lint>): <reason>` with one of: {}", known_names())
            } else if d.lint.is_none() {
                format!("unknown lint {:?} in dqlint::allow — known lints: {}", d.name, known_names())
            } else {
                format!(
                    "dqlint::allow({}) without a reason — write `dqlint::allow({}): <why this site is exempt>`",
                    d.name, d.name
                )
            };
            Diagnostic {
                path: path.to_string(),
                line: d.line + 1,
                lint: Lint::BadAllow,
                severity: Severity::Error,
                message,
            }
        })
        .collect()
}

fn known_names() -> String {
    Lint::ALL.map(|l| l.name()).join(", ")
}

/// True if a diagnostic of `lint` on 0-indexed `line` is suppressed by
/// an effective directive on the same line, or on a contiguous run of
/// code-free lines directly above it. `line_has_code[l]` says whether
/// line `l` has any tokens.
pub fn is_suppressed(
    lint: Lint,
    line: usize,
    directives: &[Directive],
    line_has_code: &[bool],
) -> bool {
    let effective = |l: usize| {
        directives.iter().any(|d| d.line == l && d.lint == Some(lint) && d.is_effective())
    };
    if effective(line) {
        return true;
    }
    let mut l = line;
    while l > 0 {
        l -= 1;
        if line_has_code.get(l).copied().unwrap_or(false) {
            return false;
        }
        if effective(l) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scrub;

    fn directives(src: &str) -> Vec<Directive> {
        parse_directives(&scrub(src))
    }

    #[test]
    fn parses_well_formed_directive() {
        let d = directives("// dqlint::allow(no-map-iteration): lookup-only cache\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].lint, Some(Lint::NoMapIteration));
        assert_eq!(d[0].reason.as_deref(), Some("lookup-only cache"));
        assert!(d[0].is_effective());
    }

    #[test]
    fn bare_and_unknown_allows_are_bad() {
        let d = directives("// dqlint::allow(unseeded-rng)\n// dqlint::allow(nope): x\n");
        assert_eq!(d.len(), 2);
        assert!(!d[0].is_effective(), "missing reason");
        assert!(!d[1].is_effective(), "unknown lint");
        let bad = bad_allow_diagnostics("f.rs", &d);
        assert_eq!(bad.len(), 2);
        assert!(bad[0].message.contains("without a reason"));
        assert!(bad[1].message.contains("unknown lint"));
        assert_eq!(bad[0].line, 1);
        assert_eq!(bad[1].line, 2);
    }

    #[test]
    fn prose_and_placeholders_are_not_directives() {
        let src = "// suppress with a dqlint::allow comment\n\
                   // dqlint::allow(<lint>): <reason>\n";
        assert!(directives(src).is_empty());
        // An unclosed paren is still a malformed directive attempt.
        let d = directives("// dqlint::allow(no-map-iteration missing close\n");
        assert_eq!(d.len(), 1);
        assert!(!d[0].is_effective());
        assert!(d[0].name.is_empty());
    }

    #[test]
    fn suppression_covers_same_line_and_next_code_line() {
        // Directive on its own line 0, blank line 1, code line 2.
        let d = directives("// dqlint::allow(wallclock-hygiene): bench-only path\n\nx();\n");
        let has_code = [false, false, true];
        assert!(is_suppressed(Lint::WallclockHygiene, 0, &d, &has_code));
        assert!(is_suppressed(Lint::WallclockHygiene, 2, &d, &has_code));
        assert!(!is_suppressed(Lint::UnseededRng, 2, &d, &has_code));
    }

    #[test]
    fn code_line_breaks_the_suppression_run() {
        let d = directives("// dqlint::allow(unseeded-rng): fixture\ny();\nx();\n");
        let has_code = [false, true, true];
        assert!(is_suppressed(Lint::UnseededRng, 1, &d, &has_code));
        assert!(!is_suppressed(Lint::UnseededRng, 2, &d, &has_code), "line 1 has code");
    }
}
