//! Comment/string-stripping lexer and line-indexed token scanner.
//!
//! [`scrub`] turns Rust source into a same-line-structure "code skeleton":
//! comments and string/char-literal *contents* are blanked to spaces
//! (newlines preserved, so line numbers survive), while the comment text
//! itself is captured per line for the allow-directive engine
//! ([`super::allow`]) and the `SAFETY:` check. [`tokenize`] then splits
//! the skeleton into line-tagged identifier/punctuation tokens — the
//! representation the lint passes in [`super::scan`] pattern-match over.
//!
//! The lexer understands the Rust surface forms that matter for not
//! mis-classifying code as text: nested `/* */` block comments, `//`
//! line comments, `"…"` strings with escapes, raw strings `r"…"` /
//! `r#"…"#` (any hash depth, plus `b`/`br` byte variants), char
//! literals (including escaped ones), and lifetimes (`'a` is *not* an
//! unterminated char literal).

/// Per-line metadata captured while scrubbing.
#[derive(Clone, Debug, Default)]
pub struct LineMeta {
    /// Text of every comment (or block-comment fragment) on this line,
    /// without the `//` / `/*` markers.
    pub comments: Vec<String>,
}

impl LineMeta {
    /// True if any comment on this line contains a `SAFETY` marker —
    /// the evidence [`super::diag::Lint::UnsafeNeedsSafetyComment`]
    /// looks for near an `unsafe` token.
    pub fn has_safety(&self) -> bool {
        self.comments.iter().any(|c| c.contains("SAFETY"))
    }
}

/// Output of [`scrub`]: the blanked code skeleton plus per-line comment
/// metadata. `lines` always covers every line of the input (0-indexed;
/// display line numbers are `index + 1`).
#[derive(Clone, Debug)]
pub struct Scrubbed {
    /// Source with comments and string/char contents replaced by spaces;
    /// identical line structure to the input.
    pub code: String,
    /// One entry per input line.
    pub lines: Vec<LineMeta>,
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

/// Strip comments and string/char-literal contents from `src`,
/// preserving line structure and capturing comment text per line.
pub fn scrub(src: &str) -> Scrubbed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut lines: Vec<LineMeta> = vec![LineMeta::default()];
    let mut line = 0usize;
    let mut i = 0usize;

    // Record one comment fragment on `line`.
    let push_comment = |lines: &mut Vec<LineMeta>, line: usize, text: &[u8]| {
        while lines.len() <= line {
            lines.push(LineMeta::default());
        }
        lines[line].comments.push(String::from_utf8_lossy(text).into_owned());
    };

    macro_rules! newline {
        () => {{
            out.push(b'\n');
            line += 1;
            while lines.len() <= line {
                lines.push(LineMeta::default());
            }
            i += 1;
        }};
    }

    while i < n {
        let c = b[i];
        match c {
            b'\n' => newline!(),
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                // Line comment (also `///` and `//!`).
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                push_comment(&mut lines, line, &b[start..j]);
                for _ in i..j {
                    out.push(b' ');
                }
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                // Block comment; Rust block comments nest.
                let mut depth = 1usize;
                out.push(b' ');
                out.push(b' ');
                i += 2;
                let mut frag: Vec<u8> = Vec::new();
                let mut frag_line = line;
                while i < n && depth > 0 {
                    if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else if b[i] == b'\n' {
                        push_comment(&mut lines, frag_line, &frag);
                        frag.clear();
                        newline!();
                        frag_line = line;
                    } else {
                        frag.push(b[i]);
                        out.push(b' ');
                        i += 1;
                    }
                }
                push_comment(&mut lines, frag_line, &frag);
            }
            b'"' => {
                // Normal string (escapes honored, may span lines).
                out.push(b' ');
                i += 1;
                while i < n {
                    match b[i] {
                        b'\\' => {
                            out.push(b' ');
                            i += 1;
                            if i < n {
                                if b[i] == b'\n' {
                                    newline!();
                                } else {
                                    out.push(b' ');
                                    i += 1;
                                }
                            }
                        }
                        b'"' => {
                            out.push(b' ');
                            i += 1;
                            break;
                        }
                        b'\n' => newline!(),
                        _ => {
                            out.push(b' ');
                            i += 1;
                        }
                    }
                }
            }
            b'r' | b'b' if i == 0 || !is_ident_byte(b[i - 1]) => {
                // Possible raw/byte string or byte char: r"…", r#"…"#,
                // b"…", br#"…"#, b'…'. Anything else falls through as an
                // ordinary identifier character.
                let mut j = i;
                let mut is_raw = false;
                if c == b'b' {
                    j += 1;
                    if j < n && b[j] == b'r' {
                        is_raw = true;
                        j += 1;
                    }
                } else {
                    // c == b'r'
                    is_raw = true;
                    j += 1;
                }
                let hash_start = j;
                while j < n && b[j] == b'#' {
                    j += 1;
                }
                let hashes = j - hash_start;
                if is_raw && j < n && b[j] == b'"' {
                    // Raw string: blank through `"` + `hashes` hashes.
                    for _ in i..=j {
                        out.push(b' ');
                    }
                    i = j + 1;
                    while i < n {
                        if b[i] == b'\n' {
                            newline!();
                            continue;
                        }
                        if b[i] == b'"' && i + hashes < n && b[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#') {
                            for _ in 0..=hashes {
                                out.push(b' ');
                            }
                            i += 1 + hashes;
                            break;
                        }
                        out.push(b' ');
                        i += 1;
                    }
                } else if c == b'b' && hashes == 0 && !is_raw && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'\'') {
                    // b"…" / b'…': blank the prefix and re-handle the
                    // quote on the next iteration.
                    out.push(b' ');
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            b'\'' => {
                // Lifetime or char literal.
                let next = if i + 1 < n { Some(b[i + 1]) } else { None };
                let after = if i + 2 < n { Some(b[i + 2]) } else { None };
                let is_lifetime = matches!(next, Some(nb) if is_ident_start(nb)) && after != Some(b'\'');
                if is_lifetime {
                    out.push(b' ');
                    i += 1; // the label tokenizes as a harmless ident
                } else {
                    // Char literal: blank until the closing quote (same
                    // line; bail at newline on malformed input).
                    out.push(b' ');
                    i += 1;
                    if i < n && b[i] == b'\\' {
                        out.push(b' ');
                        i += 1;
                        if i < n && b[i] != b'\n' {
                            out.push(b' ');
                            i += 1;
                        }
                    } else if i < n && b[i] != b'\n' {
                        out.push(b' ');
                        i += 1;
                    }
                    while i < n && b[i] != b'\'' && b[i] != b'\n' {
                        out.push(b' ');
                        i += 1;
                    }
                    if i < n && b[i] == b'\'' {
                        out.push(b' ');
                        i += 1;
                    }
                }
            }
            _ => {
                out.push(c);
                i += 1;
            }
        }
    }

    Scrubbed {
        // The skeleton is ASCII + the original non-string/non-comment
        // bytes, which came from valid UTF-8 at unchanged offsets.
        code: String::from_utf8_lossy(&out).into_owned(),
        lines,
    }
}

/// One token of the scrubbed skeleton.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    /// 0-indexed source line the token starts on.
    pub line: usize,
    /// Identifier text or single punctuation byte.
    pub kind: TokKind,
}

/// Token payload: identifiers (and keywords) carry their text;
/// everything that is not an identifier, number, or whitespace is a
/// single punctuation character. Numeric literals are consumed and
/// dropped — no lint patterns involve them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// An identifier or keyword.
    Ident(String),
    /// A single punctuation byte (`::` appears as two `:` tokens).
    Punct(u8),
}

/// Split a scrubbed skeleton into line-tagged tokens.
pub fn tokenize(code: &str) -> Vec<Tok> {
    let b = code.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_byte(b[i]) {
                i += 1;
            }
            toks.push(Tok {
                line,
                kind: TokKind::Ident(String::from_utf8_lossy(&b[start..i]).into_owned()),
            });
        } else if c.is_ascii_digit() {
            // Numeric literal (incl. suffixes like 0u64): consumed, not
            // emitted.
            while i < n && is_ident_byte(b[i]) {
                i += 1;
            }
        } else {
            if c.is_ascii() {
                toks.push(Tok { line, kind: TokKind::Punct(c) });
            }
            i += 1;
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(&scrub(src).code)
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(s) => Some(s),
                TokKind::Punct(_) => None,
            })
            .collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let s = scrub("let a = 1; // partial_cmp here\n/* HashMap */ let b = 2;\n");
        assert!(!s.code.contains("partial_cmp"));
        assert!(!s.code.contains("HashMap"));
        assert_eq!(s.lines[0].comments, vec!["partial_cmp here".to_string()]);
        assert_eq!(s.lines[1].comments, vec![" HashMap ".to_string()]);
        assert_eq!(idents("let a = 1; // partial_cmp\n"), vec!["let", "a"]);
    }

    #[test]
    fn nested_block_comments_and_multiline_fragments() {
        let s = scrub("a /* x /* y */ z\nstill comment */ b\n");
        let id = idents("a /* x /* y */ z\nstill comment */ b\n");
        assert_eq!(id, vec!["a", "b"]);
        assert!(s.lines[0].comments[0].contains('x'));
        assert!(s.lines[1].comments[0].contains("still comment"));
    }

    #[test]
    fn strips_strings_and_raw_strings() {
        assert_eq!(idents("f(\"Instant::now\");\n"), vec!["f"]);
        assert_eq!(idents("f(r\"thread_rng\");\n"), vec!["f"]);
        assert_eq!(idents("f(r#\"a \" HashSet \" b\"#);\n"), vec!["f"]);
        assert_eq!(idents("f(b\"SystemTime\");\n"), vec!["f"]);
        assert_eq!(idents("f(\"esc \\\" partial_cmp\");\n"), vec!["f"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        // A mis-lexed lifetime would swallow `T` and derail everything.
        assert_eq!(
            idents("fn f<'a, T>(x: &'a T) -> &'a T { x }\n"),
            vec!["fn", "f", "a", "T", "x", "a", "T", "a", "T", "x"]
        );
        assert_eq!(idents("let c = 'x'; let q = '\\''; g();\n"), vec!["let", "c", "let", "q", "g"]);
        assert_eq!(idents("let s: &'static str = \"y\"; h();\n"), vec!["let", "s", "static", "str", "h"]);
    }

    #[test]
    fn line_numbers_survive_scrubbing() {
        let toks = tokenize(&scrub("a\n\"two\nlines\"\nb\n").code);
        assert_eq!(toks[0], Tok { line: 0, kind: TokKind::Ident("a".into()) });
        assert_eq!(toks[1], Tok { line: 3, kind: TokKind::Ident("b".into()) });
    }

    #[test]
    fn safety_marker_detection() {
        let s = scrub("// SAFETY: disjoint ranges\nx();\n");
        assert!(s.lines[0].has_safety());
        assert!(!s.lines[1].has_safety());
    }
}
