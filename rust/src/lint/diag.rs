//! Diagnostics model for `dqlint`: the lint catalog, severities, and the
//! human/JSON rendering of findings.
//!
//! Every diagnostic names the contract it enforces (see `docs/LINTS.md`
//! for the full rationale per lint) so a hit is actionable without
//! opening the docs.

use crate::util::json::Json;
use std::fmt;

/// The repo-specific lints `dqlint` enforces. Each corresponds to a
/// clause of the determinism / panic-safety contracts in
/// `docs/CONCURRENCY.md`; `docs/LINTS.md` documents rationale and the
/// `// dqlint::allow(<lint>): <reason>` suppression syntax.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lint {
    /// Float comparators must use `total_cmp`, not
    /// `partial_cmp(..).unwrap()` — NaN panics or nondeterministic order.
    FloatSortDeterminism,
    /// `HashMap`/`HashSet` in non-test code: iteration order is
    /// nondeterministic and feeds event logs and reports. Use
    /// `BTreeMap`/`BTreeSet`, or allow with a reason proving the
    /// container is never iterated.
    NoMapIteration,
    /// Wall-clock reads (`Instant::now`, `SystemTime`) only in the
    /// allowlisted timing modules whose outputs `canonical()` strips.
    WallclockHygiene,
    /// No entropy-seeded randomness (`thread_rng`, `from_entropy`,
    /// `OsRng`, `getrandom`) outside tests — all randomness derives from
    /// the run's seed through `util::prng`.
    UnseededRng,
    /// All thread fan-out goes through `util::threadpool` so panics are
    /// contained and join order is deterministic.
    RawThreadSpawn,
    /// No bare `.lock().unwrap()` / `.lock().expect(..)` outside
    /// `util::sync` — poisoned locks recover through
    /// `util::sync::lock_or_poisoned` instead of cascading panics.
    LockPoisonDiscipline,
    /// Every `unsafe` needs an adjacent `// SAFETY:` comment stating the
    /// invariant that makes it sound.
    UnsafeNeedsSafetyComment,
    /// A malformed `dqlint::allow` directive: unknown lint name, or a
    /// suppression without a reason. Not itself suppressible.
    BadAllow,
}

impl Lint {
    /// The seven suppressible lints, in catalog order ([`Lint::BadAllow`]
    /// is the directive-syntax meta-lint and is excluded: it cannot be
    /// allowed away).
    pub const ALL: [Lint; 7] = [
        Lint::FloatSortDeterminism,
        Lint::NoMapIteration,
        Lint::WallclockHygiene,
        Lint::UnseededRng,
        Lint::RawThreadSpawn,
        Lint::LockPoisonDiscipline,
        Lint::UnsafeNeedsSafetyComment,
    ];

    /// The kebab-case name used in output and in allow directives.
    pub fn name(self) -> &'static str {
        match self {
            Lint::FloatSortDeterminism => "float-sort-determinism",
            Lint::NoMapIteration => "no-map-iteration",
            Lint::WallclockHygiene => "wallclock-hygiene",
            Lint::UnseededRng => "unseeded-rng",
            Lint::RawThreadSpawn => "raw-thread-spawn",
            Lint::LockPoisonDiscipline => "lock-poison-discipline",
            Lint::UnsafeNeedsSafetyComment => "unsafe-needs-safety-comment",
            Lint::BadAllow => "bad-allow",
        }
    }

    /// Parse a directive name back to a lint (suppressible lints only —
    /// `bad-allow` deliberately has no name here).
    pub fn from_name(name: &str) -> Option<Lint> {
        Lint::ALL.iter().copied().find(|l| l.name() == name)
    }
}

/// Diagnostic severity. Every lint in the current catalog is an error
/// (the exit code gates CI); `Warning` exists so future advisory lints
/// can ride the same reporting surface without gating.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Advisory: reported but does not affect the exit code.
    Warning,
    /// Gating: any error fails `dqlint` (and therefore `ci.sh`).
    Error,
}

impl Severity {
    /// Lowercase label used in human and JSON output.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a lint fired at `path:line`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Normalized (forward-slash) path of the offending file.
    pub path: String,
    /// 1-based line number of the offending token.
    pub line: usize,
    /// Which lint fired.
    pub lint: Lint,
    /// Gating or advisory.
    pub severity: Severity,
    /// Human-readable explanation with the suggested fix.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}[{}] {}",
            self.path,
            self.line,
            self.severity.label(),
            self.lint.name(),
            self.message
        )
    }
}

/// Machine-readable report: `{"count", "errors", "diagnostics": [...]}`.
/// Round-trips through [`crate::util::json`]; `ci.sh` archives it as
/// `lint_report.json`.
pub fn report_json(diags: &[Diagnostic], files_scanned: usize) -> Json {
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    Json::obj(vec![
        ("count", Json::Num(diags.len() as f64)),
        ("errors", Json::Num(errors as f64)),
        ("files_scanned", Json::Num(files_scanned as f64)),
        (
            "diagnostics",
            Json::Arr(
                diags
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("path", Json::Str(d.path.clone())),
                            ("line", Json::Num(d.line as f64)),
                            ("lint", Json::Str(d.lint.name().to_string())),
                            ("severity", Json::Str(d.severity.label().to_string())),
                            ("message", Json::Str(d.message.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for l in Lint::ALL {
            assert_eq!(Lint::from_name(l.name()), Some(l));
        }
        assert_eq!(Lint::from_name("bad-allow"), None);
        assert_eq!(Lint::from_name("no-such-lint"), None);
    }

    #[test]
    fn report_json_counts_errors() {
        let d = Diagnostic {
            path: "x.rs".into(),
            line: 3,
            lint: Lint::FloatSortDeterminism,
            severity: Severity::Error,
            message: "m".into(),
        };
        let j = report_json(&[d.clone()], 7);
        assert_eq!(j.get_usize("count"), Some(1));
        assert_eq!(j.get_usize("errors"), Some(1));
        assert_eq!(j.get_usize("files_scanned"), Some(7));
        let arr = j.get("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get_str("lint"), Some("float-sort-determinism"));
        assert_eq!(d.to_string(), "x.rs:3: error[float-sort-determinism] m");
    }
}
