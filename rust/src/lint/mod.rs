//! `dqlint` — the repo's in-tree static-analysis pass.
//!
//! The determinism and panic-safety guarantees documented in
//! `docs/CONCURRENCY.md` (bit-identical replay across the parallel,
//! streamed, packed, and served paths) are contracts on *source
//! patterns*: float comparators must be total, randomness must derive
//! from the run seed, wall clocks stay out of canonical reports, locks
//! recover from poisoning instead of cascading panics. This module
//! family enforces those contracts mechanically so they survive PRs:
//!
//! - [`lexer`] — a comment/string-stripping pass ([`lexer::scrub`]) that
//!   preserves line structure, plus a line-indexed tokenizer
//!   ([`lexer::tokenize`]); lints never fire inside strings or comments.
//! - [`scan`] — the lint passes themselves and the `#[cfg(test)]`
//!   exemption mask (the contracts govern shipping code, not tests).
//! - [`diag`] — the lint catalog, severities, and human/JSON rendering.
//! - [`allow`] — the `// dqlint::allow(<lint>): <reason>` suppression
//!   engine; a suppression without a reason is itself an error.
//!
//! The `dqlint` binary (`rust/src/bin/dqlint.rs`) drives
//! [`scan_paths`] over `rust/src/**` and `rust/benches/**` and exits
//! nonzero on any error-severity diagnostic, gating `ci.sh` and
//! `make lint`. The lint catalog and per-lint rationale live in
//! `docs/LINTS.md`.

pub mod allow;
pub mod diag;
pub mod lexer;
pub mod scan;

pub use diag::{report_json, Diagnostic, Lint, Severity};
pub use scan::scan_source;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The default scan roots, relative to the repo root.
pub const DEFAULT_ROOTS: [&str; 2] = ["rust/src", "rust/benches"];

/// Recursively collect every `.rs` file under `root`, sorted by path so
/// scan order (and therefore report order) is deterministic across
/// platforms. A `root` that is itself a file is returned as-is.
pub fn walk_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(&dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Render a path with forward slashes (diagnostics and the allowlists
/// in [`scan`] are specified in `/`-separated form regardless of OS).
pub fn display_path(path: &Path) -> String {
    let mut parts: Vec<String> = Vec::new();
    for comp in path.components() {
        parts.push(comp.as_os_str().to_string_lossy().into_owned());
    }
    parts.join("/")
}

/// Scan a single file from disk.
pub fn scan_file(path: &Path) -> io::Result<Vec<Diagnostic>> {
    let src = fs::read_to_string(path)?;
    Ok(scan::scan_source(&display_path(path), &src))
}

/// Scan every `.rs` file under each root (files are scanned directly).
/// Returns all diagnostics plus the number of files scanned.
pub fn scan_paths(roots: &[PathBuf]) -> io::Result<(Vec<Diagnostic>, usize)> {
    let mut diags = Vec::new();
    let mut files = 0usize;
    for root in roots {
        for file in walk_rs_files(root)? {
            diags.extend(scan_file(&file)?);
            files += 1;
        }
    }
    Ok((diags, files))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_path_is_forward_slashed() {
        let p: PathBuf = ["rust", "src", "lint", "mod.rs"].iter().collect();
        assert_eq!(display_path(&p), "rust/src/lint/mod.rs");
    }

    #[test]
    fn walker_is_sorted_and_rs_only() {
        let dir = std::env::temp_dir().join(format!("dqlint-walk-{}", std::process::id()));
        fs::create_dir_all(dir.join("b")).unwrap();
        fs::write(dir.join("z.rs"), "fn z() {}\n").unwrap();
        fs::write(dir.join("a.rs"), "fn a() {}\n").unwrap();
        fs::write(dir.join("notes.md"), "skip\n").unwrap();
        fs::write(dir.join("b").join("c.rs"), "fn c() {}\n").unwrap();
        let files = walk_rs_files(&dir).unwrap();
        let names: Vec<String> =
            files.iter().map(|f| display_path(f.strip_prefix(&dir).unwrap())).collect();
        assert_eq!(names, ["a.rs", "b/c.rs", "z.rs"]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scan_paths_counts_files() {
        let dir = std::env::temp_dir().join(format!("dqlint-scan-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("bad.rs"), "fn f() { a.partial_cmp(b); }\n").unwrap();
        fs::write(dir.join("good.rs"), "fn f() { a.total_cmp(b); }\n").unwrap();
        let (diags, files) = scan_paths(&[dir.clone()]).unwrap();
        assert_eq!(files, 2);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].lint, Lint::FloatSortDeterminism);
        assert!(diags[0].path.ends_with("bad.rs"));
        fs::remove_dir_all(&dir).unwrap();
    }
}
