//! The lint passes: token-pattern matching over a scrubbed file, the
//! `#[cfg(test)]` exemption mask, and per-file diagnostic assembly.
//!
//! Every lint here is lexical — it pattern-matches the identifier/
//! punctuation token stream from [`super::lexer`], which makes the pass
//! dependency-free and fast, at the cost of being conservative: a lint
//! fires on the *use of a pattern*, not on a proven semantic violation.
//! That is the intended trade — a false positive at a genuinely-safe
//! site is answered with a `// dqlint::allow(<lint>): <reason>`
//! directive, which doubles as in-tree documentation of why the site is
//! exempt (see `docs/LINTS.md`).

use super::allow;
use super::diag::{Diagnostic, Lint, Severity};
use super::lexer::{self, Tok, TokKind};

/// Modules allowed to read wall clocks: the timing surfaces whose
/// outputs `PipelineRecord::canonical()` strips (`docs/CONCURRENCY.md`),
/// plus everything under `benches/` (measuring wall time is a bench's
/// purpose and bench output is never a canonical report).
const WALLCLOCK_MODULES: [&str; 4] = [
    "util/bench.rs",
    "coordinator/stages.rs",
    "coordinator/scheduler.rs",
    "coordinator/registry.rs",
];

/// Entropy-source identifiers banned outside tests.
const ENTROPY_IDENTS: [&str; 4] = ["thread_rng", "from_entropy", "OsRng", "getrandom"];

/// `std::thread` members that bypass `util::threadpool`.
const THREAD_MEMBERS: [&str; 3] = ["spawn", "scope", "Builder"];

/// How many lines above an `unsafe` token a `// SAFETY:` comment may
/// sit (same line also counts). Multi-line SAFETY comments fit because
/// any line of the comment containing the marker satisfies the check.
const SAFETY_WINDOW: usize = 3;

fn ident<'a>(toks: &'a [Tok], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct(toks: &[Tok], i: usize, c: u8) -> bool {
    matches!(toks.get(i).map(|t| &t.kind), Some(TokKind::Punct(p)) if *p == c)
}

/// Compute which 0-indexed lines fall inside a `#[cfg(test)]` item
/// (attribute line through the item's closing `}` or `;`). The
/// contracts govern shipping code; test modules are exempt from every
/// lint except [`Lint::BadAllow`].
pub fn test_line_mask(toks: &[Tok], n_lines: usize) -> Vec<bool> {
    let mut mask = vec![false; n_lines];
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg_test = punct(toks, i, b'#')
            && punct(toks, i + 1, b'[')
            && ident(toks, i + 2) == Some("cfg")
            && punct(toks, i + 3, b'(')
            && ident(toks, i + 4) == Some("test")
            && punct(toks, i + 5, b')')
            && punct(toks, i + 6, b']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let attr_line = toks[i].line;
        let mut j = i + 7;
        // Skip any further attributes between #[cfg(test)] and the item.
        while punct(toks, j, b'#') && punct(toks, j + 1, b'[') {
            j += 2;
            let mut depth = 1usize;
            while j < toks.len() && depth > 0 {
                if punct(toks, j, b'[') {
                    depth += 1;
                } else if punct(toks, j, b']') {
                    depth -= 1;
                }
                j += 1;
            }
        }
        // The item extends to the matching `}` of its first brace block,
        // or to a top-level `;` (use/type items).
        let mut brace = 0usize;
        let mut end = j;
        let mut end_line = n_lines.saturating_sub(1);
        while end < toks.len() {
            if punct(toks, end, b'{') {
                brace += 1;
            } else if punct(toks, end, b'}') {
                brace = brace.saturating_sub(1);
                if brace == 0 {
                    end_line = toks[end].line;
                    break;
                }
            } else if punct(toks, end, b';') && brace == 0 {
                end_line = toks[end].line;
                break;
            }
            end += 1;
        }
        for m in mask.iter_mut().take(end_line.min(n_lines - 1) + 1).skip(attr_line) {
            *m = true;
        }
        i = end.max(i) + 1;
    }
    mask
}

struct Hit {
    line: usize, // 0-indexed
    lint: Lint,
    message: String,
}

/// Run the seven token lints over one file's tokens.
fn token_lints(path: &str, toks: &[Tok], scrub: &lexer::Scrubbed, mask: &[bool]) -> Vec<Hit> {
    let wallclock_ok = in_benches(path) || WALLCLOCK_MODULES.iter().any(|m| path.ends_with(m));
    let spawn_ok = path.ends_with("util/threadpool.rs");
    let lock_ok = path.ends_with("util/sync.rs");
    let mut hits = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if mask.get(t.line).copied().unwrap_or(false) {
            continue;
        }
        let TokKind::Ident(name) = &t.kind else { continue };
        match name.as_str() {
            "partial_cmp" => hits.push(Hit {
                line: t.line,
                lint: Lint::FloatSortDeterminism,
                message: "float comparator via partial_cmp — NaN panics or flips the order; \
                          use f32::total_cmp / f64::total_cmp"
                    .into(),
            }),
            "HashMap" | "HashSet" => hits.push(Hit {
                line: t.line,
                lint: Lint::NoMapIteration,
                message: format!(
                    "{name} in non-test code — hash iteration order is nondeterministic and \
                     leaks into event logs/reports; use BTreeMap/BTreeSet, or allow with a \
                     reason proving the container is never iterated"
                ),
            }),
            "Instant" if !wallclock_ok && punct(toks, i + 1, b':') && punct(toks, i + 2, b':') && ident(toks, i + 3) == Some("now") => {
                hits.push(Hit {
                    line: t.line,
                    lint: Lint::WallclockHygiene,
                    message: wallclock_message("Instant::now()"),
                });
            }
            "SystemTime" if !wallclock_ok => hits.push(Hit {
                line: t.line,
                lint: Lint::WallclockHygiene,
                message: wallclock_message("SystemTime"),
            }),
            _ if ENTROPY_IDENTS.contains(&name.as_str()) => hits.push(Hit {
                line: t.line,
                lint: Lint::UnseededRng,
                message: format!(
                    "{name} is entropy-seeded — all randomness must derive from the run's \
                     seed through util::prng::Pcg64 so runs replay bit-identically"
                ),
            }),
            "thread" if !spawn_ok && punct(toks, i + 1, b':') && punct(toks, i + 2, b':') && ident(toks, i + 3).is_some_and(|m| THREAD_MEMBERS.contains(&m)) => {
                hits.push(Hit {
                    line: t.line,
                    lint: Lint::RawThreadSpawn,
                    message: format!(
                        "raw thread::{} — all fan-out goes through util::threadpool \
                         (panic containment + deterministic join order)",
                        ident(toks, i + 3).unwrap_or("spawn")
                    ),
                });
            }
            "spawn_scoped" if !spawn_ok => hits.push(Hit {
                line: t.line,
                lint: Lint::RawThreadSpawn,
                message: "raw spawn_scoped — all fan-out goes through util::threadpool \
                          (panic containment + deterministic join order)"
                    .into(),
            }),
            "lock" if !lock_ok && punct(toks, i.wrapping_sub(1), b'.') && i > 0 && punct(toks, i + 1, b'(') && punct(toks, i + 2, b')') && punct(toks, i + 3, b'.') && matches!(ident(toks, i + 4), Some("unwrap") | Some("expect")) => {
                hits.push(Hit {
                    line: t.line,
                    lint: Lint::LockPoisonDiscipline,
                    message: format!(
                        ".lock().{}(..) panics on a poisoned mutex, cascading one worker's \
                         panic into every thread that touches the lock; use \
                         util::sync::lock_or_poisoned",
                        ident(toks, i + 4).unwrap_or("unwrap")
                    ),
                });
            }
            "unsafe" => {
                let lo = t.line.saturating_sub(SAFETY_WINDOW);
                let documented = (lo..=t.line)
                    .any(|l| scrub.lines.get(l).is_some_and(|m| m.has_safety()));
                if !documented {
                    hits.push(Hit {
                        line: t.line,
                        lint: Lint::UnsafeNeedsSafetyComment,
                        message: format!(
                            "unsafe without an adjacent `// SAFETY:` comment (within {SAFETY_WINDOW} \
                             lines) stating the invariant that makes it sound"
                        ),
                    });
                }
            }
            _ => {}
        }
    }
    hits
}

fn wallclock_message(what: &str) -> String {
    format!(
        "{what} outside the allowlisted timing modules ({}) — wall-clock reads feed \
         nondeterminism into reports; route timing through the stage observer, or allow \
         with a reason if the reading never reaches canonical output",
        WALLCLOCK_MODULES.join(", ")
    )
}

fn in_benches(path: &str) -> bool {
    path.split('/').any(|c| c == "benches")
}

/// Scan one file's source text. `path` is used for allowlisting and
/// diagnostic labels; use a normalized forward-slash path.
pub fn scan_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let scrub = lexer::scrub(src);
    let toks = lexer::tokenize(&scrub.code);
    let n_lines = scrub.lines.len().max(1);
    let mask = test_line_mask(&toks, n_lines);
    let directives = allow::parse_directives(&scrub);
    let mut line_has_code = vec![false; n_lines];
    for t in &toks {
        if let Some(slot) = line_has_code.get_mut(t.line) {
            *slot = true;
        }
    }
    let mut diags: Vec<Diagnostic> = token_lints(path, &toks, &scrub, &mask)
        .into_iter()
        .filter(|h| !allow::is_suppressed(h.lint, h.line, &directives, &line_has_code))
        .map(|h| Diagnostic {
            path: path.to_string(),
            line: h.line + 1,
            lint: h.lint,
            severity: Severity::Error,
            message: h.message,
        })
        .collect();
    diags.extend(allow::bad_allow_diagnostics(path, &directives));
    diags.sort_by(|a, b| a.line.cmp(&b.line).then(a.lint.cmp(&b.lint)));
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lints_of(path: &str, src: &str) -> Vec<Lint> {
        scan_source(path, src).into_iter().map(|d| d.lint).collect()
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "\
fn live() { let t = a.partial_cmp(b); }
#[cfg(test)]
mod tests {
    fn helper() { let t = a.partial_cmp(b); }
}
";
        let d = scan_source("x.rs", src);
        assert_eq!(d.len(), 1, "only the non-test hit: {d:?}");
        assert_eq!(d[0].line, 1);
    }

    #[test]
    fn cfg_test_on_statement_item() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn f() {}\n";
        assert!(lints_of("x.rs", src).is_empty());
    }

    #[test]
    fn wallclock_allowlist_is_path_based() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(lints_of("rust/src/serve/engine.rs", src), vec![Lint::WallclockHygiene]);
        assert!(lints_of("rust/src/util/bench.rs", src).is_empty());
        assert!(lints_of("rust/src/coordinator/stages.rs", src).is_empty());
        assert!(lints_of("rust/benches/perf_decode.rs", src).is_empty());
    }

    #[test]
    fn lock_pattern_requires_empty_args_and_unwrap() {
        let hit = "fn f() { state.lock().unwrap(); }\n";
        let exp = "fn f() { state.lock().expect(\"m\"); }\n";
        let ok = "fn f() { let g = lock_or_poisoned(&state); }\n";
        assert_eq!(lints_of("a.rs", hit), vec![Lint::LockPoisonDiscipline]);
        assert_eq!(lints_of("a.rs", exp), vec![Lint::LockPoisonDiscipline]);
        assert!(lints_of("a.rs", ok).is_empty());
        assert!(lints_of("rust/src/util/sync.rs", hit).is_empty(), "home module is exempt");
    }

    #[test]
    fn thread_patterns() {
        assert_eq!(
            lints_of("a.rs", "fn f() { std::thread::spawn(|| {}); }\n"),
            vec![Lint::RawThreadSpawn]
        );
        assert_eq!(
            lints_of("a.rs", "fn f() { std::thread::scope(|s| {}); }\n"),
            vec![Lint::RawThreadSpawn]
        );
        assert!(lints_of("a.rs", "fn f() { thread::available_parallelism(); }\n").is_empty());
        assert!(
            lints_of("rust/src/util/threadpool.rs", "fn f() { std::thread::spawn(|| {}); }\n")
                .is_empty()
        );
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f() { unsafe { g() } }\n";
        let good = "fn f() {\n    // SAFETY: g has no preconditions here\n    unsafe { g() }\n}\n";
        assert_eq!(lints_of("a.rs", bad), vec![Lint::UnsafeNeedsSafetyComment]);
        assert!(lints_of("a.rs", good).is_empty());
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "fn f() { g(\"partial_cmp HashMap Instant::now\"); } // thread_rng\n";
        assert!(lints_of("a.rs", src).is_empty());
    }

    #[test]
    fn trailing_allow_suppresses_and_bare_allow_errors() {
        let ok = "fn f() { let m = HashMap::new(); } // dqlint::allow(no-map-iteration): lookup-only\n";
        assert!(lints_of("a.rs", ok).is_empty());
        let bare = "fn f() { let m = HashMap::new(); } // dqlint::allow(no-map-iteration)\n";
        assert_eq!(
            lints_of("a.rs", bare),
            vec![Lint::NoMapIteration, Lint::BadAllow],
            "an ineffective allow suppresses nothing and is itself an error"
        );
    }
}
