//! Quantized activations: the per-row asymmetric fake-quant grid plus
//! [`QAct`], the integer-code representation of a fake-quantized
//! activation matrix.
//!
//! Historically the activation grid lived in `model::forward`
//! (`fq_row_grid` / `fake_quant_row`) and the integer matmul re-derived
//! every row's codes on **every** linear. [`quantize_act`] factors that
//! pipeline: fake-quantize once at the layer boundary, recover the codes
//! once, and hand the same [`QAct`] to every linear that consumes the
//! activation (wq/wk/wv share one, wg/wu share one). The numeric
//! semantics are **bit-identical** to the historical two-step
//! (fake-quant then per-linear recovery): [`quantize_act`] literally runs
//! [`fake_quant_row`] and then [`QAct::from_quantized`], the verbatim
//! recovery loop the old `matmul_transb_q` carried inline.
//!
//! Grid contract (shared with the KV-cache code storage in `model::kv`):
//! per-row asymmetric, `scale = (mx - mn) / (levels - 1)`, disabled at
//! `levels >= 32768` (the fp16 settings), constant rows (`scale <= 0`)
//! left untouched with the offset carrying the value.

use super::Mat;

/// Per-row asymmetric fake-quant grid `(mn, scale)` at `levels`, or
/// `None` when quantization is disabled (`levels >= 32768`) or the row
/// is constant (zero range, left untouched).
pub fn act_grid(row: &[f32], levels: f32) -> Option<(f32, f32)> {
    if levels >= 32768.0 {
        return None;
    }
    let (mut mn, mut mx) = (f32::MAX, f32::MIN);
    for &v in row {
        mn = mn.min(v);
        mx = mx.max(v);
    }
    let scale = (mx - mn) / (levels - 1.0).max(1.0);
    if scale <= 0.0 {
        None
    } else {
        Some((mn, scale))
    }
}

/// Fake-quantize one row in place on its [`act_grid`] grid.
pub fn fake_quant_row(row: &mut [f32], levels: f32) {
    if let Some((mn, scale)) = act_grid(row, levels) {
        for v in row.iter_mut() {
            *v = ((*v - mn) / scale).round() * scale + mn;
        }
    }
}

/// Per-token asymmetric fake quantization over rows (the activation
/// quantizer). `levels >= 32768` disables — mirrors `model._fq_act`.
pub fn fake_quant_rows(x: &mut Mat, levels: f32) {
    for i in 0..x.rows {
        fake_quant_row(x.row_mut(i), levels);
    }
}

/// A fake-quantized activation matrix in integer form: per-row u8 codes
/// plus the `(mn, scale)` grid each row sits on. `scale == 0` marks a
/// constant (untouched) row whose value rides entirely in `mn` — its
/// codes are all zero, exactly like the historical in-kernel recovery.
///
/// Decode semantics: `x[i][k] = codes[i][k] as f32 * scale[i] + mn[i]`.
#[derive(Clone, Debug, PartialEq)]
pub struct QAct {
    rows: usize,
    cols: usize,
    codes: Vec<u8>,
    mns: Vec<f32>,
    scales: Vec<f32>,
}

impl QAct {
    /// Recover codes from rows **already on** the `levels` fake-quant
    /// grid — the verbatim recovery loop of the historical integer
    /// matmul: the grid is re-derived per row and round-to-nearest
    /// against it is exact. `levels` must be ≤ 256 so codes fit u8.
    pub fn from_quantized(x: &Mat, levels: f32) -> QAct {
        assert!(levels <= 256.0, "QAct codes are u8: levels {levels} > 256");
        let (m, k) = (x.rows, x.cols);
        let mut codes = vec![0u8; m * k];
        let mut mns = vec![0f32; m];
        let mut scales = vec![0f32; m];
        let hi = levels - 1.0;
        for i in 0..m {
            let row = x.row(i);
            let (mut mn, mut mx) = (f32::MAX, f32::MIN);
            for &v in row {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let scale = (mx - mn) / (levels - 1.0).max(1.0);
            mns[i] = mn;
            if scale <= 0.0 {
                continue; // constant row: codes 0, offset carries the value
            }
            scales[i] = scale;
            for (o, &v) in codes[i * k..(i + 1) * k].iter_mut().zip(row) {
                *o = ((v - mn) / scale).round().clamp(0.0, hi) as u8;
            }
        }
        QAct { rows: m, cols: k, codes, mns, scales }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i`'s codes.
    #[inline]
    pub fn code_row(&self, i: usize) -> &[u8] {
        &self.codes[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i`'s grid `(mn, scale)`; `scale == 0` for constant rows.
    #[inline]
    pub fn grid(&self, i: usize) -> (f32, f32) {
        (self.mns[i], self.scales[i])
    }

    /// Take a contiguous row slice [lo, hi) as a new `QAct` (the MoE
    /// per-token expert dispatch slices single rows).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> QAct {
        assert!(lo <= hi && hi <= self.rows);
        QAct {
            rows: hi - lo,
            cols: self.cols,
            codes: self.codes[lo * self.cols..hi * self.cols].to_vec(),
            mns: self.mns[lo..hi].to_vec(),
            scales: self.scales[lo..hi].to_vec(),
        }
    }

    /// Decode into a fresh f32 matrix (tests / diagnostics; the hot path
    /// never materializes this).
    pub fn decode(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (mn, scale) = self.grid(i);
            for (o, &c) in out.row_mut(i).iter_mut().zip(self.code_row(i)) {
                *o = c as f32 * scale + mn;
            }
        }
        out
    }

    /// Memory footprint in bytes.
    pub fn nbytes(&self) -> u64 {
        (self.codes.len() + 4 * self.mns.len() + 4 * self.scales.len()) as u64
    }
}

/// The layer-boundary activation quantizer: fake-quantize `x` in place
/// (bit-identical to [`fake_quant_rows`]) and, when the grid is integer
/// (`levels <= 256`, i.e. the ≤ 8-bit activation settings), return the
/// recovered codes so downstream linears skip the per-call re-derivation.
/// Returns `None` — with `x` still correctly fake-quantized or left
/// untouched per the `levels >= 32768` disable — for the wide/fp grids.
pub fn quantize_act(x: &mut Mat, levels: f32) -> Option<QAct> {
    fake_quant_rows(x, levels);
    if levels > 256.0 {
        return None;
    }
    Some(QAct::from_quantized(x, levels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn quantize_act_writeback_is_fake_quant_rows_bitwise() {
        for levels in [4.0f32, 16.0, 256.0, 1024.0, 65536.0] {
            let mut a = rand_mat(7, 5, 33);
            let mut b = a.clone();
            let qa = quantize_act(&mut a, levels);
            fake_quant_rows(&mut b, levels);
            assert_eq!(a, b, "levels {levels}");
            assert_eq!(qa.is_some(), levels <= 256.0);
        }
    }

    #[test]
    fn codes_match_the_in_kernel_recovery_and_decode_roundtrips() {
        let mut x = rand_mat(3, 4, 17);
        let qa = quantize_act(&mut x, 16.0).unwrap();
        // Recovery of the already-quantized mat reproduces the same codes
        // and grids exactly.
        assert_eq!(QAct::from_quantized(&x, 16.0), qa);
        // Decode lands within one re-derived-grid rounding of x.
        let d = qa.decode().max_abs_diff(&x);
        assert!(d <= 1e-5 * x.max_abs().max(1e-12), "decode drift {d}");
    }

    #[test]
    fn constant_rows_ride_in_the_offset() {
        let mut x = Mat::from_vec(2, 3, vec![2.5, 2.5, 2.5, 0.0, 1.0, 2.0]);
        let qa = quantize_act(&mut x, 4.0).unwrap();
        assert_eq!(qa.grid(0), (2.5, 0.0));
        assert_eq!(qa.code_row(0), &[0, 0, 0]);
        assert_eq!(x.row(0), &[2.5, 2.5, 2.5], "constant row left untouched");
        assert_eq!(qa.decode().row(0), &[2.5, 2.5, 2.5]);
        let (mn, scale) = qa.grid(1);
        assert!(scale > 0.0 && mn == 0.0);
    }

    #[test]
    fn rows_slice_matches_whole_mat_quantization() {
        let mut x = rand_mat(11, 6, 9);
        let qa = quantize_act(&mut x, 16.0).unwrap();
        let sliced = qa.rows_slice(2, 5);
        let direct = QAct::from_quantized(&x.rows_slice(2, 5), 16.0);
        assert_eq!(sliced, direct);
    }
}
