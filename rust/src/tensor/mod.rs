//! Minimal dense f32 tensor substrate (no `ndarray` offline): row-major
//! matrices with blocked, multi-threaded matmul — enough to run the tiny
//! Llama-style models natively, compute GPTQ Hessians, and verify the
//! PJRT-executed artifacts against a pure-rust oracle. `qmat` adds the
//! packed quantized-weight representation (integer codes + scales) and
//! its streaming/integer matmul kernels; `qact` is the quantized-
//! activation side (per-row asymmetric u8 codes, computed once per layer
//! boundary); `gemm` is the cache-blocked, register-tiled i8/i4 GEMM
//! that consumes both; `shard` adds the bit-identical column-parallel /
//! row-parallel tensor-parallel plans over all three kernel families.

mod gemm;
mod matmul;
pub mod qact;
pub mod qmat;
pub mod shard;

pub use gemm::{matmul_transb_qact, matmul_transb_qact_with};
pub use matmul::{matmul, matmul_into, matmul_transb, matmul_transb_with};
pub use qact::{fake_quant_row, fake_quant_rows, quantize_act, QAct};
pub use qmat::{
    matmul_transb_deq, matmul_transb_deq_with, matmul_transb_q, matmul_transb_q_ref,
    matmul_transb_q_with, quantize_into, QMat, QuantSpec,
};
pub use shard::{
    matmul_transb_deq_sharded, matmul_transb_q_rowpar, matmul_transb_q_sharded,
    matmul_transb_qact_rowpar, matmul_transb_qact_sharded, matmul_transb_sharded, reduce_i32,
    shard_ranges,
};
// Crate-internal: the sharded attention in `model::forward` reuses the
// disjoint-range writer pointer and the shard runner.
pub(crate) use matmul::SendPtr;
pub(crate) use shard::run_shards;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on the bigger configs.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Take a contiguous row slice [lo, hi) as a new matrix.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat { rows: hi - lo, cols: self.cols, data: self.data[lo * self.cols..hi * self.cols].to_vec() }
    }

    /// Gather rows by index (token sampling).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Gather columns by index.
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |i, j| self.at(i, idx[j]))
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// max |a_ij - b_ij|
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f32::max)
    }

    /// ‖A‖² rows: per-row squared L2 norms.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|x| x * x).sum())
            .collect()
    }

    /// Memory footprint in bytes (for the PeakTracker accounting).
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        let _ = Mat::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(37, 53, |i, j| (i * 101 + j * 7) as f32);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t().at(10, 20), m.at(20, 10));
    }

    #[test]
    fn gather_and_slice() {
        let m = Mat::from_fn(5, 3, |i, j| (i * 10 + j) as f32);
        let g = m.gather_rows(&[4, 0, 2]);
        assert_eq!(g.row(0), m.row(4));
        assert_eq!(g.row(2), m.row(2));
        let s = m.rows_slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0), m.row(1));
        let c = m.gather_cols(&[2, 0]);
        assert_eq!(c.at(3, 0), m.at(3, 2));
    }

    #[test]
    fn norms_and_arith() {
        let mut a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        let b = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        assert_eq!(a.sub(&b).data, vec![2.0, 3.0]);
        a.add_assign(&b);
        assert_eq!(a.data, vec![4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![2.0, 2.5]);
        assert_eq!(a.max_abs(), 2.5);
        assert_eq!(Mat::eye(3).row_sq_norms(), vec![1.0, 1.0, 1.0]);
    }
}
