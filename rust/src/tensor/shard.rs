//! Within-layer tensor-parallel sharding — the single-process analogue of
//! tensor parallelism for the linear kernels.
//!
//! Two plans, both built on `par_ranges`-style disjoint contiguous ranges
//! ([`shard_ranges`]) so blocking and shard count can never move a bit:
//!
//! * **Column-parallel** — split the *output* columns (= rows of the
//!   transposed weight). Every output element is still computed whole,
//!   over the full inner dimension, by exactly one shard, so the
//!   per-element arithmetic is identical to the unsharded kernel for
//!   **every** dtype — including f32, whose accumulation order must not
//!   change. This is the plan the forward path uses
//!   ([`crate::model::FwdOptions::shards`]).
//! * **Row-parallel** — split the inner (k) dimension; each shard
//!   produces partial i32 accumulators that are reduced in shard-index
//!   order ([`reduce_i32`]). i32 addition is associative, so the split
//!   point and shard count cannot move a bit — which is exactly why this
//!   plan exists **only for the integer kernels**. An f32 k-split would
//!   reassociate the float sum and break the determinism contract
//!   (`docs/CONCURRENCY.md`), so no f32 row-parallel variant is provided.
//!
//! Every sharded kernel is gated on bit-identity with its unsharded
//! counterpart at shards ∈ {1, 2, 4, 7} (tests below plus
//! `rust/tests/shard.rs`, `perf_gemm`, `perf_hotpath`).

use super::gemm;
use super::matmul::{dot_unrolled, SendPtr};
use super::qact::QAct;
use super::qmat::{matmul_transb_q_ref, QMat};
use super::Mat;

/// Split `[0, n)` into at most `shards` contiguous, disjoint,
/// exactly-covering ranges — the same `div_ceil` chunking as
/// `util::threadpool::par_ranges`, returned as data so callers can
/// enumerate shards (job decomposition, gate charges) instead of running
/// them. Degenerate inputs mirror `par_ranges`: `shards` ≤ 1, `shards` >
/// `n`, and `n` = 0 all still cover every index exactly once (`n` = 0
/// yields the single empty range `(0, 0)`).
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, n.max(1));
    if shards <= 1 || n == 0 {
        return vec![(0, n)];
    }
    let chunk = n.div_ceil(shards);
    let mut out = Vec::with_capacity(shards);
    for t in 0..shards {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        out.push((lo, hi));
    }
    out
}

/// The shard-reduce half of the row-parallel plan: sum per-shard i32
/// partial vectors elementwise, folding in **shard-index order**. i32
/// addition is associative and overflow-free at our operand ranges, so
/// the result is independent of how `[0, k)` was split — but fixing the
/// fold order keeps the rule mechanical. Empty input reduces to an empty
/// vector; a singleton reduces to itself.
pub fn reduce_i32(parts: Vec<Vec<i32>>) -> Vec<i32> {
    let mut it = parts.into_iter();
    let Some(mut acc) = it.next() else { return Vec::new() };
    for p in it {
        assert_eq!(p.len(), acc.len(), "shard partials disagree on length");
        for (a, v) in acc.iter_mut().zip(&p) {
            *a += v;
        }
    }
    acc
}

/// Run `f(lo, hi)` for every shard range — through the panic-safe
/// [`crate::util::threadpool::scoped_try_map`] fan-out when there is more
/// than one range (a single range runs inline on the caller — shards = 1
/// never pays a spawn).
pub(crate) fn run_shards<F>(ranges: &[(usize, usize)], f: F)
where
    F: Fn(usize, usize) + Send + Sync,
{
    if let [(lo, hi)] = ranges {
        f(*lo, *hi);
        return;
    }
    crate::util::threadpool::scoped_try_map(ranges.len(), ranges, |_, &(lo, hi)| f(lo, hi))
        .expect("shard closures do not panic");
}

/// Column-parallel `C = A · Bᵀ`: shard the output columns (rows of `b`).
/// Each element is one full-k [`dot_unrolled`] — the identical expression
/// of [`super::matmul_transb`] — so the result is bit-identical to the
/// unsharded kernel at any shard count, f32 included.
pub fn matmul_transb_sharded(a: &Mat, b: &Mat, shards: usize) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_transb_sharded inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    run_shards(&shard_ranges(n, shards), |jlo, jhi| {
        let c_ptr = &c_ptr;
        for i in 0..m {
            let a_row = &a_data[i * k..(i + 1) * k];
            for j in jlo..jhi {
                let v = dot_unrolled(a_row, &b_data[j * k..(j + 1) * k]);
                // SAFETY: each shard writes the disjoint column range
                // [jlo, jhi) — no two shards touch the same element.
                unsafe { *c_ptr.0.add(i * n + j) = v };
            }
        }
    });
    c
}

/// Column-parallel streamed-dequantize matmul: shard the output columns,
/// each shard decoding its own weight rows into thread-local scratch —
/// per-element math identical to [`super::matmul_transb_deq`].
pub fn matmul_transb_deq_sharded(x: &Mat, q: &QMat, shards: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_deq_sharded inner-dim mismatch");
    let (m, k, n) = (x.rows, x.cols, q.rows());
    let mut y = Mat::zeros(m, n);
    let x_data = &x.data;
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    run_shards(&shard_ranges(n, shards), |jlo, jhi| {
        let y_ptr = &y_ptr;
        let mut cbuf = vec![0i8; k];
        let mut wrow = vec![0f32; k];
        for j in jlo..jhi {
            q.decode_row_scratch(j, &mut cbuf, &mut wrow);
            for i in 0..m {
                let v = dot_unrolled(&x_data[i * k..(i + 1) * k], &wrow);
                // SAFETY: disjoint column range per shard (see above).
                unsafe { *y_ptr.0.add(i * n + j) = v };
            }
        }
    });
    y
}

/// Column-parallel integer matmul ([`super::matmul_transb_q`] sharded):
/// recovers the activation codes once, then shards the panel GEMM.
/// Mirrors the unsharded fallback rule exactly — wide/fp activation grids
/// (> 256 levels) and grouped weight scales take the dequantizing path.
pub fn matmul_transb_q_sharded(x: &Mat, q: &QMat, a_levels: f32, shards: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_q_sharded inner-dim mismatch");
    if a_levels > 256.0 || q.is_grouped() {
        return matmul_transb_deq_sharded(x, q, shards);
    }
    let qa = QAct::from_quantized(x, a_levels);
    matmul_transb_qact_sharded(x, &qa, q, shards)
}

/// Column-parallel panel GEMM ([`super::matmul_transb_qact`] sharded):
/// shard the weight panels (disjoint `NR`-column output ranges) and run
/// the identical [`gemm::panel_block`] body per panel. i32 accumulation
/// plus whole-panel ownership make it bit-identical to the unsharded
/// GEMM at any shard count.
pub fn matmul_transb_qact_sharded(x: &Mat, qa: &QAct, q: &QMat, shards: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_qact_sharded inner-dim mismatch");
    assert_eq!((qa.rows(), qa.cols()), x.shape(), "QAct/x shape mismatch");
    if q.is_grouped() {
        return matmul_transb_deq_sharded(x, q, shards);
    }
    let (m, n) = (x.rows, q.rows());
    let mut y = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return y;
    }
    let panels = q.panels().expect("panel GEMM requires per-row scales");
    let n_panels = n.div_ceil(gemm::NR);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    run_shards(&shard_ranges(n_panels, shards), |plo, phi| {
        let y_ptr = &y_ptr;
        for p in plo..phi {
            gemm::panel_block(x, qa, q, panels, p, y_ptr);
        }
    });
    y
}

/// Row-parallel integer matmul: split the **k** dimension, each shard
/// accumulating partial `Σ_k qx[i][k]·qw[j][k]` (and partial weight
/// column sums) as i32 over its k range; partials reduce in shard-index
/// order ([`reduce_i32`]) and the float epilogue — the verbatim
/// expression of [`matmul_transb_q_ref`] — runs exactly once per output.
/// Exact at any shard count because the split only ever reassociates i32
/// sums. Wide grids / grouped scales take the (column-parallel)
/// dequantizing path: there is no exact f32 k-split.
pub fn matmul_transb_q_rowpar(x: &Mat, q: &QMat, a_levels: f32, shards: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_q_rowpar inner-dim mismatch");
    if a_levels > 256.0 || q.is_grouped() {
        return matmul_transb_deq_sharded(x, q, shards);
    }
    let qa = QAct::from_quantized(x, a_levels);
    matmul_transb_qact_rowpar(x, &qa, q, shards)
}

/// The row-parallel kernel proper (integer codes already recovered).
pub fn matmul_transb_qact_rowpar(x: &Mat, qa: &QAct, q: &QMat, shards: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_qact_rowpar inner-dim mismatch");
    assert_eq!((qa.rows(), qa.cols()), x.shape(), "QAct/x shape mismatch");
    if q.is_grouped() {
        return matmul_transb_deq_sharded(x, q, shards);
    }
    let (m, k, n) = (x.rows, x.cols, q.rows());
    let mut y = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return y;
    }
    let ranges = shard_ranges(k, shards);
    // Each shard owns its own partial accumulators; scoped_try_map joins
    // them back in shard-index (submission) order.
    let parts = crate::util::threadpool::scoped_try_map(
        ranges.len(),
        &ranges,
        |_, &(klo, khi)| {
            let mut acc = vec![0i32; m * n];
            let mut colsum = vec![0i32; n];
            let mut wbuf = vec![0i8; k];
            for j in 0..n {
                q.codes_row_into(j, &mut wbuf);
                let wslice = &wbuf[klo..khi];
                colsum[j] = wslice.iter().map(|&c| c as i32).sum();
                for i in 0..m {
                    let arow = &qa.code_row(i)[klo..khi];
                    let mut s: i32 = 0;
                    for (&a, &w) in arow.iter().zip(wslice) {
                        s += a as i32 * w as i32;
                    }
                    acc[i * n + j] = s;
                }
            }
            (acc, colsum)
        },
    )
    .expect("shard workers do not panic");
    let (accs, colsums): (Vec<_>, Vec<_>) = parts.into_iter().unzip();
    let acc = reduce_i32(accs);
    let colsum = reduce_i32(colsums);
    // One epilogue per output — the exact expression of the scalar
    // reference kernel (matmul_transb_q_ref), protected columns included.
    for j in 0..n {
        let sw = q.row_scale(j);
        let prot = q.protected_row(j);
        for i in 0..m {
            let (mn, sx) = qa.grid(i);
            let mut v = sw * (sx * acc[i * n + j] as f32 + mn * colsum[j] as f32);
            if let Some((idx, vals)) = prot {
                let xrow = x.row(i);
                for (&c, &pv) in idx.iter().zip(vals) {
                    v += xrow[c as usize] * pv;
                }
            }
            *y.at_mut(i, j) = v;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{
        fake_quant_rows, matmul_transb, matmul_transb_deq, matmul_transb_qact, quantize_act,
        QuantSpec,
    };
    use crate::util::prng::Pcg64;

    const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        for (n, shards) in [(1003usize, 7usize), (64, 4), (16, 16), (5, 2)] {
            let mut hits = vec![0usize; n];
            for (lo, hi) in shard_ranges(n, shards) {
                for h in &mut hits[lo..hi] {
                    *h += 1;
                }
            }
            assert!(hits.iter().all(|&h| h == 1), "(n={n}, shards={shards})");
        }
    }

    #[test]
    fn shard_ranges_degenerate_inputs() {
        // n = 0: one empty range, like par_ranges' single f(0, 0) call.
        assert_eq!(shard_ranges(0, 0), vec![(0, 0)]);
        assert_eq!(shard_ranges(0, 4), vec![(0, 0)]);
        // shards = 0 and shards = 1 both mean "the whole range".
        assert_eq!(shard_ranges(9, 0), vec![(0, 9)]);
        assert_eq!(shard_ranges(9, 1), vec![(0, 9)]);
        // shards > n clamps to n single-element ranges.
        assert_eq!(shard_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        // Every case still covers exactly once.
        for (n, shards) in [(0usize, 0usize), (0, 3), (1, 0), (1, 9), (3, 8), (7, 7)] {
            let mut hits = vec![0usize; n];
            let ranges = shard_ranges(n, shards);
            assert!(!ranges.is_empty(), "ranges never empty");
            for (lo, hi) in ranges {
                assert!(lo <= hi && hi <= n);
                for h in &mut hits[lo..hi] {
                    *h += 1;
                }
            }
            assert!(hits.iter().all(|&h| h == 1), "(n={n}, shards={shards})");
        }
    }

    #[test]
    fn reduce_i32_empty_and_singleton() {
        assert_eq!(reduce_i32(Vec::new()), Vec::<i32>::new());
        assert_eq!(reduce_i32(vec![vec![3, -1, 4]]), vec![3, -1, 4]);
        assert_eq!(reduce_i32(vec![Vec::new(), Vec::new()]), Vec::<i32>::new());
        assert_eq!(reduce_i32(vec![vec![1, 2], vec![10, 20], vec![100, 200]]), vec![111, 222]);
    }

    #[test]
    #[should_panic]
    fn reduce_i32_rejects_mismatched_lengths() {
        reduce_i32(vec![vec![1, 2], vec![3]]);
    }

    #[test]
    fn f32_column_parallel_is_bit_identical() {
        let a = rand_mat(1, 13, 48);
        let b = rand_mat(2, 29, 48);
        let want = matmul_transb(&a, &b);
        for shards in SHARD_COUNTS {
            assert_eq!(matmul_transb_sharded(&a, &b, shards).data, want.data, "{shards} shards");
        }
    }

    #[test]
    fn deq_column_parallel_is_bit_identical() {
        let w = rand_mat(3, 21, 40);
        let x = rand_mat(4, 9, 40);
        for bits in [4u8, 8] {
            let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
            let want = matmul_transb_deq(&x, &q);
            for shards in SHARD_COUNTS {
                assert_eq!(
                    matmul_transb_deq_sharded(&x, &q, shards).data,
                    want.data,
                    "{bits} bits, {shards} shards"
                );
            }
        }
    }

    #[test]
    fn integer_kernels_match_scalar_reference_at_all_shard_counts() {
        let a_levels = 16.0;
        for (seed, m, k) in [(5u64, 7usize, 33usize), (6, 12, 64)] {
            let w = rand_mat(seed, 19, k);
            let mut x = rand_mat(seed + 100, m, k);
            fake_quant_rows(&mut x, a_levels);
            for bits in [4u8, 8] {
                let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
                q.prepack();
                let want = matmul_transb_q_ref(&x, &q, a_levels);
                for shards in SHARD_COUNTS {
                    assert_eq!(
                        matmul_transb_q_sharded(&x, &q, a_levels, shards).data,
                        want.data,
                        "column-parallel, {bits} bits, {shards} shards"
                    );
                    assert_eq!(
                        matmul_transb_q_rowpar(&x, &q, a_levels, shards).data,
                        want.data,
                        "row-parallel, {bits} bits, {shards} shards"
                    );
                }
            }
        }
    }

    #[test]
    fn qact_sharded_matches_unsharded_including_protected() {
        let k = 48;
        let w = rand_mat(7, 17, k);
        let mut x = rand_mat(8, 6, k);
        fake_quant_rows(&mut x, 16.0);
        let qa = quantize_act(&mut x, 16.0).expect("integer grid");
        let mut mask = vec![false; k];
        mask[3] = true;
        mask[40] = true;
        let quants = [
            QMat::quantize_rtn(&w, QuantSpec::new(4)),
            QMat::quantize_protected(&w, QuantSpec::new(4), &mask),
        ];
        for q in &quants {
            q.prepack();
            let want = matmul_transb_qact(&x, &qa, q);
            for shards in SHARD_COUNTS {
                assert_eq!(
                    matmul_transb_qact_sharded(&x, &qa, q, shards).data,
                    want.data,
                    "{} scheme, {shards} shards",
                    q.scheme_label()
                );
                assert_eq!(
                    matmul_transb_qact_rowpar(&x, &qa, q, shards).data,
                    want.data,
                    "{} scheme rowpar, {shards} shards",
                    q.scheme_label()
                );
            }
        }
    }

    #[test]
    fn grouped_and_wide_grids_take_the_deq_path_sharded() {
        let k = 32;
        let w = rand_mat(9, 11, k);
        let x = rand_mat(10, 5, k);
        let order: Vec<usize> = (0..k).rev().collect();
        let g = QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 8);
        let want = matmul_transb_deq(&x, &g);
        for shards in SHARD_COUNTS {
            assert_eq!(matmul_transb_q_sharded(&x, &g, 16.0, shards).data, want.data);
            assert_eq!(matmul_transb_q_rowpar(&x, &g, 16.0, shards).data, want.data);
        }
        // Wide activation grid (> 256 levels) falls back identically.
        let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
        let wide = matmul_transb_deq(&x, &q);
        for shards in SHARD_COUNTS {
            assert_eq!(matmul_transb_q_sharded(&x, &q, 65536.0, shards).data, wide.data);
        }
    }
}
