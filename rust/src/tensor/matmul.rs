//! Blocked, threaded f32 matmul. This is the native-path workhorse (model
//! forward for activation capture, GPTQ Hessians, fusion checks). The PJRT
//! path handles the calibration hot loop; this one must merely be fast
//! enough that capture/eval of the tiny configs stays interactive, so we use
//! the classic i-k-j loop order with row blocking and thread-parallel rows.

use super::Mat;
use crate::util::threadpool::par_ranges;

/// Threshold below which threading overhead dominates.
const PAR_FLOPS_THRESHOLD: usize = 1 << 22;

/// Resolve an explicit thread count (0 = the flops-based default shared
/// by every dense and packed matmul kernel).
pub(crate) fn resolve_threads(threads: usize, flops: usize) -> usize {
    if threads > 0 {
        threads
    } else if flops < PAR_FLOPS_THRESHOLD {
        1
    } else {
        crate::util::threadpool::ThreadPool::default_parallelism()
    }
}

/// Dot product with 4-way unrolled accumulation for ILP — the one inner
/// kernel `matmul_transb` and the packed `matmul_transb_deq` share, which
/// is what makes the packed path bit-identical to the dense oracle.
#[inline]
pub(crate) fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    debug_assert_eq!(k, b.len());
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = k / 4;
    for c4 in 0..chunks {
        let p = c4 * 4;
        s0 += a[p] * b[p];
        s1 += a[p + 1] * b[p + 1];
        s2 += a[p + 2] * b[p + 2];
        s3 += a[p + 3] * b[p + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for p in chunks * 4..k {
        s += a[p] * b[p];
    }
    s
}

/// C = A · B.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B into a preallocated output (hot loops reuse the buffer).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.fill(0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let flops = 2 * m * k * n;
    let threads = if flops < PAR_FLOPS_THRESHOLD {
        1
    } else {
        crate::util::threadpool::ThreadPool::default_parallelism()
    };
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    par_ranges(m, threads, |lo, hi| {
        let c_ptr = &c_ptr;
        for i in lo..hi {
            // SAFETY: par_ranges hands each thread a disjoint row range
            // [lo, hi), so row i aliases no other thread's slice; i < m
            // keeps the slice inside C's m*n buffer.
            let c_row = unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n) };
            let a_row = &a_data[i * k..(i + 1) * k];
            for (kk, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let b_row = &b_data[kk * n..(kk + 1) * n];
                // i-k-j: unit-stride over both C and B; autovectorizes.
                for (cj, bj) in c_row.iter_mut().zip(b_row.iter()) {
                    *cj += aik * bj;
                }
            }
        }
    });
}

/// C = A · Bᵀ (B given row-major as (n, k)): the natural layout for
/// `X · Wᵀ` linear layers, avoiding a materialized transpose of W.
pub fn matmul_transb(a: &Mat, b: &Mat) -> Mat {
    matmul_transb_with(a, b, 0)
}

/// [`matmul_transb`] with an explicit thread count (0 = the flops-based
/// default; benches pass `DQ_WORKERS` for apples-to-apples rows).
pub fn matmul_transb_with(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_transb inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    let threads = resolve_threads(threads, 2 * m * k * n);
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    par_ranges(m, threads, |lo, hi| {
        let c_ptr = &c_ptr;
        for i in lo..hi {
            // SAFETY: disjoint row range per thread (see matmul_into) and
            // i < m bounds the slice inside C's m*n buffer.
            let c_row = unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n) };
            let a_row = &a_data[i * k..(i + 1) * k];
            for (j, cij) in c_row.iter_mut().enumerate() {
                *cij = dot_unrolled(a_row, &b_data[j * k..(j + 1) * k]);
            }
        }
    });
    c
}

/// Shareable raw pointer for the disjoint-element parallel write pattern
/// (each thread writes a disjoint row or column range).
pub(crate) struct SendPtr(pub(crate) *mut f32);
// SAFETY: shared only across par_ranges' scoped threads, each writing a
// disjoint element range, so concurrent access never aliases a write.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows, b.cols, |i, j| {
            (0..a.cols).map(|k| a.at(i, k) * b.at(k, j)).sum()
        })
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(1, 1, 1), (2, 3, 4), (7, 5, 9), (16, 16, 16)] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
        }
    }

    #[test]
    fn matches_naive_large_threaded() {
        let mut rng = Pcg64::new(2);
        let a = Mat::from_fn(130, 257, |_, _| rng.normal());
        let b = Mat::from_fn(257, 190, |_, _| rng.normal());
        let d = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
        assert!(d < 1e-3, "diff {d}");
    }

    #[test]
    fn transb_equals_transpose_then_mul() {
        let mut rng = Pcg64::new(3);
        let a = Mat::from_fn(33, 48, |_, _| rng.normal());
        let w = Mat::from_fn(29, 48, |_, _| rng.normal());
        let d = matmul_transb(&a, &w).max_abs_diff(&matmul(&a, &w.t()));
        assert!(d < 1e-4, "diff {d}");
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(4);
        let a = Mat::from_fn(12, 12, |_, _| rng.normal());
        assert!(matmul(&a, &Mat::eye(12)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Mat::eye(12), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn into_buffer_reuse() {
        let mut rng = Pcg64::new(5);
        let a = Mat::from_fn(8, 6, |_, _| rng.normal());
        let b = Mat::from_fn(6, 10, |_, _| rng.normal());
        let mut c = Mat::from_fn(8, 10, |_, _| 999.0); // dirty buffer
        matmul_into(&a, &b, &mut c);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-4);
    }
}
