//! Packed quantized-weight storage: integer codes + scales instead of
//! dequantized f32 — the representation that actually realizes the
//! paper's memory story (a 4-bit model holds ~1/8 of the f32 bytes
//! instead of pretending).
//!
//! [`QMat`] stores row-major i8 codes (nibble-packed at ≤ 4 bits) with one
//! of three scale schemes covering every weight quantizer in `quant`:
//!
//! * **per-row** symmetric scales (RTN, GPTQ, OmniQuant),
//! * **protected** — per-row scales over the unprotected columns plus
//!   full-precision values for the protected ones (QUIK mixed precision),
//! * **grouped** — reordered per-group scales with the top group kept at
//!   8 bits (Atom mixed precision).
//!
//! The equivalence contract (see `docs/QUANTIZED_STORAGE.md`):
//! [`QMat::dequantize`] is **bit-identical** to the historical fake-quant
//! output (`code as f32 * scale` reproduces
//! `(v / scale).round().clamp(..) * scale` exactly), and
//! [`matmul_transb_deq`] is bit-identical to `matmul_transb` against the
//! dequantized matrix (same dot kernel, same operands). The integer path
//! [`matmul_transb_q`] trades that bit-exactness for i8×i8 → i32
//! accumulation with scales applied once per output; it agrees with the
//! dequantized oracle to f32 reassociation error (~1e-6 relative) and
//! runs through the cache-blocked panel GEMM in `tensor::gemm` — which
//! is in turn bit-identical to the scalar reference
//! [`matmul_transb_q_ref`] (i32 sums are associative; the float epilogue
//! is the same expression).

use super::matmul::{dot_unrolled, resolve_threads, SendPtr};
use super::qact::QAct;
use super::Mat;
use crate::util::threadpool::par_ranges;
use std::sync::OnceLock;

/// Symmetric quantization grid: bit width + derived constants. The one
/// scale/round/clamp definition every weight quantizer shares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSpec {
    bits: u8,
}

impl QuantSpec {
    /// A packed grid at `bits` ∈ [2, 8]. Widths outside that range don't
    /// pack (use [`QuantSpec::supports`] to gate callers).
    pub fn new(bits: u8) -> QuantSpec {
        assert!(
            QuantSpec::supports(bits),
            "QMat packs 2..=8 bit codes, got {bits}"
        );
        QuantSpec { bits }
    }

    /// Whether `bits` fits the packed representation.
    pub fn supports(bits: u8) -> bool {
        (2..=8).contains(&bits)
    }

    /// The code bit width.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Largest positive code on the symmetric grid (2^{b-1} − 1).
    pub fn qmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1) as f32
    }

    /// Most negative code (−2^{b-1}).
    pub fn qmin(&self) -> f32 {
        -self.qmax() - 1.0
    }

    /// Scale of a symmetric grid spanning |v| ≤ `amax` (floored away from
    /// zero exactly like the historical quantizers).
    pub fn scale_for(&self, amax: f32) -> f32 {
        (amax / self.qmax()).max(1e-10)
    }

    /// Whether codes nibble-pack two per byte.
    pub fn packs_nibbles(&self) -> bool {
        self.bits <= 4
    }

    /// Encode one value on the grid `scale`: round-to-nearest, clamped to
    /// [qmin, qmax] — `code as f32 * scale` reproduces the historical
    /// fake-quant value bit-for-bit.
    #[inline]
    pub fn encode(&self, v: f32, scale: f32) -> i8 {
        (v / scale).round().clamp(self.qmin(), self.qmax()) as i8
    }
}

/// The shared scale/round/clamp kernel: encode `row` on the symmetric
/// grid `scale` into integer codes. Every quantizer in `quant` funnels
/// through here (directly or via the [`QMat`] constructors).
pub fn quantize_into(spec: QuantSpec, row: &[f32], scale: f32, out: &mut [i8]) {
    assert_eq!(row.len(), out.len());
    for (o, &v) in out.iter_mut().zip(row) {
        *o = spec.encode(v, scale);
    }
}

/// Code storage: plain i8, or two's-complement nibbles (two per byte,
/// even column in the low nibble; rows are byte-aligned).
#[derive(Clone, Debug, PartialEq)]
enum Codes {
    I8(Vec<i8>),
    I4(Vec<u8>),
}

#[inline]
pub(crate) fn sign_extend_nibble(n: u8) -> i8 {
    (((n & 0x0F) << 4) as i8) >> 4
}

impl Codes {
    fn pack(flat: Vec<i8>, rows: usize, cols: usize, spec: QuantSpec) -> Codes {
        debug_assert_eq!(flat.len(), rows * cols);
        if !spec.packs_nibbles() {
            return Codes::I8(flat);
        }
        let bpr = cols.div_ceil(2);
        let mut v = vec![0u8; rows * bpr];
        for i in 0..rows {
            for c in 0..cols {
                let code = flat[i * cols + c];
                debug_assert!((-8..=7).contains(&code), "i4 code {code} out of range");
                let nib = (code as u8) & 0x0F;
                v[i * bpr + c / 2] |= if c % 2 == 0 { nib } else { nib << 4 };
            }
        }
        Codes::I4(v)
    }

    fn nbytes(&self) -> u64 {
        match self {
            Codes::I8(v) => v.len() as u64,
            Codes::I4(v) => v.len() as u64,
        }
    }

    fn row_into(&self, i: usize, cols: usize, out: &mut [i8]) {
        debug_assert_eq!(out.len(), cols);
        match self {
            Codes::I8(v) => out.copy_from_slice(&v[i * cols..(i + 1) * cols]),
            Codes::I4(v) => {
                let bpr = cols.div_ceil(2);
                let row = &v[i * bpr..(i + 1) * bpr];
                for (c, o) in out.iter_mut().enumerate() {
                    let b = row[c / 2];
                    *o = sign_extend_nibble(if c % 2 == 0 { b } else { b >> 4 });
                }
            }
        }
    }
}

/// How codes map back to f32 — the per-quantizer scale metadata.
#[derive(Clone, Debug, PartialEq)]
enum Scheme {
    /// One symmetric scale per output row (RTN / GPTQ / OmniQuant).
    PerRow {
        /// len = rows.
        scales: Vec<f32>,
    },
    /// QUIK mixed precision: per-row scales scanned over the unprotected
    /// columns; protected columns keep their full-precision values (their
    /// codes are stored as 0).
    Protected {
        /// len = rows.
        scales: Vec<f32>,
        /// len = cols; true = protected.
        mask: Vec<bool>,
        /// Ascending protected column indices.
        cols_idx: Vec<u32>,
        /// rows × cols_idx.len(), row-major full-precision values.
        values: Vec<f32>,
    },
    /// Atom mixed precision: columns reordered by activation magnitude,
    /// quantized in groups with per-group scales; the top group's codes
    /// are 8-bit (stored separately so the bulk can still nibble-pack).
    Grouped {
        /// Inverse permutation: rank[c] = position of column c in the
        /// activation-magnitude order.
        rank: Vec<u32>,
        /// Columns per group.
        group: usize,
        /// Groups per row (= ceil(cols / group)).
        n_groups: usize,
        /// rows × n_groups, row-major.
        scales: Vec<f32>,
        /// rows × hi_len 8-bit codes of group 0 (bulk codes there are 0).
        hi_codes: Vec<i8>,
        /// Top-group length (= min(group, cols)).
        hi_len: usize,
    },
}

impl Scheme {
    fn nbytes(&self) -> u64 {
        match self {
            Scheme::PerRow { scales } => 4 * scales.len() as u64,
            Scheme::Protected { scales, mask, cols_idx, values } => {
                4 * (scales.len() + cols_idx.len() + values.len()) as u64 + mask.len() as u64
            }
            Scheme::Grouped { rank, scales, hi_codes, .. } => {
                4 * (rank.len() + scales.len()) as u64 + hi_codes.len() as u64
            }
        }
    }
}

/// A packed quantized matrix: integer codes + scale metadata standing in
/// for a dense `[rows, cols]` f32 weight (applied as `x · Wᵀ`, exactly
/// like [`Mat`] weights).
///
/// Alongside the stored representation, a `QMat` lazily caches the
/// panel-packed code layout the tiled integer GEMM streams
/// (`tensor::gemm`). The cache is **derived data**: it is rebuilt on
/// demand, never serialized, excluded from [`QMat::nbytes`] (see
/// [`QMat::panel_nbytes`]) and ignored by `PartialEq`. Quantizers call
/// [`QMat::prepack`] so the pack cost is paid at quantization time, not
/// on the first forward.
#[derive(Clone, Debug)]
pub struct QMat {
    rows: usize,
    cols: usize,
    spec: QuantSpec,
    codes: Codes,
    scheme: Scheme,
    panels: OnceLock<super::gemm::Panels>,
}

impl PartialEq for QMat {
    /// Equality over the stored representation only — the derived panel
    /// cache (built or not) never affects comparison, so a prepacked
    /// matrix compares equal to its deserialized blob roundtrip.
    fn eq(&self, other: &QMat) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.spec == other.spec
            && self.codes == other.codes
            && self.scheme == other.scheme
    }
}

impl QMat {
    /// RTN: per-row abs-max symmetric scales.
    pub fn quantize_rtn(w: &Mat, spec: QuantSpec) -> QMat {
        let scales = (0..w.rows)
            .map(|i| {
                let amax = w.row(i).iter().map(|v| v.abs()).fold(0.0f32, f32::max);
                spec.scale_for(amax)
            })
            .collect();
        QMat::quantize_with_scales(w, spec, scales)
    }

    /// Encode on caller-provided per-row grids (GPTQ's final snap,
    /// OmniQuant's clipped scales).
    pub fn quantize_with_scales(w: &Mat, spec: QuantSpec, scales: Vec<f32>) -> QMat {
        assert_eq!(scales.len(), w.rows, "one scale per output row");
        let mut flat = vec![0i8; w.rows * w.cols];
        for i in 0..w.rows {
            quantize_into(spec, w.row(i), scales[i], &mut flat[i * w.cols..(i + 1) * w.cols]);
        }
        QMat {
            rows: w.rows,
            cols: w.cols,
            spec,
            codes: Codes::pack(flat, w.rows, w.cols, spec),
            scheme: Scheme::PerRow { scales },
            panels: OnceLock::new(),
        }
    }

    /// QUIK-style mixed precision: `mask[c]` columns keep full precision,
    /// the rest land on a per-row grid whose scale scans unprotected
    /// columns only.
    pub fn quantize_protected(w: &Mat, spec: QuantSpec, mask: &[bool]) -> QMat {
        assert_eq!(mask.len(), w.cols, "one mask entry per input column");
        let cols_idx: Vec<u32> = mask
            .iter()
            .enumerate()
            .filter_map(|(c, &m)| m.then_some(c as u32))
            .collect();
        let mut scales = Vec::with_capacity(w.rows);
        let mut values = Vec::with_capacity(w.rows * cols_idx.len());
        let mut flat = vec![0i8; w.rows * w.cols];
        for i in 0..w.rows {
            let row = w.row(i);
            let mut amax = 0.0f32;
            for (c, &v) in row.iter().enumerate() {
                if !mask[c] {
                    amax = amax.max(v.abs());
                }
            }
            let scale = spec.scale_for(amax);
            scales.push(scale);
            let crow = &mut flat[i * w.cols..(i + 1) * w.cols];
            for (c, &v) in row.iter().enumerate() {
                if mask[c] {
                    values.push(v);
                } else {
                    crow[c] = spec.encode(v, scale);
                }
            }
        }
        QMat {
            rows: w.rows,
            cols: w.cols,
            spec,
            codes: Codes::pack(flat, w.rows, w.cols, spec),
            scheme: Scheme::Protected { scales, mask: mask.to_vec(), cols_idx, values },
            panels: OnceLock::new(),
        }
    }

    /// Atom-style mixed precision: `order` permutes columns by activation
    /// magnitude; each `group`-column chunk gets its own per-row scale,
    /// and the first chunk is kept at 8 bits.
    pub fn quantize_grouped(w: &Mat, spec: QuantSpec, order: &[usize], group: usize) -> QMat {
        assert_eq!(order.len(), w.cols, "order must permute the input columns");
        assert!(group > 0);
        let hi = QuantSpec::new(8);
        let n_groups = w.cols.div_ceil(group);
        let hi_len = group.min(w.cols);
        let mut rank = vec![0u32; w.cols];
        for (r, &c) in order.iter().enumerate() {
            rank[c] = r as u32;
        }
        let mut scales = vec![0f32; w.rows * n_groups];
        let mut hi_codes = vec![0i8; w.rows * hi_len];
        let mut flat = vec![0i8; w.rows * w.cols];
        for i in 0..w.rows {
            for (g, chunk) in order.chunks(group).enumerate() {
                let gspec = if g == 0 { hi } else { spec };
                let amax = chunk.iter().map(|&c| w.at(i, c).abs()).fold(0.0f32, f32::max);
                let scale = gspec.scale_for(amax);
                scales[i * n_groups + g] = scale;
                for (r, &c) in chunk.iter().enumerate() {
                    let code = gspec.encode(w.at(i, c), scale);
                    if g == 0 {
                        hi_codes[i * hi_len + r] = code;
                    } else {
                        flat[i * w.cols + c] = code;
                    }
                }
            }
        }
        QMat {
            rows: w.rows,
            cols: w.cols,
            spec,
            codes: Codes::pack(flat, w.rows, w.cols, spec),
            scheme: Scheme::Grouped { rank, group, n_groups, scales, hi_codes, hi_len },
            panels: OnceLock::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// The bulk-code grid.
    pub fn spec(&self) -> QuantSpec {
        self.spec
    }

    /// Scheme label for reports ("per-row" / "protected" / "grouped").
    pub fn scheme_label(&self) -> &'static str {
        match self.scheme {
            Scheme::PerRow { .. } => "per-row",
            Scheme::Protected { .. } => "protected",
            Scheme::Grouped { .. } => "grouped",
        }
    }

    /// True packed footprint: codes + scales + mixed-precision metadata.
    pub fn nbytes(&self) -> u64 {
        self.codes.nbytes() + self.scheme.nbytes()
    }

    /// Bytes of the dense f32 equivalent.
    pub fn dense_nbytes(&self) -> u64 {
        (self.rows * self.cols * 4) as u64
    }

    /// Packed-size estimate for a per-row-scaled `[rows, cols]` matrix —
    /// budget accounting before the matrix exists.
    pub fn packed_estimate(rows: usize, cols: usize, spec: QuantSpec) -> u64 {
        let codes = if spec.packs_nibbles() { rows * cols.div_ceil(2) } else { rows * cols };
        (codes + 4 * rows) as u64
    }

    /// Unpack row `i`'s bulk codes (protected columns read 0; grouped
    /// top-group columns read 0 — their codes live in the scheme).
    pub(crate) fn codes_row_into(&self, i: usize, out: &mut [i8]) {
        self.codes.row_into(i, self.cols, out);
    }

    /// Whether the scale scheme is grouped (Atom) — those take the
    /// dequantizing matmul path instead of the panel GEMM.
    pub(crate) fn is_grouped(&self) -> bool {
        matches!(self.scheme, Scheme::Grouped { .. })
    }

    /// Row `j`'s symmetric scale (per-row and protected schemes only).
    pub(crate) fn row_scale(&self, j: usize) -> f32 {
        match &self.scheme {
            Scheme::PerRow { scales } | Scheme::Protected { scales, .. } => scales[j],
            Scheme::Grouped { .. } => unreachable!("grouped delegates to the deq path"),
        }
    }

    /// Row `j`'s protected columns `(indices, full-precision values)`,
    /// or `None` for schemes without protection.
    pub(crate) fn protected_row(&self, j: usize) -> Option<(&[u32], &[f32])> {
        match &self.scheme {
            Scheme::Protected { cols_idx, values, .. } => {
                let np = cols_idx.len();
                Some((cols_idx.as_slice(), &values[j * np..(j + 1) * np]))
            }
            _ => None,
        }
    }

    /// The cached panel-packed code layout for the tiled integer GEMM,
    /// built on first use. `None` for grouped scales (no per-row scale
    /// to fold into the panel epilogue — those run the deq path).
    pub(crate) fn panels(&self) -> Option<&super::gemm::Panels> {
        if self.is_grouped() {
            return None;
        }
        Some(self.panels.get_or_init(|| super::gemm::Panels::build(self)))
    }

    /// Eagerly build the panel cache. Quantizers call this at pack time
    /// so the repack cost lands in quantization, not on the first
    /// forward; deserialized weights (`from_bytes`) pack lazily instead.
    /// No-op for grouped scales.
    pub fn prepack(&self) {
        let _ = self.panels();
    }

    /// Bytes held by the derived panel cache — 0 until built. Reported
    /// separately from [`QMat::nbytes`], which counts only the stored
    /// representation (codes + scale metadata).
    pub fn panel_nbytes(&self) -> u64 {
        self.panels.get().map_or(0, |p| p.nbytes())
    }

    /// Decode row `i` into `out` — bit-identical to the historical
    /// fake-quant output for every scheme.
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        let mut buf = vec![0i8; self.cols];
        self.decode_row_scratch(i, &mut buf, out);
    }

    /// [`QMat::decode_row_into`] with a caller-held code scratch — the
    /// streaming matmul and `dequantize` reuse one buffer across rows
    /// instead of allocating per weight row.
    pub(crate) fn decode_row_scratch(&self, i: usize, buf: &mut [i8], out: &mut [f32]) {
        assert_eq!(out.len(), self.cols);
        self.codes_row_into(i, buf);
        match &self.scheme {
            Scheme::PerRow { scales } => {
                let s = scales[i];
                for (o, &c) in out.iter_mut().zip(buf.iter()) {
                    *o = c as f32 * s;
                }
            }
            Scheme::Protected { scales, mask, cols_idx, values } => {
                let s = scales[i];
                for (o, &c) in out.iter_mut().zip(buf.iter()) {
                    *o = c as f32 * s;
                }
                let vrow = &values[i * cols_idx.len()..(i + 1) * cols_idx.len()];
                debug_assert_eq!(mask.len(), self.cols);
                for (&c, &v) in cols_idx.iter().zip(vrow) {
                    out[c as usize] = v;
                }
            }
            Scheme::Grouped { rank, group, n_groups, scales, hi_codes, hi_len } => {
                let srow = &scales[i * n_groups..(i + 1) * n_groups];
                let hrow = &hi_codes[i * hi_len..(i + 1) * hi_len];
                for (c, o) in out.iter_mut().enumerate() {
                    let r = rank[c] as usize;
                    let g = r / group;
                    let code = if g == 0 { hrow[r] } else { buf[c] };
                    *o = code as f32 * srow[g];
                }
            }
        }
    }

    /// Serialize codes + scale metadata to the little-endian binary blob
    /// format of the indexed artifact (`docs/STREAMING.md` documents the
    /// layout). [`QMat::from_bytes`] is the exact inverse: the decoded
    /// matrix compares equal (`PartialEq`) to the original, so packed
    /// checkpoints roundtrip **bit-identically** — no dequantize/requantize
    /// detour, and `nbytes()` is preserved.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(self.nbytes() as usize + 64);
        b.push(self.spec.bits());
        push_u32(&mut b, self.rows as u32);
        push_u32(&mut b, self.cols as u32);
        match &self.codes {
            Codes::I8(v) => {
                b.push(0);
                push_u64(&mut b, v.len() as u64);
                b.extend(v.iter().map(|&c| c as u8));
            }
            Codes::I4(v) => {
                b.push(1);
                push_u64(&mut b, v.len() as u64);
                b.extend_from_slice(v);
            }
        }
        match &self.scheme {
            Scheme::PerRow { scales } => {
                b.push(0);
                push_f32s(&mut b, scales);
            }
            Scheme::Protected { scales, mask, cols_idx, values } => {
                b.push(1);
                push_f32s(&mut b, scales);
                push_u64(&mut b, mask.len() as u64);
                b.extend(mask.iter().map(|&m| m as u8));
                push_u32s(&mut b, cols_idx);
                push_f32s(&mut b, values);
            }
            Scheme::Grouped { rank, group, n_groups, scales, hi_codes, hi_len } => {
                b.push(2);
                push_u32s(&mut b, rank);
                push_u64(&mut b, *group as u64);
                push_u64(&mut b, *n_groups as u64);
                push_f32s(&mut b, scales);
                push_u64(&mut b, hi_codes.len() as u64);
                b.extend(hi_codes.iter().map(|&c| c as u8));
                push_u64(&mut b, *hi_len as u64);
            }
        }
        b
    }

    /// Parse a [`QMat::to_bytes`] blob back. Validates the grid width,
    /// code-buffer geometry and scheme metadata lengths, so a truncated
    /// or corrupt artifact entry fails contextfully instead of panicking
    /// later in a matmul.
    pub fn from_bytes(buf: &[u8]) -> anyhow::Result<QMat> {
        let mut c = Cursor { buf, at: 0 };
        let bits = c.u8()?;
        anyhow::ensure!(QuantSpec::supports(bits), "packed blob has unsupported bit width {bits}");
        let spec = QuantSpec::new(bits);
        let rows = c.u32()? as usize;
        let cols = c.u32()? as usize;
        let codes = match c.u8()? {
            0 => {
                anyhow::ensure!(!spec.packs_nibbles(), "i8 codes with a nibble-packing width");
                let n = c.u64()? as usize;
                anyhow::ensure!(n == rows * cols, "i8 code count {n} != {rows}×{cols}");
                Codes::I8(c.bytes(n)?.iter().map(|&v| v as i8).collect())
            }
            1 => {
                anyhow::ensure!(spec.packs_nibbles(), "nibble codes with an i8-storage width");
                let n = c.u64()? as usize;
                anyhow::ensure!(
                    n == rows * cols.div_ceil(2),
                    "i4 code bytes {n} != {rows}×ceil({cols}/2)"
                );
                Codes::I4(c.bytes(n)?.to_vec())
            }
            t => anyhow::bail!("unknown code storage tag {t}"),
        };
        let scheme = match c.u8()? {
            0 => {
                let scales = c.f32s()?;
                anyhow::ensure!(scales.len() == rows, "per-row scale count mismatch");
                Scheme::PerRow { scales }
            }
            1 => {
                let scales = c.f32s()?;
                let n_mask = c.u64()? as usize;
                anyhow::ensure!(n_mask == cols, "protected mask length mismatch");
                let mask: Vec<bool> = c.bytes(n_mask)?.iter().map(|&m| m != 0).collect();
                let cols_idx = c.u32s()?;
                let values = c.f32s()?;
                anyhow::ensure!(
                    scales.len() == rows && values.len() == rows * cols_idx.len(),
                    "protected scheme metadata mismatch"
                );
                Scheme::Protected { scales, mask, cols_idx, values }
            }
            2 => {
                let rank = c.u32s()?;
                let group = c.u64()? as usize;
                let n_groups = c.u64()? as usize;
                let scales = c.f32s()?;
                let n_hi = c.u64()? as usize;
                let hi_codes: Vec<i8> = c.bytes(n_hi)?.iter().map(|&v| v as i8).collect();
                let hi_len = c.u64()? as usize;
                anyhow::ensure!(
                    rank.len() == cols
                        && group > 0
                        && n_groups == cols.div_ceil(group)
                        && scales.len() == rows * n_groups
                        && hi_len == group.min(cols)
                        && hi_codes.len() == rows * hi_len,
                    "grouped scheme metadata mismatch"
                );
                Scheme::Grouped { rank, group, n_groups, scales, hi_codes, hi_len }
            }
            t => anyhow::bail!("unknown scale scheme tag {t}"),
        };
        anyhow::ensure!(c.at == buf.len(), "trailing bytes in packed blob");
        Ok(QMat { rows, cols, spec, codes, scheme, panels: OnceLock::new() })
    }

    /// Materialize the dense f32 matrix this QMat stands in for.
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let mut buf = vec![0i8; self.cols];
        for i in 0..self.rows {
            let row = &mut out.data[i * self.cols..(i + 1) * self.cols];
            self.decode_row_scratch(i, &mut buf, row);
        }
        out
    }
}

// --------------------------------------------------------------- blob I/O

fn push_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(b: &mut Vec<u8>, v: &[f32]) {
    push_u64(b, v.len() as u64);
    for x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_u32s(b: &mut Vec<u8>, v: &[u32]) {
    push_u64(b, v.len() as u64);
    for x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a packed blob.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn bytes(&mut self, n: usize) -> anyhow::Result<&[u8]> {
        let end = self.at.checked_add(n);
        anyhow::ensure!(end.is_some_and(|e| e <= self.buf.len()), "packed blob truncated");
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        // Bound n against buf.len()/4 *before* computing n * 4 — a
        // corrupt length near usize::MAX would wrap the multiplication
        // and sneak past the bytes() check.
        let n = self.u64()? as usize;
        anyhow::ensure!(n <= self.buf.len() / 4, "f32 array length {n} exceeds blob");
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.u64()? as usize;
        anyhow::ensure!(n <= self.buf.len() / 4, "u32 array length {n} exceeds blob");
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// `y = x · dequantize(Q)ᵀ` streaming codes instead of a materialized
/// dense weight — **bit-identical** to
/// `matmul_transb(x, &q.dequantize())` (same dot kernel, same decoded
/// operands), with ~4–8× less weight memory traffic.
pub fn matmul_transb_deq(x: &Mat, q: &QMat) -> Mat {
    matmul_transb_deq_with(x, q, 0)
}

/// [`matmul_transb_deq`] with an explicit thread count (0 = the same
/// flops-based default the f32 kernels use; benches pass `DQ_WORKERS`).
pub fn matmul_transb_deq_with(x: &Mat, q: &QMat, threads: usize) -> Mat {
    assert_eq!(x.cols, q.cols, "matmul_transb_deq inner-dim mismatch");
    let (m, k, n) = (x.rows, x.cols, q.rows);
    let mut y = Mat::zeros(m, n);
    let threads = resolve_threads(threads, 2 * m * k * n);
    let x_data = &x.data;
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    par_ranges(n, threads, |jlo, jhi| {
        let y_ptr = &y_ptr;
        let mut cbuf = vec![0i8; k];
        let mut wrow = vec![0f32; k];
        for j in jlo..jhi {
            q.decode_row_scratch(j, &mut cbuf, &mut wrow);
            for i in 0..m {
                let v = dot_unrolled(&x_data[i * k..(i + 1) * k], &wrow);
                // SAFETY: each thread writes the disjoint column range
                // [jlo, jhi) — no two threads touch the same element.
                unsafe { *y_ptr.0.add(i * n + j) = v };
            }
        }
    });
    y
}

/// The integer forward path: activations on the per-row asymmetric
/// fake-quant grid at `a_levels` (≤ 256 levels), i8 weight codes,
/// **i8×i8 → i32 accumulation**, scales applied once per output:
///
/// ```text
/// y[i][j] = s_w[j] · (s_x[i] · Σ_k qx[i][k]·qw[j][k]  +  mn[i] · Σ_k qw[j][k])
/// ```
///
/// (plus the f32 protected-column contribution for QUIK-packed weights).
/// `x` must already be on the `a_levels` fake-quant grid — the rows'
/// codes are recovered exactly. Falls back to [`matmul_transb_deq`] when
/// the activations aren't integer-gridded (`a_levels` > 256, i.e. fp or
/// wide settings) or the weights use grouped scales.
pub fn matmul_transb_q(x: &Mat, q: &QMat, a_levels: f32) -> Mat {
    matmul_transb_q_with(x, q, a_levels, 0)
}

/// [`matmul_transb_q`] with an explicit thread count (0 = default).
///
/// The activation codes are recovered **once** for the whole call
/// ([`QAct::from_quantized`] — x rows sit on the fake-quant grid, so
/// round-to-nearest against the recomputed (mn, scale) is exact) and the
/// product runs through the cache-blocked panel GEMM (`tensor::gemm`).
/// i32 accumulation is associative, so the blocked sum is bit-identical
/// to the historical scalar loop — retained below as
/// [`matmul_transb_q_ref`], the oracle `rust/tests/gemm.rs` sweeps
/// against.
pub fn matmul_transb_q_with(x: &Mat, q: &QMat, a_levels: f32, threads: usize) -> Mat {
    assert_eq!(x.cols, q.cols, "matmul_transb_q inner-dim mismatch");
    if a_levels > 256.0 || matches!(q.scheme, Scheme::Grouped { .. }) {
        return matmul_transb_deq_with(x, q, threads);
    }
    let qa = QAct::from_quantized(x, a_levels);
    super::gemm::gemm_qact(x, &qa, q, threads)
}

/// The pre-tiling scalar integer kernel, kept **verbatim** as the
/// reference implementation: one dot loop per output, per-call code
/// recovery, identical i32 accumulation semantics and float epilogue.
/// `rust/tests/gemm.rs` asserts the blocked GEMM is bit-identical to
/// this across ragged shapes, schemes and edge grids. Not a hot path —
/// serial, no panel cache.
pub fn matmul_transb_q_ref(x: &Mat, q: &QMat, a_levels: f32) -> Mat {
    assert_eq!(x.cols, q.cols, "matmul_transb_q inner-dim mismatch");
    if a_levels > 256.0 || matches!(q.scheme, Scheme::Grouped { .. }) {
        return matmul_transb_deq_with(x, q, 1);
    }
    let (m, k, n) = (x.rows, x.cols, q.rows);
    // Recover the activation codes: x rows sit on the fake-quant grid, so
    // round-to-nearest against the recomputed (mn, scale) is exact.
    let mut qx = vec![0u8; m * k];
    let mut sx = vec![0f32; m];
    let mut mns = vec![0f32; m];
    let hi = a_levels - 1.0;
    for i in 0..m {
        let row = x.row(i);
        let (mut mn, mut mx) = (f32::MAX, f32::MIN);
        for &v in row {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let scale = (mx - mn) / (a_levels - 1.0).max(1.0);
        mns[i] = mn;
        if scale <= 0.0 {
            continue; // constant row: codes 0, offset carries the value
        }
        sx[i] = scale;
        for (o, &v) in qx[i * k..(i + 1) * k].iter_mut().zip(row) {
            *o = ((v - mn) / scale).round().clamp(0.0, hi) as u8;
        }
    }
    let mut y = Mat::zeros(m, n);
    let mut wbuf = vec![0i8; k];
    for j in 0..n {
        q.codes_row_into(j, &mut wbuf);
        let colsum: i32 = wbuf.iter().map(|&c| c as i32).sum();
        let (sw, prot) = match &q.scheme {
            Scheme::PerRow { scales } => (scales[j], None),
            Scheme::Protected { scales, cols_idx, values, .. } => {
                let np = cols_idx.len();
                (scales[j], Some((cols_idx, &values[j * np..(j + 1) * np])))
            }
            Scheme::Grouped { .. } => unreachable!("grouped delegates to the deq path"),
        };
        for i in 0..m {
            let qrow = &qx[i * k..(i + 1) * k];
            let mut acc: i32 = 0;
            for (&a, &w) in qrow.iter().zip(wbuf.iter()) {
                acc += a as i32 * w as i32;
            }
            let mut v = sw * (sx[i] * acc as f32 + mns[i] * colsum as f32);
            if let Some((idx, vals)) = prot {
                let xrow = x.row(i);
                for (&c, &pv) in idx.iter().zip(vals) {
                    v += xrow[c as usize] * pv;
                }
            }
            *y.at_mut(i, j) = v;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_transb;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn i4_pack_unpack_roundtrips_all_code_values() {
        // Every i4 code value, at even and odd column positions, plus an
        // odd column count exercising the padded trailing nibble.
        let all: Vec<i8> = (-8..=7).collect();
        for cols in [16usize, 15, 1, 7] {
            let rows = 3;
            let flat: Vec<i8> =
                (0..rows * cols).map(|i| all[(i * 5 + i / cols) % all.len()]).collect();
            let codes = Codes::pack(flat.clone(), rows, cols, QuantSpec::new(4));
            assert!(matches!(codes, Codes::I4(_)));
            let mut out = vec![0i8; cols];
            for i in 0..rows {
                codes.row_into(i, cols, &mut out);
                assert_eq!(out, flat[i * cols..(i + 1) * cols], "row {i}, cols {cols}");
            }
        }
    }

    #[test]
    fn prop_i4_roundtrip_random_codes() {
        Runner::new().cases(32).run("i4 pack/unpack roundtrip", |rng| {
            let rows = gen::size(rng, 1, 6);
            let cols = gen::size(rng, 1, 40);
            let flat: Vec<i8> =
                (0..rows * cols).map(|_| (rng.below(16) as i8) - 8).collect();
            let codes = Codes::pack(flat.clone(), rows, cols, QuantSpec::new(3));
            let mut out = vec![0i8; cols];
            for i in 0..rows {
                codes.row_into(i, cols, &mut out);
                if out != flat[i * cols..(i + 1) * cols] {
                    return Err(format!("row {i} mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spec_grid_constants() {
        let s = QuantSpec::new(4);
        assert_eq!(s.qmax(), 7.0);
        assert_eq!(s.qmin(), -8.0);
        assert!(s.packs_nibbles());
        assert!(!QuantSpec::new(8).packs_nibbles());
        assert!(!QuantSpec::supports(16));
        assert!(!QuantSpec::supports(1));
        // encode saturates instead of wrapping
        assert_eq!(s.encode(1e30, 1e-10), 7);
        assert_eq!(s.encode(-1e30, 1e-10), -8);
    }

    #[test]
    fn nbytes_reports_true_packed_footprint() {
        let w = rand_mat(1, 16, 64);
        let q4 = QMat::quantize_rtn(&w, QuantSpec::new(4));
        let q8 = QMat::quantize_rtn(&w, QuantSpec::new(8));
        assert_eq!(q4.nbytes(), (16 * 32 + 16 * 4) as u64); // nibbles + scales
        assert_eq!(q8.nbytes(), (16 * 64 + 16 * 4) as u64);
        assert_eq!(q4.dense_nbytes(), 16 * 64 * 4);
        assert!(q4.dense_nbytes() / q4.nbytes() >= 6, "4-bit must be ≥ 6× smaller");
        assert_eq!(QMat::packed_estimate(16, 64, QuantSpec::new(4)), q4.nbytes());
        assert_eq!(QMat::packed_estimate(16, 64, QuantSpec::new(8)), q8.nbytes());
    }

    #[test]
    fn deq_matmul_is_bit_identical_to_dense_oracle() {
        let x = rand_mat(2, 9, 48);
        let w = rand_mat(3, 21, 48);
        for bits in [4u8, 8] {
            let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
            let oracle = matmul_transb(&x, &q.dequantize());
            let fast = matmul_transb_deq(&x, &q);
            assert_eq!(fast.data, oracle.data, "bits {bits}");
        }
    }

    #[test]
    fn integer_matmul_matches_oracle_closely() {
        let mut x = rand_mat(4, 7, 64);
        crate::model::fake_quant_rows(&mut x, 16.0); // the W4A4 grid
        let w = rand_mat(5, 19, 64);
        for bits in [4u8, 8] {
            let q = QMat::quantize_rtn(&w, QuantSpec::new(bits));
            let oracle = matmul_transb(&x, &q.dequantize());
            let fast = matmul_transb_q(&x, &q, 16.0);
            let d = fast.max_abs_diff(&oracle);
            let tol = 1e-4 * oracle.max_abs().max(1.0);
            assert!(d <= tol, "bits {bits}: diff {d} > {tol}");
        }
    }

    #[test]
    fn integer_matmul_handles_constant_rows_and_protected_cols() {
        let k = 32;
        let mut x = Mat::from_fn(3, k, |i, j| if i == 0 { 2.5 } else { (i * k + j) as f32 * 0.01 });
        crate::model::fake_quant_rows(&mut x, 16.0); // row 0 is constant → untouched
        let w = rand_mat(6, 11, k);
        let mut mask = vec![false; k];
        mask[3] = true;
        mask[17] = true;
        let q = QMat::quantize_protected(&w, QuantSpec::new(4), &mask);
        let oracle = matmul_transb(&x, &q.dequantize());
        let fast = matmul_transb_q(&x, &q, 16.0);
        let d = fast.max_abs_diff(&oracle);
        assert!(d <= 1e-4 * oracle.max_abs().max(1.0), "diff {d}");
    }

    #[test]
    fn fp_activations_and_grouped_weights_fall_back_to_deq() {
        let x = rand_mat(7, 5, 64);
        let w = rand_mat(8, 13, 64);
        let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
        // fp sentinel → deq path → bit-identical to the oracle
        assert_eq!(
            matmul_transb_q(&x, &q, 65536.0).data,
            matmul_transb(&x, &q.dequantize()).data
        );
        let order: Vec<usize> = (0..64).rev().collect();
        let g = QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 32);
        assert_eq!(
            matmul_transb_q(&x, &g, 16.0).data,
            matmul_transb(&x, &g.dequantize()).data
        );
    }

    #[test]
    fn explicit_thread_count_is_deterministic() {
        let x = rand_mat(9, 33, 48);
        let w = rand_mat(10, 29, 48);
        let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
        let serial = matmul_transb_deq_with(&x, &q, 1);
        let parallel = matmul_transb_deq_with(&x, &q, 4);
        assert_eq!(serial.data, parallel.data);
        let mut xq = x.clone();
        crate::model::fake_quant_rows(&mut xq, 16.0);
        assert_eq!(
            matmul_transb_q_with(&xq, &q, 16.0, 1).data,
            matmul_transb_q_with(&xq, &q, 16.0, 4).data
        );
    }

    #[test]
    fn blob_roundtrip_is_bit_identical_for_every_scheme() {
        let w = rand_mat(20, 12, 48);
        let mut mask = vec![false; 48];
        mask[5] = true;
        mask[40] = true;
        let order: Vec<usize> = (0..48).rev().collect();
        let mats = [
            QMat::quantize_rtn(&w, QuantSpec::new(4)),
            QMat::quantize_rtn(&w, QuantSpec::new(8)),
            QMat::quantize_with_scales(&w, QuantSpec::new(3), vec![0.01; 12]),
            QMat::quantize_protected(&w, QuantSpec::new(4), &mask),
            QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 16),
        ];
        for q in mats {
            let blob = q.to_bytes();
            let back = QMat::from_bytes(&blob).unwrap();
            assert_eq!(back, q, "{} roundtrip", q.scheme_label());
            assert_eq!(back.nbytes(), q.nbytes());
            assert_eq!(back.dequantize().data, q.dequantize().data);
        }
    }

    #[test]
    fn from_bytes_rejects_corrupt_blobs() {
        let q = QMat::quantize_rtn(&rand_mat(21, 6, 10), QuantSpec::new(4));
        let blob = q.to_bytes();
        // truncation
        assert!(QMat::from_bytes(&blob[..blob.len() - 3]).is_err());
        // trailing garbage
        let mut long = blob.clone();
        long.push(0);
        assert!(QMat::from_bytes(&long).is_err());
        // unsupported bit width
        let mut bad = blob.clone();
        bad[0] = 16;
        assert!(QMat::from_bytes(&bad).is_err());
        // wrong code-count geometry
        let mut short = blob;
        short[9] = 0xff; // code storage tag byte offset: 1 + 4 + 4 = 9
        assert!(QMat::from_bytes(&short).is_err());
    }

    #[test]
    fn scale_array_length_overflow_is_rejected_not_panicking() {
        // Regression: Cursor::f32s/u32s bound the element count against
        // buf.len()/4 *before* computing n * 4 — a corrupt length near
        // usize::MAX would wrap the byte count and bypass the bounds
        // check. Every hostile length must Err, never panic.
        let q = QMat::quantize_rtn(&rand_mat(22, 6, 10), QuantSpec::new(4));
        let blob = q.to_bytes();
        // The per-row blob ends [scales-len u64][6 × f32 scales].
        let len_at = blob.len() - 8 - 6 * 4;
        for bad_len in [u64::MAX, u64::MAX / 4, u64::MAX / 4 + 1, blob.len() as u64] {
            let mut b = blob.clone();
            b[len_at..len_at + 8].copy_from_slice(&bad_len.to_le_bytes());
            assert!(QMat::from_bytes(&b).is_err(), "length {bad_len:#x} must be rejected");
        }
    }

    #[test]
    fn tiled_gemm_is_bit_identical_to_scalar_reference() {
        // Ragged everything: m crosses MC and the MR register tile, n
        // leaves a partial NR panel, k crosses KC and is odd (the i4
        // panels exercise the trailing-nibble half step).
        let (m, k, n) = (70, 259, 19);
        let mut x = rand_mat(31, m, k);
        crate::model::fake_quant_rows(&mut x, 16.0);
        let w = rand_mat(32, n, k);
        let mut mask = vec![false; k];
        mask[0] = true;
        mask[258] = true;
        for q in [
            QMat::quantize_rtn(&w, QuantSpec::new(4)),
            QMat::quantize_rtn(&w, QuantSpec::new(8)),
            QMat::quantize_protected(&w, QuantSpec::new(4), &mask),
        ] {
            assert_eq!(
                matmul_transb_q(&x, &q, 16.0).data,
                matmul_transb_q_ref(&x, &q, 16.0).data,
                "{} {}b",
                q.scheme_label(),
                q.spec().bits()
            );
        }
    }

    #[test]
    fn panel_cache_is_derived_data_only() {
        let w = rand_mat(33, 9, 33);
        let q = QMat::quantize_rtn(&w, QuantSpec::new(4));
        let nbytes = q.nbytes();
        let blob = q.to_bytes();
        assert_eq!(q.panel_nbytes(), 0, "no cache before first use");
        q.prepack();
        assert!(q.panel_nbytes() > 0);
        assert_eq!(q.nbytes(), nbytes, "panels don't count in the stored footprint");
        assert_eq!(q.to_bytes(), blob, "panels are never serialized");
        let back = QMat::from_bytes(&blob).unwrap();
        assert_eq!(back, q, "equality ignores the cache");
        // Grouped scales never panel-pack (deq fallback path).
        let order: Vec<usize> = (0..33).collect();
        let g = QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 16);
        g.prepack();
        assert_eq!(g.panel_nbytes(), 0);
    }

    #[test]
    fn grouped_scheme_reports_metadata_bytes() {
        let w = rand_mat(11, 8, 64);
        let order: Vec<usize> = (0..64).collect();
        let g = QMat::quantize_grouped(&w, QuantSpec::new(4), &order, 32);
        assert_eq!(g.scheme_label(), "grouped");
        // codes (nibbles) + rank + scales + hi codes
        let expect = (8 * 32) + (64 * 4) + (8 * 2 * 4) + (8 * 32);
        assert_eq!(g.nbytes(), expect as u64);
        assert!(g.nbytes() < g.dense_nbytes());
    }
}
