//! Cache-blocked, register-tiled integer GEMM over packed weights and
//! quantized activations — the kernel that turns "8× smaller" into
//! "measurably faster".
//!
//! GotoBLAS-style structure, specialized to `y = x · Wᵀ` with u8
//! activation codes and i8/i4 weight codes:
//!
//! * **Panels** — weight rows are repacked once per [`super::QMat`]
//!   (cached on the matrix, see `QMat::prepack`) into `NR`-row panels
//!   laid out k-major (`panel[kk * NR + jr]`), so the micro-kernel
//!   streams one contiguous buffer with unit stride. ≤ 4-bit codes stay
//!   nibble-packed in the panel (two k positions per byte) and are
//!   sign-extended in registers, halving panel memory traffic. Per-row
//!   code sums (`Σ_k qw[j][k]`) are precomputed alongside — the
//!   asymmetric-activation offset term needs them on every call.
//! * **Blocking** — `MC`-row activation blocks × `KC`-deep k blocks are
//!   accumulated into an on-stack `MC×NR` i32 tile before the float
//!   epilogue runs, keeping the working set in L1/L2.
//! * **Register tiling** — the micro-kernel advances `MR = 4` activation
//!   rows at once against one `NR = 8`-wide panel row, reusing each
//!   loaded weight vector four times.
//! * **Parallelism** — panels (disjoint output column ranges) are
//!   distributed over [`crate::util::threadpool::par_ranges`], the same
//!   sanctioned parallel-for every other tensor kernel uses; thread
//!   count changes never change results (i32 accumulation is exact).
//!
//! **Equivalence contract**: i32 accumulation is associative, so any
//! blocking order produces bit-identical sums; the float epilogue is the
//! verbatim expression of the historical scalar kernel (retained as
//! `qmat::matmul_transb_q_ref`, the oracle of `rust/tests/gemm.rs`).
//! The dequantizing path `matmul_transb_deq` remains the bit-exact f32
//! oracle and the fallback for grouped scales / wide activation grids.

use super::matmul::{resolve_threads, SendPtr};
use super::qact::QAct;
use super::qmat::{sign_extend_nibble, QMat};
use super::Mat;
use crate::util::threadpool::par_ranges;

/// Weight rows per panel (output-column tile width).
pub(crate) const NR: usize = 8;
/// Activation rows per register tile.
pub(crate) const MR: usize = 4;
/// Activation rows per cache block.
pub(crate) const MC: usize = 64;
/// Inner-dimension depth per cache block (even, so nibble-packed panels
/// split on byte boundaries).
pub(crate) const KC: usize = 256;

/// Panel-packed weight codes cached on a [`QMat`] (derived data — never
/// serialized, excluded from `nbytes`/`PartialEq`). Rows are grouped in
/// `NR`-row panels stored k-major; the last panel zero-pads missing rows
/// so the micro-kernel never branches on ragged edges.
#[derive(Clone, Debug)]
pub(crate) struct Panels {
    k: usize,
    n: usize,
    data: PanelData,
    /// Per weight row: `Σ_k qw[j][k]` (the asymmetric-offset term).
    colsums: Vec<i32>,
}

#[derive(Clone, Debug)]
enum PanelData {
    /// Per panel: `k × NR` codes, `data[kk * NR + jr]`.
    I8(Vec<i8>),
    /// Per panel: `ceil(k/2) × NR` bytes; byte `g` holds k = 2g in the
    /// low nibble and k = 2g+1 in the high nibble (zero-padded at odd k).
    I4(Vec<u8>),
}

impl Panels {
    /// Repack `q`'s codes into the panel layout (one pass over the
    /// stored rows; zero cost thereafter — `QMat` caches the result).
    pub(crate) fn build(q: &QMat) -> Panels {
        let (n, k) = q.shape();
        let n_panels = n.div_ceil(NR);
        let mut row = vec![0i8; k];
        let mut colsums = vec![0i32; n];
        let data = if q.spec().packs_nibbles() {
            let kg = k.div_ceil(2);
            let mut d = vec![0u8; n_panels * kg * NR];
            for (j, sum) in colsums.iter_mut().enumerate() {
                q.codes_row_into(j, &mut row);
                *sum = row.iter().map(|&c| c as i32).sum();
                let base = (j / NR) * kg * NR + (j % NR);
                for (g, pair) in row.chunks(2).enumerate() {
                    let lo = pair[0] as u8 & 0x0F;
                    let hi = if pair.len() == 2 { (pair[1] as u8 & 0x0F) << 4 } else { 0 };
                    d[base + g * NR] = lo | hi;
                }
            }
            PanelData::I4(d)
        } else {
            let mut d = vec![0i8; n_panels * k * NR];
            for (j, sum) in colsums.iter_mut().enumerate() {
                q.codes_row_into(j, &mut row);
                *sum = row.iter().map(|&c| c as i32).sum();
                let base = (j / NR) * k * NR + (j % NR);
                for (kk, &c) in row.iter().enumerate() {
                    d[base + kk * NR] = c;
                }
            }
            PanelData::I8(d)
        };
        Panels { k, n, data, colsums }
    }

    /// Cache footprint in bytes (reported via `QMat::panel_nbytes`).
    pub(crate) fn nbytes(&self) -> u64 {
        let d = match &self.data {
            PanelData::I8(v) => v.len(),
            PanelData::I4(v) => v.len(),
        };
        (d + 4 * self.colsums.len()) as u64
    }
}

/// `y = x · dequantize(Q)ᵀ` through the tiled integer GEMM, with the
/// activation codes supplied by the caller (computed **once** per layer
/// boundary by [`super::quantize_act`], not once per linear). `x` must
/// be the fake-quantized f32 matrix `qa` was derived from — the epilogue
/// reads it for QUIK protected columns. Grouped-scale weights take the
/// bit-exact dequantizing fallback.
pub fn matmul_transb_qact(x: &Mat, qa: &QAct, q: &QMat) -> Mat {
    matmul_transb_qact_with(x, qa, q, 0)
}

/// [`matmul_transb_qact`] with an explicit thread count (0 = the same
/// flops-based default the f32 kernels use; benches pass `DQ_WORKERS`).
pub fn matmul_transb_qact_with(x: &Mat, qa: &QAct, q: &QMat, threads: usize) -> Mat {
    assert_eq!(x.cols, q.cols(), "matmul_transb_qact inner-dim mismatch");
    assert_eq!((qa.rows(), qa.cols()), x.shape(), "QAct/x shape mismatch");
    if q.is_grouped() {
        return super::qmat::matmul_transb_deq_with(x, q, threads);
    }
    gemm_qact(x, qa, q, threads)
}

/// The blocked kernel proper (callers have already routed grouped scales
/// to the deq path).
pub(crate) fn gemm_qact(x: &Mat, qa: &QAct, q: &QMat, threads: usize) -> Mat {
    let (m, k, n) = (x.rows, x.cols, q.rows());
    let mut y = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return y;
    }
    let panels = q.panels().expect("panel GEMM requires per-row scales");
    let n_panels = n.div_ceil(NR);
    let threads = resolve_threads(threads, 2 * m * k * n);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    par_ranges(n_panels, threads, |plo, phi| {
        let y_ptr = &y_ptr;
        for p in plo..phi {
            panel_block(x, qa, q, panels, p, y_ptr);
        }
    });
    y
}

/// One panel (`NR` output columns) against all activation rows: MC×KC
/// cache blocks accumulate into an on-stack i32 tile, then the float
/// epilogue applies scales, the asymmetric offset and any protected
/// columns — the exact per-output expression of the scalar kernel.
/// `pub(crate)` so the column-parallel shard kernel (`super::shard`) can
/// distribute the same panels over explicit shard ranges.
pub(crate) fn panel_block(
    x: &Mat,
    qa: &QAct,
    q: &QMat,
    panels: &Panels,
    p: usize,
    y_ptr: &SendPtr,
) {
    let (m, k, n) = (x.rows, panels.k, panels.n);
    let j0 = p * NR;
    let jn = NR.min(n - j0);
    let kg = k.div_ceil(2);
    // Per-panel scale/protection metadata, hoisted out of the row loops.
    let sws: [f32; NR] = std::array::from_fn(|jr| if jr < jn { q.row_scale(j0 + jr) } else { 0.0 });
    let prots: [Option<(&[u32], &[f32])>; NR] =
        std::array::from_fn(|jr| if jr < jn { q.protected_row(j0 + jr) } else { None });
    for i0 in (0..m).step_by(MC) {
        let mb = MC.min(m - i0);
        let mut acc = [[0i32; NR]; MC];
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            match &panels.data {
                PanelData::I8(d) => {
                    let base = p * k * NR;
                    let pb = &d[base + k0 * NR..base + (k0 + kc) * NR];
                    accumulate_i8(qa, i0, mb, k0, kc, pb, &mut acc);
                }
                PanelData::I4(d) => {
                    // KC is even, so k blocks split on nibble-pair bytes.
                    let base = p * kg * NR;
                    let g0 = k0 / 2;
                    let gc = (k0 + kc).div_ceil(2) - g0;
                    let pb = &d[base + g0 * NR..base + (g0 + gc) * NR];
                    accumulate_i4(qa, i0, mb, k0, kc, pb, &mut acc);
                }
            }
        }
        for (ii, accr) in acc.iter().enumerate().take(mb) {
            let i = i0 + ii;
            let (mn, sx) = qa.grid(i);
            let xrow = x.row(i);
            let colsums = &panels.colsums[j0..j0 + jn];
            for (jr, &colsum) in colsums.iter().enumerate() {
                let mut v = sws[jr] * (sx * accr[jr] as f32 + mn * colsum as f32);
                if let Some((idx, vals)) = prots[jr] {
                    for (&c, &pv) in idx.iter().zip(vals) {
                        v += xrow[c as usize] * pv;
                    }
                }
                // SAFETY: this thread owns panels [plo, phi) from
                // par_ranges, i.e. the disjoint output columns
                // [plo*NR, phi*NR) — no two threads write the same element.
                unsafe { *y_ptr.0.add(i * n + j0 + jr) = v };
            }
        }
    }
}

/// i8 micro-kernel: advance `MR` activation rows at once down a `KC`
/// slab of one panel, accumulating into the i32 tile.
fn accumulate_i8(
    qa: &QAct,
    i0: usize,
    mb: usize,
    k0: usize,
    kc: usize,
    pb: &[i8],
    acc: &mut [[i32; NR]; MC],
) {
    let mut ii = 0;
    while ii < mb {
        let mrb = MR.min(mb - ii);
        // The slice pattern selects the full MR-row register tile; ragged
        // tails (mrb < MR) fall through to the one-row loop.
        if let [t0, t1, t2, t3] = &mut acc[ii..ii + mrb] {
            let a0 = &qa.code_row(i0 + ii)[k0..k0 + kc];
            let a1 = &qa.code_row(i0 + ii + 1)[k0..k0 + kc];
            let a2 = &qa.code_row(i0 + ii + 2)[k0..k0 + kc];
            let a3 = &qa.code_row(i0 + ii + 3)[k0..k0 + kc];
            for (kk, b) in pb.chunks_exact(NR).enumerate() {
                let (v0, v1, v2, v3) =
                    (a0[kk] as i32, a1[kk] as i32, a2[kk] as i32, a3[kk] as i32);
                for (jr, &w) in b.iter().enumerate() {
                    let w = w as i32;
                    t0[jr] += v0 * w;
                    t1[jr] += v1 * w;
                    t2[jr] += v2 * w;
                    t3[jr] += v3 * w;
                }
            }
        } else {
            for (t, ir) in acc[ii..ii + mrb].iter_mut().zip(0..) {
                let a = &qa.code_row(i0 + ii + ir)[k0..k0 + kc];
                for (b, &av) in pb.chunks_exact(NR).zip(a) {
                    let v = av as i32;
                    for (t_el, &w) in t.iter_mut().zip(b) {
                        *t_el += v * w as i32;
                    }
                }
            }
        }
        ii += mrb;
    }
}

/// i4 micro-kernel: weights stay nibble-packed in the panel; each byte
/// supplies two k positions, sign-extended in registers. An odd `kc`
/// tail (only possible at odd `k`) consumes the low nibble alone — the
/// padded high nibble is zero and its activation index doesn't exist.
fn accumulate_i4(
    qa: &QAct,
    i0: usize,
    mb: usize,
    k0: usize,
    kc: usize,
    pb: &[u8],
    acc: &mut [[i32; NR]; MC],
) {
    let pairs = kc / 2;
    let mut ii = 0;
    while ii < mb {
        let mrb = MR.min(mb - ii);
        if let [t0, t1, t2, t3] = &mut acc[ii..ii + mrb] {
            let a0 = &qa.code_row(i0 + ii)[k0..k0 + kc];
            let a1 = &qa.code_row(i0 + ii + 1)[k0..k0 + kc];
            let a2 = &qa.code_row(i0 + ii + 2)[k0..k0 + kc];
            let a3 = &qa.code_row(i0 + ii + 3)[k0..k0 + kc];
            for (g, b) in pb.chunks_exact(NR).enumerate().take(pairs) {
                let (l0, l1, l2, l3) = (
                    a0[2 * g] as i32,
                    a1[2 * g] as i32,
                    a2[2 * g] as i32,
                    a3[2 * g] as i32,
                );
                let (h0, h1, h2, h3) = (
                    a0[2 * g + 1] as i32,
                    a1[2 * g + 1] as i32,
                    a2[2 * g + 1] as i32,
                    a3[2 * g + 1] as i32,
                );
                for (jr, &byte) in b.iter().enumerate() {
                    let wlo = sign_extend_nibble(byte) as i32;
                    let whi = sign_extend_nibble(byte >> 4) as i32;
                    t0[jr] += l0 * wlo + h0 * whi;
                    t1[jr] += l1 * wlo + h1 * whi;
                    t2[jr] += l2 * wlo + h2 * whi;
                    t3[jr] += l3 * wlo + h3 * whi;
                }
            }
            if kc % 2 == 1 {
                let b = &pb[pairs * NR..(pairs + 1) * NR];
                let (l0, l1, l2, l3) = (
                    a0[kc - 1] as i32,
                    a1[kc - 1] as i32,
                    a2[kc - 1] as i32,
                    a3[kc - 1] as i32,
                );
                for (jr, &byte) in b.iter().enumerate() {
                    let wlo = sign_extend_nibble(byte) as i32;
                    t0[jr] += l0 * wlo;
                    t1[jr] += l1 * wlo;
                    t2[jr] += l2 * wlo;
                    t3[jr] += l3 * wlo;
                }
            }
        } else {
            for (t, ir) in acc[ii..ii + mrb].iter_mut().zip(0..) {
                let a = &qa.code_row(i0 + ii + ir)[k0..k0 + kc];
                for (g, b) in pb.chunks_exact(NR).enumerate().take(pairs) {
                    let (lo, hi) = (a[2 * g] as i32, a[2 * g + 1] as i32);
                    for (t_el, &byte) in t.iter_mut().zip(b) {
                        *t_el += lo * sign_extend_nibble(byte) as i32
                            + hi * sign_extend_nibble(byte >> 4) as i32;
                    }
                }
                if kc % 2 == 1 {
                    let b = &pb[pairs * NR..(pairs + 1) * NR];
                    let lo = a[kc - 1] as i32;
                    for (t_el, &byte) in t.iter_mut().zip(b) {
                        *t_el += lo * sign_extend_nibble(byte) as i32;
                    }
                }
            }
        }
        ii += mrb;
    }
}
