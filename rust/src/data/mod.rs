//! Synthetic corpus substrate — stand-ins for WikiText2 / PTB / C4.
//!
//! The paper's dataset experiments (Tables 1, 5, 16) only require that the
//! three calibration corpora have *distinct token distributions* with a
//! held-out split each. Each [`Dialect`] is a seeded stochastic process
//! over the model vocabulary combining:
//!
//! * a Zipf marginal (dialect-specific exponent α),
//! * first-order Markov structure (a deterministic successor table, taken
//!   with dialect-specific probability — the "temperature"),
//! * dialect-specific topic blocks (contiguous vocab bands the walk
//!   prefers), so cross-dialect perplexity transfers imperfectly, giving
//!   the distribution shift Table 1's overfitting experiment needs.

use crate::util::prng::{Pcg64, Zipf};

/// The three corpus dialects, named after the paper's datasets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dialect {
    /// WikiText2-like: moderate Zipf, strong bigram structure.
    Wiki,
    /// PTB-like: steep Zipf (small effective vocab), rigid structure.
    Ptb,
    /// C4-like: flat Zipf (broad vocab), noisy structure.
    C4,
}

impl Dialect {
    pub const ALL: [Dialect; 3] = [Dialect::Wiki, Dialect::Ptb, Dialect::C4];

    /// Parse a dialect name — the single parser shared by the CLI, the
    /// benches and the pipeline registry/report. Accepts the short CLI
    /// names and the paper labels, case-insensitively.
    pub fn parse(s: &str) -> anyhow::Result<Dialect> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "wiki" | "wikitext2" => Dialect::Wiki,
            "ptb" => Dialect::Ptb,
            "c4" => Dialect::C4,
            other => anyhow::bail!("unknown dialect {other:?} (wiki|ptb|c4)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Dialect::Wiki => "WikiText2",
            Dialect::Ptb => "PTB",
            Dialect::C4 => "C4",
        }
    }

    fn params(&self) -> (f64, f64, u64) {
        // (zipf_alpha, markov_follow_prob, seed_salt)
        match self {
            Dialect::Wiki => (1.05, 0.55, 0x11),
            Dialect::Ptb => (1.35, 0.70, 0x22),
            Dialect::C4 => (0.85, 0.35, 0x33),
        }
    }
}

/// A seeded corpus over vocab [0, V).
pub struct Corpus {
    pub dialect: Dialect,
    pub vocab: usize,
    zipf: Zipf,
    successor: Vec<usize>,
    follow_p: f64,
    seed: u64,
}

impl Corpus {
    pub fn new(dialect: Dialect, vocab: usize, seed: u64) -> Corpus {
        let (alpha, follow_p, salt) = dialect.params();
        let mut rng = Pcg64::new(seed ^ salt.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        // Deterministic successor table = the corpus's "grammar". Targets
        // are drawn from the dialect's own Zipf so the Markov walk keeps
        // the dialect's marginal skew instead of flattening it.
        let zipf = Zipf::new(vocab, alpha);
        let successor: Vec<usize> = (0..vocab).map(|_| zipf.sample(&mut rng)).collect();
        Corpus { dialect, vocab, zipf, successor, follow_p, seed }
    }

    /// Sample one sequence of `len` tokens. `stream` selects train/valid
    /// material deterministically (same corpus, disjoint randomness).
    pub fn sequence(&self, len: usize, stream: u64, index: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(
            self.seed ^ stream.wrapping_mul(0xd134_2543_de82_ef95) ^ index.wrapping_mul(0x2545_f491_4f6c_dd1d),
        );
        let mut out = Vec::with_capacity(len);
        let mut prev = self.zipf.sample(&mut rng);
        out.push(prev as i32);
        for _ in 1..len {
            let next = if rng.uniform() < self.follow_p {
                self.successor[prev]
            } else {
                self.zipf.sample(&mut rng)
            };
            out.push(next as i32);
            prev = next;
        }
        out
    }

    /// A batch of sequences from the train stream.
    pub fn train_batch(&self, batch: usize, seq: usize, step: u64) -> Vec<Vec<i32>> {
        (0..batch as u64)
            .map(|b| self.sequence(seq, 0, step * batch as u64 + b))
            .collect()
    }

    /// A batch from the held-out (validation) stream.
    pub fn valid_batch(&self, batch: usize, seq: usize, index: u64) -> Vec<Vec<i32>> {
        (0..batch as u64)
            .map(|b| self.sequence(seq, 1, index * batch as u64 + b))
            .collect()
    }

    /// Calibration sequences (the paper uses 128 × 2048-token samples;
    /// our artifacts use `configs.SEQ`-token sequences).
    pub fn calib_sequences(&self, count: usize, seq: usize) -> Vec<Vec<i32>> {
        (0..count as u64).map(|i| self.sequence(seq, 2, i)).collect()
    }

    /// Calibration sequences at a step offset (distinct batches for the
    /// end-to-end fine-tuning baseline's epochs).
    pub fn calib_sequences_at(&self, count: usize, seq: usize, step: u64) -> Vec<Vec<i32>> {
        (0..count as u64)
            .map(|i| self.sequence(seq, 2, step * count as u64 + i))
            .collect()
    }

    /// The deterministic successor table (the corpus "grammar") — used by
    /// `Weights::init_grammar` to plant predictive structure in a model
    /// without training (DESIGN.md §3).
    pub fn successor(&self) -> &[usize] {
        &self.successor
    }

    /// Probability that a token is followed by its successor-table entry.
    pub fn follow_prob(&self) -> f64 {
        self.follow_p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dialect_parse_accepts_cli_names_and_labels() {
        for d in Dialect::ALL {
            assert_eq!(Dialect::parse(d.label()).unwrap(), d, "{}", d.label());
        }
        assert_eq!(Dialect::parse("wiki").unwrap(), Dialect::Wiki);
        assert_eq!(Dialect::parse("PTB").unwrap(), Dialect::Ptb);
        assert!(Dialect::parse("owt").is_err());
    }

    #[test]
    fn deterministic_and_stream_disjoint() {
        let c = Corpus::new(Dialect::Wiki, 512, 42);
        assert_eq!(c.sequence(64, 0, 0), c.sequence(64, 0, 0));
        assert_ne!(c.sequence(64, 0, 0), c.sequence(64, 1, 0));
        assert_ne!(c.sequence(64, 0, 0), c.sequence(64, 0, 1));
    }

    #[test]
    fn tokens_in_vocab() {
        for d in Dialect::ALL {
            let c = Corpus::new(d, 512, 7);
            for t in c.sequence(1000, 0, 0) {
                assert!((0..512).contains(&t));
            }
        }
    }

    #[test]
    fn dialects_have_distinct_marginals() {
        // PTB (steep zipf) concentrates more mass on the top token than C4.
        let count_top = |d: Dialect| {
            let c = Corpus::new(d, 512, 1);
            let seq = c.sequence(20_000, 0, 0);
            let mut counts = vec![0usize; 512];
            for &t in &seq {
                counts[t as usize] += 1;
            }
            *counts.iter().max().unwrap()
        };
        let ptb = count_top(Dialect::Ptb);
        let c4 = count_top(Dialect::C4);
        assert!(ptb > c4 * 2, "ptb top {ptb} vs c4 top {c4}");
    }

    #[test]
    fn markov_structure_is_learnable() {
        // Following the successor table must beat chance by a wide margin.
        let c = Corpus::new(Dialect::Wiki, 512, 3);
        let seq = c.sequence(10_000, 0, 0);
        let follows = seq
            .windows(2)
            .filter(|w| c.successor[w[0] as usize] == w[1] as usize)
            .count();
        let rate = follows as f64 / (seq.len() - 1) as f64;
        assert!(rate > 0.4, "follow rate {rate}");
    }

    #[test]
    fn batches_have_geometry() {
        let c = Corpus::new(Dialect::C4, 1024, 9);
        let b = c.train_batch(4, 32, 5);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|s| s.len() == 32));
        assert_ne!(b[0], b[1]);
        // different steps differ
        assert_ne!(c.train_batch(4, 32, 5)[0], c.train_batch(4, 32, 6)[0]);
    }
}
