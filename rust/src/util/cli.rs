//! Declarative CLI flag parsing (clap is not in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments and subcommands; generates usage text from declarations.

use std::collections::BTreeMap;

/// One declared flag.
#[derive(Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {s:?}")),
        }
    }
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got {s:?}")),
        }
    }
    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }
}

/// A command with declared flags.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, takes_value: true, default: None });
        self
    }
    pub fn flag_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.flags.push(Flag { name, help, takes_value: true, default: Some(default) });
        self
    }
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let v = if f.takes_value { " <value>" } else { "" };
            let d = f.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", f.name, f.help));
        }
        s
    }

    /// Parse a raw argv slice (without the command name itself).
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (raw, None),
                };
                let decl = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n\n{}", self.usage()))?;
                if decl.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} expects a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("--{name} does not take a value");
                    }
                    args.bools.insert(name.to_string(), true);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("calibrate", "run rotation calibration")
            .flag_default("model", "llama2-tiny", "model config name")
            .flag("steps", "iterations")
            .switch("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("model"), Some("llama2-tiny"));
        let a = cmd().parse(&sv(&["--model", "llama2-large"])).unwrap();
        assert_eq!(a.get("model"), Some("llama2-large"));
    }

    #[test]
    fn equals_form_and_switch() {
        let a = cmd().parse(&sv(&["--steps=100", "--verbose"])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.get_bool("verbose"));
        assert!(!cmd().parse(&sv(&[])).unwrap().get_bool("verbose"));
    }

    #[test]
    fn positional_and_errors() {
        let a = cmd().parse(&sv(&["out.json"])).unwrap();
        assert_eq!(a.positional, vec!["out.json"]);
        assert!(cmd().parse(&sv(&["--bogus"])).is_err());
        assert!(cmd().parse(&sv(&["--steps"])).is_err());
        assert!(cmd().parse(&sv(&["--steps", "abc"])).unwrap().get_usize("steps", 0).is_err());
        assert!(cmd().parse(&sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cmd().usage();
        assert!(u.contains("--model") && u.contains("default: llama2-tiny"));
    }
}
