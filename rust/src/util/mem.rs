//! Process memory accounting for the cost tables (Table 3 / Fig 1).
//!
//! Two complementary views:
//! * [`rss_bytes`] — actual process resident set (Linux `/proc/self/status`),
//!   used when measuring our own calibration runs.
//! * [`PeakTracker`] — a logical-bytes accountant the coordinator charges
//!   allocations against; this is what lets us *model* the paper's GPU-memory
//!   comparison (SpinQuant holds the whole model + optimizer state; DartQuant
//!   holds one layer's activations + one latent matrix) on a substrate where
//!   everything shares host RAM.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Current resident set size in bytes (Linux). Returns 0 if unreadable.
pub fn rss_bytes() -> u64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Peak RSS in bytes since process start (VmHWM). Some container kernels
/// omit VmHWM from /proc/self/status; fall back to the current RSS so
/// callers always get a usable lower bound.
pub fn peak_rss_bytes() -> u64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    rss_bytes()
}

/// Thread-safe logical memory accountant with high-water-mark tracking.
#[derive(Clone, Default)]
pub struct PeakTracker {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    current: AtomicI64,
    peak: AtomicI64,
}

impl PeakTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `bytes`; returns a guard that releases on drop.
    pub fn charge(&self, bytes: u64) -> ChargeGuard {
        let cur = self.inner.current.fetch_add(bytes as i64, Ordering::SeqCst) + bytes as i64;
        self.inner.peak.fetch_max(cur, Ordering::SeqCst);
        ChargeGuard { tracker: self.clone(), bytes }
    }

    pub fn current_bytes(&self) -> u64 {
        self.inner.current.load(Ordering::SeqCst).max(0) as u64
    }

    pub fn peak_bytes(&self) -> u64 {
        self.inner.peak.load(Ordering::SeqCst).max(0) as u64
    }

    pub fn reset_peak(&self) {
        self.inner
            .peak
            .store(self.inner.current.load(Ordering::SeqCst), Ordering::SeqCst);
    }
}

/// RAII release of a logical charge.
pub struct ChargeGuard {
    tracker: PeakTracker,
    bytes: u64,
}

impl Drop for ChargeGuard {
    fn drop(&mut self) {
        self.tracker.inner.current.fetch_sub(self.bytes as i64, Ordering::SeqCst);
    }
}

/// GiB formatting used by the cost tables.
pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_nonzero_on_linux() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn tracker_peak_semantics() {
        let t = PeakTracker::new();
        {
            let _a = t.charge(100);
            assert_eq!(t.current_bytes(), 100);
            {
                let _b = t.charge(50);
                assert_eq!(t.peak_bytes(), 150);
            }
            assert_eq!(t.current_bytes(), 100);
            assert_eq!(t.peak_bytes(), 150, "peak survives release");
        }
        assert_eq!(t.current_bytes(), 0);
        t.reset_peak();
        assert_eq!(t.peak_bytes(), 0);
    }

    #[test]
    fn tracker_is_thread_safe() {
        let t = PeakTracker::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let t = t.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        let _g = t.charge(10);
                    }
                });
            }
        });
        assert_eq!(t.current_bytes(), 0);
        assert!(t.peak_bytes() >= 10);
    }

    #[test]
    fn gib_conversion() {
        assert!((gib(1 << 30) - 1.0).abs() < 1e-12);
    }
}
