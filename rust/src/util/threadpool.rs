//! Worker-thread primitives for the coordinator's fan-out/join needs
//! (tokio is not in the offline vendor set; independent per-layer
//! calibration jobs map cleanly onto OS threads).
//!
//! Two flavors:
//!
//! * [`ThreadPool`] — a persistent, shared-queue pool for `'static` jobs
//!   (fire-and-forget [`ThreadPool::execute`], ordered
//!   [`ThreadPool::map`] / [`ThreadPool::try_map`]).
//! * [`scoped_try_map`] — a scoped fan-out/join that borrows from the
//!   caller's stack, used by the calibration scheduler
//!   (`coordinator::scheduler`) so activation pools never need cloning
//!   into `'static` closures.
//!
//! Both surfaces convert job panics into [`JobPanic`] errors instead of
//! killing workers: a dead worker would strand queued jobs and deadlock
//! the join, and `resume_unwind` across the pool boundary loses which job
//! failed.

use crate::util::sync::lock_or_poisoned;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error describing a job that panicked on a worker thread. `index` is
/// the job's position in the submitted item list (the first panicking
/// index when several jobs panic).
#[derive(Debug, thiserror::Error)]
#[error("job {index} panicked: {message}")]
pub struct JobPanic {
    /// Item index (submission order) of the panicking job.
    pub index: usize,
    /// Rendered panic payload (`&str`/`String` payloads; a placeholder
    /// otherwise).
    pub message: String,
}

/// Render a `catch_unwind` payload to a human-readable string.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// A work-stealing-free, shared-queue thread pool for `'static` jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<Mutex<Vec<String>>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1). Spawn failures degrade the pool
    /// instead of panicking: whatever workers did spawn carry the load,
    /// and if none did, jobs run inline on the submitting thread.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(Mutex::new(Vec::new()));
        let workers = (0..n)
            .filter_map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("dartquant-worker-{i}"))
                    .spawn(move || loop {
                        let job = { lock_or_poisoned(&rx).recv() };
                        match job {
                            // A panicking job must not kill the worker:
                            // queued jobs would strand and `map`'s join
                            // would deadlock waiting for their results.
                            Ok(job) => {
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                if let Err(p) = r {
                                    lock_or_poisoned(&panics).push(panic_message(p.as_ref()));
                                }
                            }
                            Err(_) => break, // sender dropped => shutdown
                        }
                    })
                    .ok()
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, panics }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Submit a fire-and-forget job. Panics inside the job are recorded
    /// (see [`ThreadPool::drain_panics`]) rather than killing a worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        if self.workers.is_empty() {
            // Every spawn failed (thread exhaustion): run inline so jobs
            // are never silently dropped.
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            if let Err(p) = r {
                lock_or_poisoned(&self.panics).push(panic_message(p.as_ref()));
            }
            return;
        }
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("worker alive");
    }

    /// Panic messages from `execute` jobs recorded since the last drain.
    /// (`map`/`try_map` report their jobs' panics through their return
    /// value instead.)
    pub fn drain_panics(&self) -> Vec<String> {
        std::mem::take(&mut *lock_or_poisoned(&self.panics))
    }

    /// Map `f` over `items` on the pool, preserving item order, joining
    /// all results. A panicking job surfaces as `Err(JobPanic)` for the
    /// lowest panicking item index; the remaining jobs still run to
    /// completion (their results are discarded on error).
    pub fn try_map<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>, JobPanic>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<JobPanic> = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("all jobs report");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => {
                    if first_panic.as_ref().map(|fp| i < fp.index).unwrap_or(true) {
                        first_panic =
                            Some(JobPanic { index: i, message: panic_message(p.as_ref()) });
                    }
                }
            }
        }
        match first_panic {
            Some(p) => Err(p),
            None => Ok(out.into_iter().map(|o| o.expect("filled")).collect()),
        }
    }

    /// [`ThreadPool::try_map`] with the historical panicking surface:
    /// a job panic re-panics on the caller with the job index attached.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        match self.try_map(items, f) {
            Ok(v) => v,
            Err(p) => panic!("{p}"),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped fan-out/join: run `f(i, &items[i])` for every item on up to
/// `threads` scoped worker threads, borrowing `items` from the caller's
/// stack (no `'static` bound, no cloning), and join all results in item
/// order.
///
/// Workers pull items from a shared queue, so long jobs don't starve a
/// fixed partition. The calling thread works too: even if every worker
/// spawn fails, all items still run. Panics are caught per item — every
/// remaining item still runs — and the lowest panicking index is
/// surfaced as `Err(JobPanic)`, independent of completion order.
pub fn scoped_try_map<T, R, F>(threads: usize, items: &[T], f: F) -> Result<Vec<R>, JobPanic>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<std::thread::Result<R>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        let work = || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &items[i])));
            *lock_or_poisoned(&results[i]) = Some(r);
        };
        for t in 1..threads {
            let _ = std::thread::Builder::new()
                .name(format!("dartquant-scoped-{t}"))
                .spawn_scoped(s, work);
        }
        work();
    });
    let mut out = Vec::with_capacity(n);
    let mut first_panic: Option<JobPanic> = None;
    for (i, cell) in results.into_iter().enumerate() {
        // Each cell's mutex is held only for the `Some(r)` store, so a
        // poisoned cell still holds a valid slot — recover it.
        let slot = cell.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        match slot.expect("every item ran") {
            Ok(v) => out.push(v),
            Err(p) => {
                if first_panic.is_none() {
                    first_panic = Some(JobPanic { index: i, message: panic_message(p.as_ref()) });
                }
            }
        }
    }
    match first_panic {
        Some(p) => Err(p),
        None => Ok(out),
    }
}

/// Scoped parallel-for over index ranges without a persistent pool — used by
/// the tensor matmul. Splits [0, n) into `chunks` contiguous ranges and runs
/// `f(start, end)` on scoped threads.
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Send + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // drop joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn par_ranges_covers_exactly_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_ranges(n, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_ranges_degenerate_inputs_cover_exactly_once() {
        // (n, threads) corners: empty range, zero threads, more threads
        // than items. Every index must still be visited exactly once.
        for (n, threads) in [(0usize, 0usize), (0, 4), (1, 0), (1, 8), (5, 9), (7, 7)] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let calls = AtomicUsize::new(0);
            par_ranges(n, threads, |lo, hi| {
                calls.fetch_add(1, Ordering::SeqCst);
                assert!(lo <= hi && hi <= n, "range ({lo}, {hi}) out of [0, {n})");
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "(n={n}, threads={threads}) missed or repeated an index"
            );
            if n == 0 {
                // Degenerate n still invokes f once with the empty range.
                assert_eq!(calls.load(Ordering::SeqCst), 1);
            }
        }
    }

    #[test]
    #[should_panic]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn try_map_surfaces_panic_as_error_with_index() {
        let pool = ThreadPool::new(4);
        let err = pool
            .try_map((0..16).collect::<Vec<_>>(), |x| {
                if x == 5 || x == 11 {
                    panic!("job {x} exploded");
                }
                x * 2
            })
            .unwrap_err();
        // Lowest panicking index wins, independent of completion order.
        assert_eq!(err.index, 5);
        assert!(err.message.contains("exploded"), "got: {}", err.message);
        // The pool is still fully usable afterwards.
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn workers_survive_execute_panics() {
        let pool = ThreadPool::new(1); // single worker: a dead worker would deadlock
        pool.execute(|| panic!("fire-and-forget boom"));
        // The same (sole) worker must still process subsequent jobs.
        let out = pool.map((0..8).collect::<Vec<_>>(), |x| x + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
        let panics = pool.drain_panics();
        assert_eq!(panics.len(), 1);
        assert!(panics[0].contains("fire-and-forget"));
        assert!(pool.drain_panics().is_empty());
    }

    #[test]
    fn scoped_try_map_borrows_and_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = scoped_try_map(5, &items, |i, &x| {
            assert_eq!(i, x);
            x * 3
        })
        .unwrap();
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        assert!(scoped_try_map(3, &[] as &[usize], |_, &x| x).unwrap().is_empty());
    }

    #[test]
    fn scoped_try_map_reports_lowest_panicking_index() {
        let items: Vec<usize> = (0..32).collect();
        let ran = AtomicUsize::new(0);
        let err = scoped_try_map(4, &items, |_, &x| {
            ran.fetch_add(1, Ordering::SeqCst);
            if x % 10 == 7 {
                panic!("bad item {x}");
            }
            x
        })
        .unwrap_err();
        assert_eq!(err.index, 7);
        assert!(err.message.contains("bad item 7"), "got: {}", err.message);
        // Every item still ran — no early abort, no stranded work.
        assert_eq!(ran.load(Ordering::SeqCst), 32);
    }
}
