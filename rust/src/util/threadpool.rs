//! Fixed-size thread pool (tokio is not in the offline vendor set; the
//! coordinator's concurrency needs — fan out independent per-layer
//! calibration jobs, join results — map cleanly onto OS threads).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A work-stealing-free, shared-queue thread pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("dartquant-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped => shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("worker alive");
    }

    /// Map `f` over `items` on the pool, preserving order. Blocks until all
    /// results are in. Panics in jobs are converted into a panic here.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("all jobs report");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        out.into_iter().map(|o| o.expect("filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel-for over index ranges without a persistent pool — used by
/// the tensor matmul. Splits [0, n) into `chunks` contiguous ranges and runs
/// `f(start, end)` on scoped threads.
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Send + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // drop joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn par_ranges_covers_exactly_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_ranges(n, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    #[should_panic]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }
}
