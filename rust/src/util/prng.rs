//! Deterministic PRNG substrate (the offline vendor set has no `rand`).
//!
//! `Pcg64` implements PCG-XSL-RR 128/64 — a small, fast, statistically solid
//! generator — plus the samplers the repo needs: uniforms, normals
//! (Box–Muller), Laplace, Zipf (for the synthetic corpora), permutations and
//! subsampling (for the paper's 10% token sampling).

/// PCG-XSL-RR 128/64. Deterministic, seedable, portable.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(0xcafe_f00d_d15e_a5e5u128 ^ (seed as u128));
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (used to give each calibration
    /// worker its own stream).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Rejection-free (modulo bias negligible for
    /// our n ≪ 2^64, but we use Lemire's method for cleanliness).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let hi = ((self.next_u64() as u128 * n as u128) >> 64) as u64;
        hi as usize
    }

    /// Standard normal via Box–Muller (one value per call; cached pair
    /// intentionally omitted to keep the generator state a pure function of
    /// call count).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Zero-mean Laplace with scale `b` — the paper's activation model
    /// (Eq. 2); used when planting synthetic activations.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.uniform() - 0.5; // (-0.5, 0.5)
        let sign = if u >= 0.0 { 1.0f64 } else { -1.0 };
        let mag = (1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE).ln();
        (-(b as f64) * sign * mag) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for k ≪ n,
    /// shuffle otherwise). Sorted output for cache-friendly gathers.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut out: Vec<usize> = if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // dqlint::allow(no-map-iteration): membership probe only —
            // the output order comes from `v` + the final sort, the set
            // is never iterated.
            let mut set = std::collections::HashSet::with_capacity(k);
            let mut v = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                if set.insert(t) {
                    v.push(t);
                } else {
                    set.insert(j);
                    v.push(j);
                }
            }
            v
        };
        out.sort_unstable();
        out
    }
}

/// Zipf(α) sampler over ranks 1..=n via precomputed CDF — drives the
/// synthetic corpus token marginals (dialects differ in α).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap_or(&1.0);
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.uniform();
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(1);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            buckets[(u * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Pcg64::new(7);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..200_000).map(|_| rng.normal() as f64).collect();
        let m = crate::util::mean(&xs);
        let v = crate::util::variance(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
        assert!(crate::util::excess_kurtosis(&xs).abs() < 0.1);
    }

    #[test]
    fn laplace_has_heavy_tails() {
        let mut rng = Pcg64::new(5);
        let xs: Vec<f64> = (0..200_000).map(|_| rng.laplace(1.0) as f64).collect();
        assert!(crate::util::mean(&xs).abs() < 0.02);
        // Laplace excess kurtosis is 3.
        let k = crate::util::excess_kurtosis(&xs);
        assert!((k - 3.0).abs() < 0.5, "kurtosis {k}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg64::new(9);
        for (n, k) in [(100, 10), (100, 90), (5, 5), (1000, 1)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_rank_decreasing() {
        let mut rng = Pcg64::new(11);
        let z = Zipf::new(50, 1.2);
        let mut counts = [0usize; 50];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[20]);
    }

    #[test]
    fn zipf_with_nan_weights_never_panics() {
        // A NaN α poisons the whole CDF (every entry becomes NaN).
        // total_cmp treats NaN as the maximum, so the binary search
        // deterministically resolves to rank 0 instead of panicking
        // mid-draw — the WeightedIndex analogue of PR 4's NaN fixes.
        let mut rng = Pcg64::new(13);
        let z = Zipf::new(8, f64::NAN);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
        // ∞ α is fine too: all mass collapses onto rank 0.
        let z = Zipf::new(8, f64::INFINITY);
        for _ in 0..100 {
            assert!(z.sample(&mut rng) < 8);
        }
    }

    #[test]
    fn split_streams_decorrelate() {
        let mut a = Pcg64::new(2);
        let mut b = a.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
