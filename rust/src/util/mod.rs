//! Self-contained utility substrate.
//!
//! The offline vendor set ships neither `rand`, `serde`, `clap`, `tokio`,
//! `criterion` nor `proptest`, so this module provides the minimal,
//! well-tested equivalents the rest of the crate builds on:
//!
//! * [`prng`] — a PCG64-family PRNG with normal/Zipf samplers.
//! * [`json`] — a small JSON parser + writer (artifact manifests, config
//!   files, experiment outputs).
//! * [`cli`] — declarative flag parsing for the `dartquant` binary.
//! * [`threadpool`] — a fixed-size worker pool used by the coordinator.
//! * [`propcheck`] — a seeded property-testing helper (proptest stand-in).
//! * [`bench`] — the harness used by `cargo bench` targets.
//! * [`mem`] — process RSS sampling for the cost tables.
//! * [`sync`] — poison-tolerant lock helpers (the only module allowed
//!   to unwrap a lock result; see `docs/LINTS.md`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod mem;
pub mod propcheck;
pub mod prng;
pub mod sync;
pub mod threadpool;

/// Human-readable duration formatting used across benches and progress logs.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Excess kurtosis (Gaussian == 0), the statistic in the paper's Table 19.
pub fn excess_kurtosis(xs: &[f64]) -> f64 {
    let m = mean(xs);
    let v = variance(xs);
    if v <= 0.0 {
        return 0.0;
    }
    let m4 = xs.iter().map(|x| (x - m).powi(4)).sum::<f64>() / xs.len() as f64;
    m4 / (v * v) - 3.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn kurtosis_of_constant_is_zero() {
        assert_eq!(excess_kurtosis(&[3.0; 16]), 0.0);
    }

    #[test]
    fn kurtosis_sign_matches_tailedness() {
        // Two-point symmetric distribution has kurtosis -2 (light tails).
        let light: Vec<f64> = (0..1000).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        assert!(excess_kurtosis(&light) < -1.9);
        // A spike + rare huge outliers is heavy-tailed.
        let mut heavy = vec![0.0f64; 1000];
        heavy[0] = 50.0;
        heavy[1] = -50.0;
        assert!(excess_kurtosis(&heavy) > 10.0);
    }

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
        assert!(fmt_duration(Duration::from_secs(360)).ends_with("min"));
    }
}
