//! Minimal JSON parser + writer (serde is not in the offline vendor set).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), model/run
//! configs (`configs/*.json`) and machine-readable experiment outputs.
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! held as f64 (all our payloads are shapes, counts and metrics).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Convenience: `obj.get_str("name")`.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    /// Builder helpers for writer-side code.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences byte-faithfully.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        self.i = end;
                        s.push_str(std::str::from_utf8(&self.b[start..end]).map_err(|_| {
                            self.err("invalid utf8")
                        })?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization (round-trips through `parse`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get_str("b"), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"shapes": [[128, 256], [256]], "name": "calib_step", "lr": 0.002, "ok": true, "none": null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        let j = Json::Str("τ→uniform".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integer_display_is_integral() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
