//! Poison-tolerant lock helpers — the one place in the tree allowed to
//! unwrap a lock result.
//!
//! `Mutex::lock()` returns `Err` only when another thread panicked while
//! holding the guard. Propagating that as a second panic cascades one
//! worker's failure into every thread that later touches the lock —
//! exactly the failure mode the scheduler's panic containment
//! (`docs/CONCURRENCY.md`) exists to avoid. For every shared structure
//! in this crate (budget ledgers, event reorder buffers, pool queues,
//! artifact caches, serve sessions) the protected data is valid at every
//! guard drop point, so the right response to poisoning is to take the
//! guard anyway, log where it happened, and keep going.
//!
//! The `lock-poison-discipline` lint (`docs/LINTS.md`) forbids bare
//! `.lock().unwrap()` outside this module, so call sites route through
//! [`lock_or_poisoned`] / [`wait_or_poisoned`].

use std::sync::{Condvar, Mutex, MutexGuard};

/// Acquire `m`, recovering the guard if the mutex is poisoned.
///
/// On poisoning, logs the recovery (with the caller's location) to
/// stderr once per call and returns the inner guard — the data is
/// whatever the panicking thread left behind, which every protected
/// structure in this crate keeps valid between operations.
#[track_caller]
pub fn lock_or_poisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            let at = std::panic::Location::caller();
            eprintln!("warning: recovering poisoned mutex at {}:{}", at.file(), at.line());
            poisoned.into_inner()
        }
    }
}

/// Block on `cv` with `guard`, recovering the guard if the mutex was
/// poisoned while waiting. Companion to [`lock_or_poisoned`] for
/// condvar loops.
#[track_caller]
pub fn wait_or_poisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(guard) => guard,
        Err(poisoned) => {
            let at = std::panic::Location::caller();
            eprintln!("warning: recovering poisoned mutex at {}:{}", at.file(), at.line());
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m = Arc::clone(m);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _guard = m.lock().unwrap();
            panic!("poison the lock");
        }));
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(41));
        poison(&m);
        assert!(m.is_poisoned());
        let mut g = lock_or_poisoned(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn lock_passes_through_when_clean() {
        let m = Mutex::new("ok");
        assert_eq!(*lock_or_poisoned(&m), "ok");
    }

    #[test]
    fn wait_recovers_from_poison() {
        // Poison the mutex, then have a peer notify the condvar while we
        // wait on the recovered guard.
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let pair = Arc::clone(&pair);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                let _guard = pair.0.lock().unwrap();
                panic!("poison the lock");
            }));
        }
        assert!(pair.0.is_poisoned());
        let notifier = {
            let pair = Arc::clone(&pair);
            // dqlint::allow(raw-thread-spawn): test-only peer; the pool
            // itself depends on this module.
            std::thread::spawn(move || {
                let mut ready = lock_or_poisoned(&pair.0);
                *ready = true;
                pair.1.notify_one();
            })
        };
        let mut ready = lock_or_poisoned(&pair.0);
        while !*ready {
            ready = wait_or_poisoned(&pair.1, ready);
        }
        assert!(*ready);
        notifier.join().unwrap();
    }
}
