//! Tiny benchmarking harness used by the `cargo bench` targets (criterion is
//! not in the offline vendor set). Each bench target sets `harness = false`
//! and drives this module; reported numbers are median / p10 / p90 over
//! repeated timed runs after warmup.

use std::time::{Duration, Instant};

/// One measured statistic.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "bench {:<44} median {:>10}  p10 {:>10}  p90 {:>10}  ({} iters)",
            self.name,
            crate::util::fmt_duration(self.median),
            crate::util::fmt_duration(self.p10),
            crate::util::fmt_duration(self.p90),
            self.iters
        );
    }
}

/// Time `f` repeatedly: `warmup` unmeasured runs then `iters` measured runs
/// (`iters = 0` is promoted to one run — `Measurement::iters` always
/// reports the count actually measured).
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let iters = iters.max(1);
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let q = |p: f64| percentile(&samples, p).expect("iters >= 1");
    Measurement {
        name: name.to_string(),
        median: q(0.5),
        p10: q(0.1),
        p90: q(0.9),
        iters: samples.len(),
    }
}

/// The `p`-th percentile (p ∈ [0, 1]) of an **ascending-sorted** sample
/// slice, by the nearest-rank-below rule `idx = ⌊(len − 1) · p⌋` — the one
/// shared index-rounding policy for every p99/p10/median in the repo
/// (serve-bench, `perf_serve`, [`time`] all route through here).
/// `None` on an empty slice.
pub fn percentile<T: Copy>(sorted: &[T], p: f64) -> Option<T> {
    if sorted.is_empty() {
        return None;
    }
    let idx = ((sorted.len() - 1) as f64 * p) as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

/// Time a single run of `f` and return (result, wall time).
pub fn once<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Pretty table printer for paper-style result tables: fixed-width columns,
/// header row, separator. Keeps bench output diffable in EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Machine-readable bench receipts: when `DQ_BENCH_JSON` names a
/// directory, serialize `payload` to `<dir>/BENCH_<area>.json` and return
/// the path. Unset means no side effects — plain `cargo bench` runs stay
/// table-only. `scripts/bench_json.sh` (`make bench-json`) pins the env
/// together with `DQ_WORKERS` so committed receipts are comparable
/// across machines and runs.
pub fn write_receipt(area: &str, payload: &crate::util::json::Json) -> Option<std::path::PathBuf> {
    let dir = std::env::var("DQ_BENCH_JSON").ok()?;
    let path = std::path::PathBuf::from(dir).join(format!("BENCH_{area}.json"));
    if let Err(e) = std::fs::write(&path, format!("{payload}\n")) {
        eprintln!("bench receipt {} not written: {e}", path.display());
        return None;
    }
    println!("bench receipt written to {}", path.display());
    Some(path)
}

/// Format a float with `p` decimals; NaN/huge values print like the paper's
/// divergent-PPL cells.
pub fn fnum(x: f64, p: usize) -> String {
    if !x.is_finite() {
        "inf".to_string()
    } else if x.abs() >= 1e5 {
        format!("{:.0}", x)
    } else {
        format!("{:.*}", p, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_orders_quantiles() {
        let m = time("noop", 2, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.p10 <= m.median && m.median <= m.p90);
        assert_eq!(m.iters, 16);
    }

    #[test]
    fn time_zero_iters_measures_once_and_reports_it() {
        // iters = 0 must reserve and run the same (one) iteration, and the
        // measurement must report what actually ran.
        let m = time("noop", 0, 0, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.iters, 1);
        assert_eq!(m.p10, m.median);
        assert_eq!(m.median, m.p90);
    }

    #[test]
    fn percentile_boundary_sample_counts() {
        // 1 sample: every percentile is the sample itself.
        assert_eq!(percentile(&[7.0f64], 0.0), Some(7.0));
        assert_eq!(percentile(&[7.0f64], 0.99), Some(7.0));
        assert_eq!(percentile(&[7.0f64], 1.0), Some(7.0));
        // 99 samples 0..99: idx = floor(98 * 0.99) = 97.
        let v99: Vec<usize> = (0..99).collect();
        assert_eq!(percentile(&v99, 0.99), Some(97));
        // 100 samples 0..100: idx = floor(99 * 0.99) = 98.
        let v100: Vec<usize> = (0..100).collect();
        assert_eq!(percentile(&v100, 0.99), Some(98));
        assert_eq!(percentile(&v100, 0.0), Some(0));
        assert_eq!(percentile(&v100, 1.0), Some(99));
        // Matches the historical integer computation `(len-1)*99/100` at
        // every boundary count the hand-rolled call sites could disagree on.
        for len in [1usize, 2, 50, 99, 100, 101] {
            let v: Vec<usize> = (0..len).collect();
            assert_eq!(percentile(&v, 0.99), Some((len - 1) * 99 / 100));
        }
        assert_eq!(percentile::<f64>(&[], 0.5), None);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.rows_str(&["1", "2"]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.rows_str(&["only-one"])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fnum_handles_edge_cases() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(f64::INFINITY, 2), "inf");
        assert_eq!(fnum(63311.10, 2), "63311.10");
        assert_eq!(fnum(1.7e6, 2), "1700000");
    }
}
