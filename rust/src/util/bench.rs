//! Tiny benchmarking harness used by the `cargo bench` targets (criterion is
//! not in the offline vendor set). Each bench target sets `harness = false`
//! and drives this module; reported numbers are median / p10 / p90 over
//! repeated timed runs after warmup.

use std::time::{Duration, Instant};

/// One measured statistic.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "bench {:<44} median {:>10}  p10 {:>10}  p90 {:>10}  ({} iters)",
            self.name,
            crate::util::fmt_duration(self.median),
            crate::util::fmt_duration(self.p10),
            crate::util::fmt_duration(self.p90),
            self.iters
        );
    }
}

/// Time `f` repeatedly: `warmup` unmeasured runs then `iters` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Measurement {
        name: name.to_string(),
        median: q(0.5),
        p10: q(0.1),
        p90: q(0.9),
        iters: samples.len(),
    }
}

/// Time a single run of `f` and return (result, wall time).
pub fn once<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Pretty table printer for paper-style result tables: fixed-width columns,
/// header row, separator. Keeps bench output diffable in EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Machine-readable bench receipts: when `DQ_BENCH_JSON` names a
/// directory, serialize `payload` to `<dir>/BENCH_<area>.json` and return
/// the path. Unset means no side effects — plain `cargo bench` runs stay
/// table-only. `scripts/bench_json.sh` (`make bench-json`) pins the env
/// together with `DQ_WORKERS` so committed receipts are comparable
/// across machines and runs.
pub fn write_receipt(area: &str, payload: &crate::util::json::Json) -> Option<std::path::PathBuf> {
    let dir = std::env::var("DQ_BENCH_JSON").ok()?;
    let path = std::path::PathBuf::from(dir).join(format!("BENCH_{area}.json"));
    if let Err(e) = std::fs::write(&path, format!("{payload}\n")) {
        eprintln!("bench receipt {} not written: {e}", path.display());
        return None;
    }
    println!("bench receipt written to {}", path.display());
    Some(path)
}

/// Format a float with `p` decimals; NaN/huge values print like the paper's
/// divergent-PPL cells.
pub fn fnum(x: f64, p: usize) -> String {
    if !x.is_finite() {
        "inf".to_string()
    } else if x.abs() >= 1e5 {
        format!("{:.0}", x)
    } else {
        format!("{:.*}", p, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_orders_quantiles() {
        let m = time("noop", 2, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.p10 <= m.median && m.median <= m.p90);
        assert_eq!(m.iters, 16);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.rows_str(&["1", "2"]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.rows_str(&["only-one"])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fnum_handles_edge_cases() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(f64::INFINITY, 2), "inf");
        assert_eq!(fnum(63311.10, 2), "63311.10");
        assert_eq!(fnum(1.7e6, 2), "1700000");
    }
}
