//! Seeded property-testing helper — a proptest stand-in for the offline
//! environment. Runs a property over many generated cases; on failure it
//! reports the seed and case index so the exact input reproduces with
//! `Runner::only(seed, case)`.

use crate::util::prng::Pcg64;

/// Property-test runner configuration.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
    only_case: Option<usize>,
}

impl Runner {
    pub fn new() -> Self {
        // Seed overridable for reproduction via env var.
        let seed = std::env::var("DARTQUANT_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5eed_d00d);
        Runner { cases: 64, seed, only_case: None }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Re-run exactly one failing case.
    pub fn only(seed: u64, case: usize) -> Self {
        Runner { cases: case + 1, seed, only_case: Some(case) }
    }

    /// Run `prop` on `cases` independently-seeded generators. `prop` returns
    /// `Err(msg)` (or panics) to signal failure.
    pub fn run<F>(&self, name: &str, prop: F)
    where
        F: Fn(&mut Pcg64) -> Result<(), String>,
    {
        let mut root = Pcg64::new(self.seed);
        for case in 0..self.cases {
            let mut rng = root.split();
            if let Some(only) = self.only_case {
                if case != only {
                    continue;
                }
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
            let failed = match &outcome {
                Ok(Ok(())) => None,
                Ok(Err(msg)) => Some(msg.clone()),
                Err(p) => Some(
                    p.downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "panic".to_string()),
                ),
            };
            if let Some(msg) = failed {
                panic!(
                    "property '{name}' failed on case {case} (seed {:#x}): {msg}\n\
                     reproduce with Runner::only({:#x}, {case})",
                    self.seed, self.seed
                );
            }
        }
    }
}

impl Default for Runner {
    fn default() -> Self {
        Self::new()
    }
}

/// Generators for common test inputs.
pub mod gen {
    use crate::util::prng::Pcg64;

    /// Size in [lo, hi].
    pub fn size(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Vector of normals scaled by a random magnitude (exercises a range of
    /// value scales including subnormal-free small values).
    pub fn vec_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        let scale = 10f32.powf(rng.uniform_in(-2.0, 2.0));
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    /// Heavy-tailed activation-like vector: Laplace body + planted outliers.
    pub fn activations(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n).map(|_| rng.laplace(1.0)).collect();
        let outliers = 1 + rng.below((n / 16).max(1));
        for _ in 0..outliers {
            let i = rng.below(n);
            v[i] = rng.uniform_in(10.0, 50.0) * if rng.below(2) == 0 { 1.0 } else { -1.0 };
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        let counter = std::cell::Cell::new(0usize);
        Runner::new().cases(10).run("counting", |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports() {
        Runner::new().cases(5).run("fails", |rng| {
            if rng.below(2) < 2 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panicking_property_reports() {
        Runner::new().cases(3).run("panics", |_| panic!("boom"));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::prng::Pcg64::new(1);
        for _ in 0..100 {
            let n = gen::size(&mut rng, 3, 9);
            assert!((3..=9).contains(&n));
            assert_eq!(gen::vec_f32(&mut rng, n).len(), n);
            let acts = gen::activations(&mut rng, 32);
            assert!(acts.iter().any(|a| a.abs() >= 10.0), "has planted outlier");
        }
    }
}
