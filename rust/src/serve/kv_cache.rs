//! Session-level KV-cache aggregation and byte accounting.
//!
//! The per-layer storage primitive is [`model::kv::LayerKv`] (it is part
//! of the forward contract — `model::forward::block_step` takes one);
//! this module aggregates one per layer into a session's [`KvCache`] and
//! owns the byte accounting the serving engine charges against the
//! `coordinator::budget` gate: [`KvCache::nbytes`] reports resident
//! bytes and [`KvCache::estimate_nbytes`] predicts them **exactly** for
//! a given position count (property-tested in `model::kv` and
//! `rust/tests/serving.rs`). Layout and the bit-identity contract are
//! documented on [`LayerKv`] and in `docs/SERVING.md`.
//!
//! [`model::kv::LayerKv`]: crate::model::kv::LayerKv

use crate::model::ModelConfig;

pub use crate::model::kv::LayerKv;

/// All layers' KV state for one decode session.
///
/// Byte accounting is exact by contract — what a session *will* cost is
/// known before it is admitted:
///
/// ```
/// use dartquant::model::ModelConfig;
/// use dartquant::serve::KvCache;
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let mut cache = KvCache::new(&cfg, 16.0, true); // 4-bit KV codes
/// for l in 0..cfg.n_layers {
///     cache.layer_mut(l).extend(5); // room for 5 new positions
/// }
/// assert_eq!(cache.positions(), 5);
/// // …the same number the engine charges the budget gate up front:
/// assert_eq!(cache.nbytes(), KvCache::estimate_nbytes(&cfg, 16.0, 5, true));
/// # Ok(()) }
/// ```
#[derive(Clone, Debug)]
pub struct KvCache {
    layers: Vec<LayerKv>,
}

impl KvCache {
    /// Fresh empty cache for `cfg` at `kv_levels` (see [`LayerKv::new`]
    /// for `compact`).
    pub fn new(cfg: &ModelConfig, kv_levels: f32, compact: bool) -> KvCache {
        KvCache {
            layers: (0..cfg.n_layers)
                .map(|_| LayerKv::for_model(cfg, kv_levels, compact))
                .collect(),
        }
    }

    /// Layer `l`'s cache.
    pub fn layer_mut(&mut self, l: usize) -> &mut LayerKv {
        &mut self.layers[l]
    }

    /// Cached positions (identical across layers by construction).
    pub fn positions(&self) -> usize {
        self.layers.first().map(|l| l.positions()).unwrap_or(0)
    }

    /// Total resident cache bytes across layers.
    pub fn nbytes(&self) -> u64 {
        self.layers.iter().map(|l| l.nbytes()).sum()
    }

    /// Exact byte cost of caching `positions` positions for `cfg` — what
    /// the serving engine charges the memory gate per session.
    pub fn estimate_nbytes(
        cfg: &ModelConfig,
        kv_levels: f32,
        positions: usize,
        compact: bool,
    ) -> u64 {
        cfg.n_layers as u64
            * LayerKv::estimate_nbytes(cfg.n_kv_heads, cfg.head_dim, kv_levels, positions, compact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_aggregates_layers_and_matches_estimate() {
        let cfg = ModelConfig::builtin("llama3-small").unwrap();
        let mut cache = KvCache::new(&cfg, 16.0, true);
        assert_eq!(cache.positions(), 0);
        assert_eq!(cache.nbytes(), 0);
        for l in 0..cfg.n_layers {
            cache.layer_mut(l).extend(7);
        }
        assert_eq!(cache.positions(), 7);
        assert_eq!(cache.nbytes(), KvCache::estimate_nbytes(&cfg, 16.0, 7, true));
        // fp KV grids fall back to f32 rows — still exact accounting.
        let mut fp = KvCache::new(&cfg, 65536.0, true);
        for l in 0..cfg.n_layers {
            fp.layer_mut(l).extend(3);
        }
        assert_eq!(fp.nbytes(), KvCache::estimate_nbytes(&cfg, 65536.0, 3, true));
        assert!(fp.nbytes() > cache.nbytes() / 7 * 3, "f32 rows outweigh codes");
    }
}
