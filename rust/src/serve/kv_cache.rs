//! Session-level KV-cache aggregation and byte accounting.
//!
//! The per-layer storage primitive is [`model::kv::LayerKv`] (it is part
//! of the forward contract — `model::forward::block_step` takes any
//! [`KvSlot`]); this module aggregates a session's layers into a
//! [`KvCache`] with two interchangeable backends:
//!
//! * **contiguous** — one owned `LayerKv` per layer; full-lifetime byte
//!   accounting via [`KvCache::estimate_nbytes`]. The fallback path and
//!   the parity oracle for the paged backend.
//! * **paged** — a [`PagedKv`] handle mapping fixed-size pages owned by
//!   `serve::pager::Pager` (prefix sharing, eviction/spill); bytes are
//!   charged page-granularly as the session grows. Bit-identical token
//!   streams to the contiguous backend at every page size — the gate in
//!   `rust/tests/serving.rs`.
//!
//! [`KvCache::nbytes`] reports what the session maps right now (exact
//! in both backends — property-tested in `model::kv` and
//! `rust/tests/serving.rs`). Layout and the bit-identity contract are
//! documented on [`LayerKv`] and in `docs/SERVING.md`.
//!
//! [`model::kv::LayerKv`]: crate::model::kv::LayerKv

use super::pager::{PagedKv, Pager};
use crate::model::ModelConfig;
use std::sync::Arc;

pub use crate::model::kv::{KvSlot, LayerKv};

enum Backend {
    Contiguous(Vec<LayerKv>),
    Paged(PagedKv),
}

/// All layers' KV state for one decode session (contiguous or paged —
/// see the module docs).
///
/// Contiguous byte accounting is exact by contract — what a session
/// *will* cost is known before it is admitted:
///
/// ```
/// use dartquant::model::ModelConfig;
/// use dartquant::serve::{KvCache, KvSlot};
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let mut cache = KvCache::new(&cfg, 16.0, true); // 4-bit KV codes
/// for l in 0..cfg.n_layers {
///     cache.layer_mut(l).extend(5); // room for 5 new positions
/// }
/// assert_eq!(cache.positions(), 5);
/// // …the same number the engine charges the budget gate up front:
/// assert_eq!(cache.nbytes(), KvCache::estimate_nbytes(&cfg, 16.0, 5, true));
/// # Ok(()) }
/// ```
pub struct KvCache {
    backend: Backend,
}

impl KvCache {
    /// Fresh empty contiguous cache for `cfg` at `kv_levels` (see
    /// [`LayerKv::new`] for `compact`).
    pub fn new(cfg: &ModelConfig, kv_levels: f32, compact: bool) -> KvCache {
        KvCache {
            backend: Backend::Contiguous(
                (0..cfg.n_layers).map(|_| LayerKv::for_model(cfg, kv_levels, compact)).collect(),
            ),
        }
    }

    /// A paged cache over pager session `sid` (created by
    /// `Pager::admit`); dropping it releases the session's pages.
    pub fn paged(pager: &Arc<Pager>, sid: u64) -> KvCache {
        KvCache { backend: Backend::Paged(PagedKv::new(pager, sid)) }
    }

    /// Layer `l`'s cache slot — what `block_step` writes and reads.
    pub fn layer_mut(&mut self, l: usize) -> &mut dyn KvSlot {
        match &mut self.backend {
            Backend::Contiguous(layers) => &mut layers[l],
            Backend::Paged(kv) => kv.layer_mut(l),
        }
    }

    /// Cached positions (identical across layers by construction).
    pub fn positions(&self) -> usize {
        match &self.backend {
            Backend::Contiguous(layers) => layers.first().map(|l| l.positions()).unwrap_or(0),
            Backend::Paged(kv) => kv.positions(),
        }
    }

    /// Roll every layer back to `positions` cached positions
    /// (speculative-decode rejection; [`KvSlot::truncate`] contract).
    /// Contiguous: row storage shrinks so `nbytes()` matches a fresh
    /// cache of that length bit-for-bit. Paged: whole pages past
    /// `pages_for(positions)` are unmapped and freed when unshared.
    pub fn truncate(&mut self, positions: usize) {
        match &mut self.backend {
            Backend::Contiguous(layers) => {
                for l in layers {
                    l.truncate(positions);
                }
            }
            Backend::Paged(kv) => kv.truncate(positions),
        }
    }

    /// Make the cache writable for a step appending `new_positions`
    /// positions. Contiguous caches are always writable; a paged cache
    /// forwards to `Pager::prepare_step` so its pages are resident and
    /// fresh ones pre-allocated (standalone sessions — the engine calls
    /// the pager directly with its protected set). Returns `false` when
    /// a paged working set cannot be made resident right now.
    pub fn reserve(&mut self, new_positions: usize) -> anyhow::Result<bool> {
        match &mut self.backend {
            Backend::Contiguous(_) => Ok(true),
            Backend::Paged(kv) => kv.prepare(new_positions),
        }
    }

    /// Bytes this session maps: summed row bytes (contiguous) or mapped
    /// pages × page bytes (paged; shared pages count toward each mapper
    /// here but only once against the gate).
    pub fn nbytes(&self) -> u64 {
        match &self.backend {
            Backend::Contiguous(layers) => layers.iter().map(|l| l.nbytes()).sum(),
            Backend::Paged(kv) => kv.nbytes(),
        }
    }

    /// Exact byte cost of caching `positions` positions contiguously for
    /// `cfg` — what the serving engine charges the memory gate per
    /// session in contiguous mode.
    pub fn estimate_nbytes(
        cfg: &ModelConfig,
        kv_levels: f32,
        positions: usize,
        compact: bool,
    ) -> u64 {
        cfg.n_layers as u64
            * LayerKv::estimate_nbytes(cfg.n_kv_heads, cfg.head_dim, kv_levels, positions, compact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_aggregates_layers_and_matches_estimate() {
        let cfg = ModelConfig::builtin("llama3-small").unwrap();
        let mut cache = KvCache::new(&cfg, 16.0, true);
        assert_eq!(cache.positions(), 0);
        assert_eq!(cache.nbytes(), 0);
        for l in 0..cfg.n_layers {
            cache.layer_mut(l).extend(7);
        }
        assert_eq!(cache.positions(), 7);
        assert_eq!(cache.nbytes(), KvCache::estimate_nbytes(&cfg, 16.0, 7, true));
        // fp KV grids fall back to f32 rows — still exact accounting.
        let mut fp = KvCache::new(&cfg, 65536.0, true);
        for l in 0..cfg.n_layers {
            fp.layer_mut(l).extend(3);
        }
        assert_eq!(fp.nbytes(), KvCache::estimate_nbytes(&cfg, 65536.0, 3, true));
        assert!(fp.nbytes() > cache.nbytes() / 7 * 3, "f32 rows outweigh codes");
    }

    #[test]
    fn paged_backend_reports_page_granular_bytes() {
        use crate::coordinator::budget::MemoryGate;
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let pager =
            Arc::new(Pager::new(&cfg, 16.0, 4, false, Arc::new(MemoryGate::new(None))));
        let sid = pager.admit(&[1, 2, 3], 6).unwrap().unwrap();
        let mut cache = KvCache::paged(&pager, sid);
        assert_eq!(cache.positions(), 0);
        assert!(pager.prepare_step(sid, 3, &[sid]).unwrap());
        for l in 0..cfg.n_layers {
            cache.layer_mut(l).extend(3);
        }
        assert_eq!(cache.positions(), 3);
        // 3 positions at P=4 → one (partially filled) page per layer,
        // charged at full capacity.
        assert_eq!(
            cache.nbytes(),
            cfg.n_layers as u64 * pager.layout().page_bytes(),
            "page-granular accounting"
        );
        assert_eq!(cache.nbytes(), pager.charged_bytes());
        drop(cache);
        assert_eq!(pager.charged_bytes(), 0, "dropping the cache releases the session");
    }
}
