//! Continuous-batching decode engine.
//!
//! [`BatchEngine`] runs many [`DecodeSession`]s in lock step: each
//! [`BatchEngine::step`] first admits pending requests (FIFO), then
//! advances active sessions by one token on scoped worker threads
//! (`util::threadpool::scoped_try_map`), then retires finished sessions
//! — releasing their cache bytes so the next pending request can slide
//! in *between* steps, not at batch boundaries.
//!
//! Two cache modes share that loop:
//!
//! * **contiguous** (default) — one contiguous [`KvCache`] per session,
//!   full-lifetime bytes reserved at admission through the
//!   `coordinator::budget` gate. The parity oracle.
//! * **paged** (`EngineConfig::paged`) — sessions map fixed-size pages
//!   from a [`Pager`]: bytes are charged page-granularly as sessions
//!   grow, identical prompt prefixes share their prefill pages, and
//!   (with `spill`) cold pages are evicted to a temp file under budget
//!   pressure. Each step selects sessions least-recently-stepped first
//!   and calls [`Pager::prepare_step`] for each, stopping at the first
//!   that cannot be made resident — deferred sessions are the oldest
//!   next step, so nothing starves.
//!
//! With [`EngineConfig::speculate`], each admitted request decodes
//! through a [`SpecSession`] instead of a plain session: a low-bit draft
//! (installed via [`BatchEngine::set_draft`], defaulting to the
//! verifier's own weights) proposes `k` tokens per round and the
//! engine's serving precision verifies them in one batched prefill. In
//! paged mode the draft holds a second, *private* pager session
//! ([`Pager::admit_private`]) — its KV rows come from a
//! different-precision forward, so it must never map or register shared
//! prefix pages; only verifier prompts enter the prefix index.
//!
//! Determinism follows the `docs/CONCURRENCY.md` contract: every session
//! samples from its own `Pcg64` seeded `seed ⊕ f(id)`, sessions never
//! share mutable state (shared pages are read-only by the pager's CoW
//! contract), and [`EngineEvent`]s are recorded only on the engine
//! thread at deterministic points. Two runs of the same submissions
//! produce identical token streams and event logs at any worker count;
//! across cache modes and page sizes the *token streams* and the
//! [`BatchEngine::canonical_events`] projection are identical, while raw
//! byte/step events legitimately differ — enforced by
//! `rust/tests/serving.rs`.

use super::kv_cache::KvCache;
use super::pager::{Pager, PagerStats};
use super::session::{sample_logits, DecodeSession};
use super::spec::{SpecConfig, SpecSession, SpecStats};
use crate::coordinator::budget::{MemoryGate, OwnedLease};
use crate::model::{FwdOptions, Weights};
use crate::util::prng::Pcg64;
use crate::util::sync::lock_or_poisoned;
use crate::util::threadpool::{scoped_try_map, ThreadPool};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// The KV bytes one request holds for its whole active lifetime in
/// contiguous mode: the prompt plus every generated token except the
/// last (sampled but never fed back through the model). The single
/// formula behind the contiguous admission charge and the CLI's
/// single-session budget check; in paged mode the analogue is
/// `PageLayout::session_max_bytes` over the same position count.
pub fn request_cache_bytes(
    cfg: &crate::model::ModelConfig,
    kv_levels: f32,
    prompt: usize,
    max_new: usize,
) -> u64 {
    KvCache::estimate_nbytes(cfg, kv_levels, prompt + max_new.saturating_sub(1), true)
}

/// One generation request: a prompt and a continuation length.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Prompt token ids (must be non-empty).
    pub prompt: Vec<i32>,
    /// Tokens to generate after the prompt.
    pub max_new: usize,
}

/// Outcome of one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenResult {
    /// Submission id (also the determinism seed offset).
    pub id: usize,
    /// Prompt length the session was fed.
    pub prompt_len: usize,
    /// Generated continuation (empty on error).
    pub tokens: Vec<i32>,
    /// Why the request failed, if it did.
    pub error: Option<String>,
}

/// Engine lifecycle events, recorded in a deterministic order (see the
/// module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineEvent {
    /// A request was admitted; `cache_bytes` is its full-lifetime
    /// reservation (contiguous) or its maximum marginal page bytes
    /// (paged — shared prefix pages excluded).
    Admitted { id: usize, prompt: usize, cache_bytes: u64 },
    /// A request can never fit the budget and was failed outright.
    Rejected { id: usize, need: u64, budget: u64 },
    /// One lock-step advance; `active` counts the sessions that stepped.
    StepBatch { step: usize, active: usize },
    /// A session finished and released its cache bytes.
    Retired { id: usize, generated: usize },
}

/// Paged-KV engine mode (see `serve::pager` for the machinery).
#[derive(Clone, Copy, Debug)]
pub struct PagedConfig {
    /// Positions per page.
    pub page_positions: usize,
    /// `true`: evict cold pages to a temp spill file under budget
    /// pressure (admission checks feasibility only). `false`: keep all
    /// pages resident and admit conservatively against the total
    /// commitment instead.
    pub spill: bool,
}

impl Default for PagedConfig {
    fn default() -> Self {
        PagedConfig { page_positions: 16, spill: false }
    }
}

/// Engine knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Forward options every session decodes with.
    pub opt: FwdOptions,
    /// Base sampling seed; session `id` draws from `seed ⊕ f(id)`.
    pub seed: u64,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Worker threads per step (0 = available parallelism).
    pub workers: usize,
    /// KV-cache byte budget across concurrent sessions (None = unlimited).
    pub budget: Option<u64>,
    /// Cap on concurrent sessions (0 = bounded by the budget only).
    pub max_sessions: usize,
    /// Paged KV cache mode (None = contiguous per-session caches).
    pub paged: Option<PagedConfig>,
    /// Speculative decoding (None = plain one-token-per-step decode).
    /// The draft model comes from [`BatchEngine::set_draft`]; greedy
    /// output is token-for-token identical either way.
    pub speculate: Option<SpecConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            opt: FwdOptions::FP,
            seed: 0,
            temperature: 0.0,
            workers: 0,
            budget: None,
            max_sessions: 0,
            paged: None,
            speculate: None,
        }
    }
}

/// Per-request decode state: one plain session, or a speculative
/// draft/verifier pair under [`EngineConfig::speculate`].
enum Decoder {
    Plain(DecodeSession),
    Spec(SpecSession),
}

/// An admitted, in-flight session.
struct Active {
    id: usize,
    decoder: Decoder,
    rng: Pcg64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    max_new: usize,
    last: i32,
    /// Whether the prompt (suffix) has been prefilled yet.
    prefilled: bool,
    /// Engine step this session last advanced in (0 = never) — the
    /// least-recently-stepped ordering key under paged pressure.
    last_tick: usize,
    /// Pager session id in paged mode (the verifier's, when speculating).
    sid: Option<u64>,
    /// The draft's private pager session id (paged speculative mode).
    draft_sid: Option<u64>,
    /// Full-lifetime gate lease in contiguous mode (paged sessions are
    /// charged per page by the pager instead).
    _lease: Option<OwnedLease>,
}

impl Active {
    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    /// Currently-mapped KV bytes — both caches of a speculative pair.
    fn cache_nbytes(&self) -> u64 {
        match &self.decoder {
            Decoder::Plain(session) => session.cache_nbytes(),
            Decoder::Spec(spec) => spec.cache_nbytes(),
        }
    }

    /// Advance this session: prefill on first touch (continuous batching
    /// admits mid-flight, so fresh sessions prefill while others step).
    /// A paged session admitted onto shared prefix pages starts with
    /// cached positions and prefills only its prompt suffix — the
    /// chunked-prefill equivalence keeps that bit-identical to a full
    /// prefill. Plain sessions commit one token per tick; a speculative
    /// pair commits its `begin` token on first touch, then a whole round
    /// (1 ..= k+1 tokens) per tick.
    fn advance(&mut self, temperature: f32) -> anyhow::Result<()> {
        if self.done() {
            return Ok(());
        }
        match &mut self.decoder {
            Decoder::Plain(session) => {
                let row: Vec<f32> = if self.prefilled {
                    session.step(self.last)
                } else {
                    let from = session.positions();
                    self.prefilled = true;
                    session.prefill_last(&self.prompt[from..])
                };
                let next = sample_logits(&row, temperature, &mut self.rng) as i32;
                self.generated.push(next);
                self.last = next;
            }
            Decoder::Spec(spec) => {
                if self.prefilled {
                    let remaining = self.max_new - self.generated.len();
                    let toks = spec.round(temperature, &mut self.rng, remaining)?;
                    self.generated.extend(toks);
                } else {
                    self.prefilled = true;
                    let first = spec.begin(&self.prompt, temperature, &mut self.rng)?;
                    self.generated.push(first);
                }
            }
        }
        Ok(())
    }
}

/// The continuous-batching engine (see the module docs).
///
/// Submit requests, then either drive [`BatchEngine::step`] yourself or
/// let [`BatchEngine::run`] loop to completion:
///
/// ```no_run
/// use dartquant::model::{ModelConfig, Weights};
/// use dartquant::serve::{BatchEngine, EngineConfig, GenRequest, PagedConfig};
/// use std::sync::Arc;
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let weights = Arc::new(Weights::default_synthetic(&cfg, 1));
/// let mut engine = BatchEngine::new(
///     weights,
///     EngineConfig {
///         budget: Some(24 << 20), // scaled single-3090 KV budget
///         paged: Some(PagedConfig::default()), // page-granular charging
///         ..EngineConfig::default()
///     },
/// );
/// for i in 0..4 {
///     engine.submit(GenRequest { prompt: vec![1, 2, 3 + i], max_new: 16 });
/// }
/// let results = engine.run()?; // admit → lock-step advance → retire
/// assert_eq!(results.len(), 4);
/// # Ok(()) }
/// ```
pub struct BatchEngine {
    weights: Arc<Weights>,
    cfg: EngineConfig,
    gate: Arc<MemoryGate>,
    pager: Option<Arc<Pager>>,
    /// Draft weights/options for speculative mode (None = draft with the
    /// verifier's own weights — correct, but every proposal accepts).
    draft: Option<(Arc<Weights>, FwdOptions)>,
    pending: VecDeque<(usize, GenRequest)>,
    active: Vec<Active>,
    finished: Vec<GenResult>,
    events: Vec<EngineEvent>,
    next_id: usize,
    steps: usize,
    peak_active: usize,
    /// Speculation counters folded in from retired sessions.
    spec_totals: SpecStats,
}

impl BatchEngine {
    /// An engine over shared weights; the admission gate is sized by
    /// `cfg.budget`, and `cfg.paged` mounts a [`Pager`] on that same
    /// gate.
    pub fn new(weights: Arc<Weights>, cfg: EngineConfig) -> BatchEngine {
        let gate = Arc::new(MemoryGate::new(cfg.budget));
        let pager = cfg.paged.map(|p| {
            Arc::new(Pager::new(
                &weights.cfg,
                cfg.opt.kv_levels,
                p.page_positions,
                p.spill,
                Arc::clone(&gate),
            ))
        });
        BatchEngine {
            gate,
            pager,
            weights,
            cfg,
            draft: None,
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            events: Vec::new(),
            next_id: 0,
            steps: 0,
            peak_active: 0,
            spec_totals: SpecStats::default(),
        }
    }

    /// Install the draft model for speculative mode
    /// ([`EngineConfig::speculate`]) — typically the same checkpoint
    /// re-quantized to an aggressive packed grid
    /// (`quant::rtn_quantize_model_packed`). The draft's `kv_levels` is
    /// forced to the engine's own: the pager sizes its pages for one KV
    /// grid, and caching both sessions on that grid keeps the draft's
    /// proposals — and therefore the accepted-prefix length — invariant
    /// to the cache backend. Left unset, speculation drafts with the
    /// verifier's weights (every proposal accepted).
    pub fn set_draft(&mut self, weights: Arc<Weights>, mut opt: FwdOptions) {
        opt.kv_levels = self.cfg.opt.kv_levels;
        self.draft = Some((weights, opt));
    }

    /// The draft weights/options speculative sessions decode with.
    fn draft_pair(&self) -> (Arc<Weights>, FwdOptions) {
        match &self.draft {
            Some((w, o)) => (Arc::clone(w), *o),
            None => (Arc::clone(&self.weights), self.cfg.opt),
        }
    }

    /// Queue a request; returns its id. Empty prompts fail immediately;
    /// `max_new == 0` succeeds trivially without ever holding cache
    /// bytes or occupying a step slot.
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        if req.prompt.is_empty() {
            self.finished.push(GenResult {
                id,
                prompt_len: 0,
                tokens: Vec::new(),
                error: Some("empty prompt".to_string()),
            });
        } else if req.max_new == 0 {
            self.finished.push(GenResult {
                id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                error: None,
            });
        } else {
            self.pending.push_back((id, req));
        }
        id
    }

    /// The KV bytes request `req` will hold while active (contiguous
    /// mode). A speculative pair holds two caches over the same
    /// positions, so both are reserved up front.
    fn cache_bytes(&self, req: &GenRequest) -> u64 {
        let one = |kv_levels: f32| {
            request_cache_bytes(&self.weights.cfg, kv_levels, req.prompt.len(), req.max_new)
        };
        let verifier = one(self.cfg.opt.kv_levels);
        match self.cfg.speculate {
            Some(_) => verifier + one(self.draft_pair().1.kv_levels),
            None => verifier,
        }
    }

    fn mk_active(
        &self,
        id: usize,
        req: GenRequest,
        sid: Option<u64>,
        draft_sid: Option<u64>,
        lease: Option<OwnedLease>,
    ) -> Active {
        let session = |weights: &Arc<Weights>, opt: FwdOptions, psid: Option<u64>| match (
            &self.pager,
            psid,
        ) {
            (Some(pager), Some(psid)) => {
                DecodeSession::with_cache(Arc::clone(weights), opt, KvCache::paged(pager, psid))
            }
            _ => DecodeSession::new(Arc::clone(weights), opt),
        };
        let verifier = session(&self.weights, self.cfg.opt, sid);
        let decoder = match self.cfg.speculate {
            Some(sc) => {
                let (dw, dopt) = self.draft_pair();
                let draft = session(&dw, dopt, draft_sid);
                Decoder::Spec(SpecSession::engine_managed(draft, verifier, sc.k))
            }
            None => Decoder::Plain(verifier),
        };
        Active {
            id,
            decoder,
            rng: Pcg64::new(self.cfg.seed ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)),
            prompt: req.prompt,
            generated: Vec::new(),
            max_new: req.max_new,
            last: 0,
            prefilled: false,
            last_tick: 0,
            sid,
            draft_sid,
            _lease: lease,
        }
    }

    /// Admit pending requests (FIFO) while the cache-mode's admission
    /// test passes and the session cap allows. Contiguous mode charges
    /// the full-lifetime bytes up front; paged mode asks the pager,
    /// which maps shared prefix pages and charges growth per step.
    fn admit_pending(&mut self) {
        while let Some((_, req)) = self.pending.front() {
            if self.cfg.max_sessions > 0 && self.active.len() >= self.cfg.max_sessions {
                break;
            }
            if let Some(pager) = &self.pager {
                // Prompt + every generated token except the last — the
                // same lifetime positions contiguous mode reserves.
                let target = req.prompt.len() + req.max_new - 1;
                match pager.admit(&req.prompt, target.max(req.prompt.len())) {
                    Err(e) => {
                        let (id, req) = self.pending.pop_front().expect("front exists");
                        self.events.push(EngineEvent::Rejected {
                            id,
                            need: e.need,
                            budget: e.budget,
                        });
                        self.finished.push(GenResult {
                            id,
                            prompt_len: req.prompt.len(),
                            tokens: Vec::new(),
                            error: Some(e.to_string()),
                        });
                    }
                    Ok(None) => break, // FIFO: wait for retirements to free pages
                    Ok(Some(sid)) => {
                        // Speculative mode: the draft needs its own
                        // *private* pager session (its KV precision
                        // differs, so it must not map shared prompt
                        // pages). Admit both halves or neither — a
                        // verifier holding pages while the draft waits
                        // would skew the FIFO accounting.
                        let draft_sid = if self.cfg.speculate.is_some() {
                            match pager.admit_private(&req.prompt, target.max(req.prompt.len())) {
                                Err(e) => {
                                    pager.release_session(sid);
                                    let (id, req) = self.pending.pop_front().expect("front exists");
                                    self.events.push(EngineEvent::Rejected {
                                        id,
                                        need: e.need,
                                        budget: e.budget,
                                    });
                                    self.finished.push(GenResult {
                                        id,
                                        prompt_len: req.prompt.len(),
                                        tokens: Vec::new(),
                                        error: Some(e.to_string()),
                                    });
                                    continue;
                                }
                                Ok(None) => {
                                    pager.release_session(sid);
                                    break;
                                }
                                Ok(Some(d)) => Some(d),
                            }
                        } else {
                            None
                        };
                        let (id, req) = self.pending.pop_front().expect("front exists");
                        let marginal = pager.session_marginal_max_bytes(sid)
                            + draft_sid.map_or(0, |d| pager.session_marginal_max_bytes(d));
                        self.events.push(EngineEvent::Admitted {
                            id,
                            prompt: req.prompt.len(),
                            cache_bytes: marginal,
                        });
                        let active = self.mk_active(id, req, Some(sid), draft_sid, None);
                        self.active.push(active);
                    }
                }
            } else {
                let bytes = self.cache_bytes(req);
                match MemoryGate::try_admit_owned(&self.gate, bytes) {
                    Err(e) => {
                        let (id, req) = self.pending.pop_front().expect("front exists");
                        self.events.push(EngineEvent::Rejected {
                            id,
                            need: e.need,
                            budget: e.budget,
                        });
                        self.finished.push(GenResult {
                            id,
                            prompt_len: req.prompt.len(),
                            tokens: Vec::new(),
                            error: Some(e.to_string()),
                        });
                    }
                    Ok(None) => break, // FIFO: wait for a retirement to free bytes
                    Ok(Some(lease)) => {
                        let (id, req) = self.pending.pop_front().expect("front exists");
                        self.events.push(EngineEvent::Admitted {
                            id,
                            prompt: req.prompt.len(),
                            cache_bytes: bytes,
                        });
                        let active = self.mk_active(id, req, None, None, Some(lease));
                        self.active.push(active);
                    }
                }
            }
        }
        self.peak_active = self.peak_active.max(self.active.len());
    }

    /// Pick this step's sessions. Contiguous mode advances everyone; in
    /// paged mode sessions are prepared least-recently-stepped first
    /// (ties to the lower id) and selection stops at the first whose
    /// working set cannot be made resident — already-selected sessions
    /// are protected from eviction, and the deferred session is the
    /// oldest candidate next step, so no session starves.
    fn select_step(&mut self) -> anyhow::Result<Vec<usize>> {
        let Some(pager) = &self.pager else {
            return Ok((0..self.active.len()).collect());
        };
        let mut order: Vec<usize> = (0..self.active.len()).collect();
        order.sort_by_key(|&i| (self.active[i].last_tick, self.active[i].id));
        let mut prot: Vec<u64> = Vec::with_capacity(order.len());
        let mut sel = Vec::with_capacity(order.len());
        for i in order {
            let a = &self.active[i];
            let sid = a.sid.expect("paged session has a pager id");
            prot.push(sid);
            if let Some(dsid) = a.draft_sid {
                prot.push(dsid);
            }
            let ready = match &a.decoder {
                Decoder::Plain(session) => {
                    let new_positions =
                        if a.prefilled { 1 } else { a.prompt.len() - session.positions() };
                    pager.prepare_step(sid, new_positions, &prot)?
                }
                Decoder::Spec(spec) => {
                    // The hint is exact for the round the pair will run
                    // this tick (prefill, k-proposal round, or the plain
                    // closing step) — pages prepared here are pages the
                    // round writes, nothing more.
                    let remaining = a.max_new - a.generated.len();
                    let (draft_new, verifier_new) = spec.reserve_hint(a.prompt.len(), remaining);
                    let dsid = a.draft_sid.expect("speculative paged session has a draft id");
                    pager.prepare_step(sid, verifier_new, &prot)?
                        && pager.prepare_step(dsid, draft_new, &prot)?
                }
            };
            if ready {
                sel.push(i);
            } else {
                break; // strict stop: keep the step's working set coherent
            }
        }
        if sel.is_empty() {
            // Unreachable by construction — the first candidate protects
            // only itself and its working set passed admission — but a
            // wedged scheduler must fail loudly, not spin.
            anyhow::bail!("paged scheduling made no progress: no session fits the budget");
        }
        sel.sort_unstable();
        Ok(sel)
    }

    /// One engine tick: admit → advance the selected sessions one token
    /// in parallel → retire finished sessions. Returns whether work
    /// remains.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        self.admit_pending();
        if self.active.is_empty() {
            // Nothing runnable: admission either drained or rejected
            // every pending request (an empty gate admits anything that
            // can ever fit), so the queue is empty too.
            return Ok(false);
        }
        let sel = self.select_step()?;
        // Sessions prefilling this step: register their prompt pages in
        // the prefix index after the join, when they are content-complete.
        let newly_prefilled: Vec<usize> =
            sel.iter().copied().filter(|&i| !self.active[i].prefilled).collect();
        let workers = if self.cfg.workers == 0 {
            ThreadPool::default_parallelism()
        } else {
            self.cfg.workers
        };
        let temperature = self.cfg.temperature;
        let cells: Vec<Mutex<&mut Active>> = self
            .active
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| sel.binary_search(i).is_ok())
            .map(|(_, a)| Mutex::new(a))
            .collect();
        let advanced = scoped_try_map(workers, &cells, |_, cell| {
            lock_or_poisoned(cell).advance(temperature)
        })
        .map_err(|p| {
            anyhow::anyhow!("decode step panicked in session slot {}: {}", p.index, p.message)
        })?;
        drop(cells);
        for r in advanced {
            r?;
        }
        self.steps += 1;
        self.events.push(EngineEvent::StepBatch { step: self.steps, active: sel.len() });
        for &i in &sel {
            self.active[i].last_tick = self.steps;
        }
        if let Some(pager) = &self.pager {
            for &i in &newly_prefilled {
                let a = &self.active[i];
                pager.register_prefix(a.sid.expect("paged session"), &a.prompt);
            }
        }
        // Retire in admission order; dropping an Active releases its
        // lease (contiguous) or its pages (paged, via the PagedKv drop).
        let mut still = Vec::with_capacity(self.active.len());
        for a in self.active.drain(..) {
            if a.done() {
                if let Decoder::Spec(spec) = &a.decoder {
                    self.spec_totals.merge(&spec.stats());
                }
                self.events.push(EngineEvent::Retired { id: a.id, generated: a.generated.len() });
                self.finished.push(GenResult {
                    id: a.id,
                    prompt_len: a.prompt.len(),
                    tokens: a.generated,
                    error: None,
                });
            } else {
                still.push(a);
            }
        }
        self.active = still;
        Ok(!(self.active.is_empty() && self.pending.is_empty()))
    }

    /// Drive [`BatchEngine::step`] until every request finished; results
    /// are ordered by request id.
    pub fn run(&mut self) -> anyhow::Result<&[GenResult]> {
        while self.step()? {}
        self.finished.sort_by_key(|r| r.id);
        Ok(&self.finished)
    }

    /// Event log so far (deterministic across worker counts).
    pub fn events(&self) -> &[EngineEvent] {
        &self.events
    }

    /// Scheduling- and layout-independent projection of the event log:
    /// per-session lifecycle facts (admitted/rejected/retired), sorted by
    /// id, with byte counts and step cadence dropped — those legitimately
    /// differ between cache modes and page sizes while the projection
    /// must not. The cross-mode equality gate in `rust/tests/serving.rs`
    /// compares exactly this.
    pub fn canonical_events(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .events
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Admitted { id, prompt, .. } => {
                    Some(format!("{id:08} admitted prompt={prompt}"))
                }
                EngineEvent::Rejected { id, .. } => Some(format!("{id:08} rejected")),
                EngineEvent::Retired { id, generated } => {
                    Some(format!("{id:08} retired generated={generated}"))
                }
                EngineEvent::StepBatch { .. } => None,
            })
            .collect();
        out.sort_unstable();
        out
    }

    /// Results so far (complete and id-ordered after [`BatchEngine::run`]).
    pub fn results(&self) -> &[GenResult] {
        &self.finished
    }

    /// Lock-step ticks executed.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Currently-mapped KV bytes summed across active sessions (in paged
    /// mode shared pages count toward each mapper; the gate charge is
    /// [`BatchEngine::pager`]'s `charged_bytes`, which counts them once).
    pub fn active_cache_bytes(&self) -> u64 {
        self.active.iter().map(|a| a.cache_nbytes()).sum()
    }

    /// High-water mark of gate-charged cache bytes (≤ the budget by the
    /// gate invariant, in both cache modes).
    pub fn peak_cache_bytes(&self) -> u64 {
        self.gate.peak_bytes()
    }

    /// Most sessions concurrently active after any admission pass — the
    /// numerator of the serve bench's sessions/GB headline.
    pub fn peak_concurrent(&self) -> usize {
        self.peak_active
    }

    /// Aggregated speculation counters over retired sessions (Some only
    /// when [`EngineConfig::speculate`] is set) — the accept-rate and
    /// tokens/round numbers `serve-bench` and `perf_spec` report.
    pub fn spec_stats(&self) -> Option<SpecStats> {
        self.cfg.speculate.map(|_| self.spec_totals)
    }

    /// The pager, in paged mode.
    pub fn pager(&self) -> Option<&Arc<Pager>> {
        self.pager.as_ref()
    }

    /// Pager counters (prefix hits, spills, faults, forks), in paged
    /// mode.
    pub fn pager_stats(&self) -> Option<PagerStats> {
        self.pager.as_ref().map(|p| p.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn engine(budget: Option<u64>, workers: usize) -> BatchEngine {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 1));
        BatchEngine::new(w, EngineConfig { workers, budget, ..EngineConfig::default() })
    }

    fn paged_engine(budget: Option<u64>, paged: PagedConfig) -> BatchEngine {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 1));
        BatchEngine::new(w, EngineConfig { budget, paged: Some(paged), ..EngineConfig::default() })
    }

    #[test]
    fn empty_prompt_fails_cleanly() {
        let mut e = engine(None, 1);
        e.submit(GenRequest { prompt: vec![], max_new: 4 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.as_deref().unwrap().contains("empty prompt"));
    }

    #[test]
    fn zero_max_new_succeeds_without_a_lease() {
        // Budget far below one prompt's cache: a 0-token request must
        // not be charged (or rejected) for cache it will never hold.
        let mut e = engine(Some(16), 1);
        e.submit(GenRequest { prompt: vec![1, 2, 3, 4], max_new: 0 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.is_none());
        assert!(r[0].tokens.is_empty());
        assert_eq!(e.peak_cache_bytes(), 0);
    }

    #[test]
    fn oversized_request_is_rejected_not_wedged() {
        let mut e = engine(Some(64), 1); // budget far below any session cache
        e.submit(GenRequest { prompt: vec![1, 2, 3], max_new: 8 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.as_deref().unwrap().contains("memory budget"));
        assert!(matches!(e.events()[0], EngineEvent::Rejected { id: 0, .. }));
    }

    #[test]
    fn generates_max_new_tokens_per_request() {
        let mut e = engine(None, 2);
        e.submit(GenRequest { prompt: vec![3, 1, 4], max_new: 5 });
        e.submit(GenRequest { prompt: vec![1, 5], max_new: 2 });
        let r = e.run().unwrap().to_vec();
        assert_eq!(r[0].tokens.len(), 5);
        assert_eq!(r[1].tokens.len(), 2);
        assert!(r.iter().all(|x| x.error.is_none()));
        // peak stayed charged and is visible
        assert!(e.peak_cache_bytes() > 0);
        assert_eq!(e.active_cache_bytes(), 0, "all sessions retired");
    }

    #[test]
    fn paged_mode_decodes_the_same_tokens_as_contiguous() {
        let reqs = |e: &mut BatchEngine| {
            e.submit(GenRequest { prompt: vec![3, 1, 4, 1, 5], max_new: 6 });
            e.submit(GenRequest { prompt: vec![2, 7], max_new: 3 });
        };
        let mut oracle = engine(None, 1);
        reqs(&mut oracle);
        let want = oracle.run().unwrap().to_vec();
        for page_positions in [1, 3, 16] {
            let mut e = paged_engine(None, PagedConfig { page_positions, spill: false });
            reqs(&mut e);
            let got = e.run().unwrap().to_vec();
            assert_eq!(got, want, "page size {page_positions} diverged");
            assert_eq!(e.canonical_events(), oracle.canonical_events());
        }
    }

    #[test]
    fn speculative_engine_matches_plain_greedy_decoding() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 1));
        let draft = Arc::new(crate::quant::rtn_quantize_model_packed(&w, 4));
        let reqs = |e: &mut BatchEngine| {
            e.submit(GenRequest { prompt: vec![3, 1, 4, 1, 5], max_new: 7 });
            e.submit(GenRequest { prompt: vec![2, 7], max_new: 3 });
        };
        let mut oracle = BatchEngine::new(Arc::clone(&w), EngineConfig::default());
        reqs(&mut oracle);
        let want = oracle.run().unwrap().to_vec();
        for paged in [None, Some(PagedConfig::default())] {
            let mut e = BatchEngine::new(
                Arc::clone(&w),
                EngineConfig {
                    speculate: Some(SpecConfig { k: 3 }),
                    paged,
                    ..EngineConfig::default()
                },
            );
            e.set_draft(Arc::clone(&draft), FwdOptions::quant(4, 4, false));
            reqs(&mut e);
            let got = e.run().unwrap().to_vec();
            assert_eq!(got, want, "speculative decode diverged (paged={})", paged.is_some());
            assert_eq!(e.canonical_events(), oracle.canonical_events());
            assert_eq!(e.active_cache_bytes(), 0, "both caches of every pair retired");
        }
    }

    #[test]
    fn undrafted_speculation_accepts_everything_and_still_matches() {
        // No set_draft: the pair drafts with the verifier's own weights.
        // Fewer engine steps than tokens proves whole rounds committed.
        let mut plain = engine(None, 1);
        plain.submit(GenRequest { prompt: vec![9, 8, 7], max_new: 9 });
        let want = plain.run().unwrap().to_vec();
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 1));
        let mut e = BatchEngine::new(
            w,
            EngineConfig { speculate: Some(SpecConfig { k: 4 }), ..EngineConfig::default() },
        );
        e.submit(GenRequest { prompt: vec![9, 8, 7], max_new: 9 });
        let got = e.run().unwrap().to_vec();
        assert_eq!(got, want);
        assert!(e.steps() < 9, "all-accept rounds must beat one-token-per-step");
    }

    #[test]
    fn paged_prefix_sharing_kicks_in_for_repeated_prompts() {
        let mut e = paged_engine(None, PagedConfig { page_positions: 2, spill: false });
        let prompt = vec![5i32, 6, 7, 8, 9];
        // Step once so session 0 prefills and registers its prompt pages
        // *before* session 1 is admitted — prefix entries only live as
        // long as the pages they point at.
        e.submit(GenRequest { prompt: prompt.clone(), max_new: 8 });
        e.step().unwrap();
        e.submit(GenRequest { prompt, max_new: 2 });
        let r = e.run().unwrap().to_vec();
        // Greedy decode of the same prompt: session 1's shared-prefix
        // suffix prefill must land on session 0's exact token stream.
        assert_eq!(r[1].tokens[..], r[0].tokens[..2]);
        let stats = e.pager_stats().unwrap();
        assert_eq!(stats.prefix_pages_hit, 2, "(5-1)/2 full pages mapped from the index");
        assert_eq!(stats.cow_forks, 0, "append-only writes never fork");
        assert_eq!(e.pager().unwrap().charged_bytes(), 0, "all pages released");
    }
}
