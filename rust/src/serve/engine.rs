//! Continuous-batching decode engine.
//!
//! [`BatchEngine`] runs many [`DecodeSession`]s in lock step: each
//! [`BatchEngine::step`] first admits pending requests (FIFO) while
//! their full KV-cache footprint fits the `coordinator::budget` gate,
//! then advances every active session by one token on scoped worker
//! threads (`util::threadpool::scoped_try_map`), then retires finished
//! sessions — releasing their cache lease so the next pending request
//! can slide in *between* steps, not at batch boundaries.
//!
//! Determinism follows the `docs/CONCURRENCY.md` contract: every session
//! samples from its own `Pcg64` seeded `seed ⊕ f(id)`, sessions never
//! share mutable state, and [`EngineEvent`]s are recorded only on the
//! engine thread at deterministic points (admission order, then retire
//! scan in admission order after each join). Two runs of the same
//! submissions produce identical token streams and event logs at any
//! worker count — enforced by `rust/tests/serving.rs`.

use super::kv_cache::KvCache;
use super::session::{sample_logits, DecodeSession};
use crate::coordinator::budget::{MemoryGate, OwnedLease};
use crate::model::{FwdOptions, Weights};
use crate::util::prng::Pcg64;
use crate::util::sync::lock_or_poisoned;
use crate::util::threadpool::{scoped_try_map, ThreadPool};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// The KV bytes one request holds for its whole active lifetime: the
/// prompt plus every generated token except the last (sampled but never
/// fed back through the model). The single formula behind the engine's
/// admission charge and the CLI's single-session budget check.
pub fn request_cache_bytes(
    cfg: &crate::model::ModelConfig,
    kv_levels: f32,
    prompt: usize,
    max_new: usize,
) -> u64 {
    KvCache::estimate_nbytes(cfg, kv_levels, prompt + max_new.saturating_sub(1), true)
}

/// One generation request: a prompt and a continuation length.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Prompt token ids (must be non-empty).
    pub prompt: Vec<i32>,
    /// Tokens to generate after the prompt.
    pub max_new: usize,
}

/// Outcome of one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenResult {
    /// Submission id (also the determinism seed offset).
    pub id: usize,
    /// Prompt length the session was fed.
    pub prompt_len: usize,
    /// Generated continuation (empty on error).
    pub tokens: Vec<i32>,
    /// Why the request failed, if it did.
    pub error: Option<String>,
}

/// Engine lifecycle events, recorded in a deterministic order (see the
/// module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineEvent {
    /// A request was admitted: its cache lease is now charged.
    Admitted { id: usize, prompt: usize, cache_bytes: u64 },
    /// A request can never fit the budget and was failed outright.
    Rejected { id: usize, need: u64, budget: u64 },
    /// One lock-step advance of all active sessions.
    StepBatch { step: usize, active: usize },
    /// A session finished and released its cache lease.
    Retired { id: usize, generated: usize },
}

/// Engine knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Forward options every session decodes with.
    pub opt: FwdOptions,
    /// Base sampling seed; session `id` draws from `seed ⊕ f(id)`.
    pub seed: u64,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Worker threads per step (0 = available parallelism).
    pub workers: usize,
    /// KV-cache byte budget across concurrent sessions (None = unlimited).
    pub budget: Option<u64>,
    /// Cap on concurrent sessions (0 = bounded by the budget only).
    pub max_sessions: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            opt: FwdOptions::FP,
            seed: 0,
            temperature: 0.0,
            workers: 0,
            budget: None,
            max_sessions: 0,
        }
    }
}

/// An admitted, in-flight session.
struct Active {
    id: usize,
    session: DecodeSession,
    rng: Pcg64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    max_new: usize,
    last: i32,
    _lease: Option<OwnedLease>,
}

impl Active {
    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    /// Advance by one token: prefill on first touch (continuous batching
    /// admits mid-flight, so fresh sessions prefill while others step).
    fn advance(&mut self, temperature: f32) {
        if self.done() {
            return;
        }
        let row: Vec<f32> = if self.session.positions() == 0 {
            self.session.prefill_last(&self.prompt)
        } else {
            self.session.step(self.last)
        };
        let next = sample_logits(&row, temperature, &mut self.rng) as i32;
        self.generated.push(next);
        self.last = next;
    }
}

/// The continuous-batching engine (see the module docs).
///
/// Submit requests, then either drive [`BatchEngine::step`] yourself or
/// let [`BatchEngine::run`] loop to completion:
///
/// ```no_run
/// use dartquant::model::{ModelConfig, Weights};
/// use dartquant::serve::{BatchEngine, EngineConfig, GenRequest};
/// use std::sync::Arc;
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let weights = Arc::new(Weights::default_synthetic(&cfg, 1));
/// let mut engine = BatchEngine::new(
///     weights,
///     EngineConfig {
///         budget: Some(24 << 20), // scaled single-3090 KV budget
///         ..EngineConfig::default()
///     },
/// );
/// for i in 0..4 {
///     engine.submit(GenRequest { prompt: vec![1, 2, 3 + i], max_new: 16 });
/// }
/// let results = engine.run()?; // admit → lock-step advance → retire
/// assert_eq!(results.len(), 4);
/// # Ok(()) }
/// ```
pub struct BatchEngine {
    weights: Arc<Weights>,
    cfg: EngineConfig,
    gate: Arc<MemoryGate>,
    pending: VecDeque<(usize, GenRequest)>,
    active: Vec<Active>,
    finished: Vec<GenResult>,
    events: Vec<EngineEvent>,
    next_id: usize,
    steps: usize,
}

impl BatchEngine {
    /// An engine over shared weights; the admission gate is sized by
    /// `cfg.budget`.
    pub fn new(weights: Arc<Weights>, cfg: EngineConfig) -> BatchEngine {
        BatchEngine {
            gate: Arc::new(MemoryGate::new(cfg.budget)),
            weights,
            cfg,
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            events: Vec::new(),
            next_id: 0,
            steps: 0,
        }
    }

    /// Queue a request; returns its id. Empty prompts fail immediately;
    /// `max_new == 0` succeeds trivially without ever holding a cache
    /// lease or occupying a step slot.
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        if req.prompt.is_empty() {
            self.finished.push(GenResult {
                id,
                prompt_len: 0,
                tokens: Vec::new(),
                error: Some("empty prompt".to_string()),
            });
        } else if req.max_new == 0 {
            self.finished.push(GenResult {
                id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                error: None,
            });
        } else {
            self.pending.push_back((id, req));
        }
        id
    }

    /// The KV bytes request `req` will hold while active.
    fn cache_bytes(&self, req: &GenRequest) -> u64 {
        request_cache_bytes(
            &self.weights.cfg,
            self.cfg.opt.kv_levels,
            req.prompt.len(),
            req.max_new,
        )
    }

    /// Admit pending requests (FIFO) while their cache bytes fit the gate
    /// and the session cap allows.
    fn admit_pending(&mut self) {
        while let Some((_, req)) = self.pending.front() {
            if self.cfg.max_sessions > 0 && self.active.len() >= self.cfg.max_sessions {
                break;
            }
            let bytes = self.cache_bytes(req);
            match MemoryGate::try_admit_owned(&self.gate, bytes) {
                Err(e) => {
                    let (id, req) = self.pending.pop_front().expect("front exists");
                    self.events.push(EngineEvent::Rejected {
                        id,
                        need: e.need,
                        budget: e.budget,
                    });
                    self.finished.push(GenResult {
                        id,
                        prompt_len: req.prompt.len(),
                        tokens: Vec::new(),
                        error: Some(e.to_string()),
                    });
                }
                Ok(None) => break, // FIFO: wait for a retirement to free bytes
                Ok(Some(lease)) => {
                    let (id, req) = self.pending.pop_front().expect("front exists");
                    self.events.push(EngineEvent::Admitted {
                        id,
                        prompt: req.prompt.len(),
                        cache_bytes: bytes,
                    });
                    self.active.push(Active {
                        id,
                        session: DecodeSession::new(Arc::clone(&self.weights), self.cfg.opt),
                        rng: Pcg64::new(
                            self.cfg.seed ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                        ),
                        prompt: req.prompt,
                        generated: Vec::new(),
                        max_new: req.max_new,
                        last: 0,
                        _lease: lease,
                    });
                }
            }
        }
    }

    /// One engine tick: admit → advance every active session one token in
    /// parallel → retire finished sessions. Returns whether work remains.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        self.admit_pending();
        if self.active.is_empty() {
            // Nothing runnable: admission either drained or rejected
            // every pending request (an empty gate admits anything that
            // can ever fit), so the queue is empty too.
            return Ok(false);
        }
        let workers = if self.cfg.workers == 0 {
            ThreadPool::default_parallelism()
        } else {
            self.cfg.workers
        };
        let temperature = self.cfg.temperature;
        let cells: Vec<Mutex<&mut Active>> = self.active.iter_mut().map(Mutex::new).collect();
        scoped_try_map(workers, &cells, |_, cell| {
            lock_or_poisoned(cell).advance(temperature);
        })
        .map_err(|p| {
            anyhow::anyhow!("decode step panicked in session slot {}: {}", p.index, p.message)
        })?;
        drop(cells);
        self.steps += 1;
        self.events.push(EngineEvent::StepBatch { step: self.steps, active: self.active.len() });
        // Retire in admission order; dropping an Active releases its lease.
        let mut still = Vec::with_capacity(self.active.len());
        for a in self.active.drain(..) {
            if a.done() {
                self.events.push(EngineEvent::Retired { id: a.id, generated: a.generated.len() });
                self.finished.push(GenResult {
                    id: a.id,
                    prompt_len: a.prompt.len(),
                    tokens: a.generated,
                    error: None,
                });
            } else {
                still.push(a);
            }
        }
        self.active = still;
        Ok(!(self.active.is_empty() && self.pending.is_empty()))
    }

    /// Drive [`BatchEngine::step`] until every request finished; results
    /// are ordered by request id.
    pub fn run(&mut self) -> anyhow::Result<&[GenResult]> {
        while self.step()? {}
        self.finished.sort_by_key(|r| r.id);
        Ok(&self.finished)
    }

    /// Event log so far (deterministic across worker counts).
    pub fn events(&self) -> &[EngineEvent] {
        &self.events
    }

    /// Results so far (complete and id-ordered after [`BatchEngine::run`]).
    pub fn results(&self) -> &[GenResult] {
        &self.finished
    }

    /// Lock-step ticks executed.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Currently-resident KV bytes across active sessions.
    pub fn active_cache_bytes(&self) -> u64 {
        self.active.iter().map(|a| a.session.cache_nbytes()).sum()
    }

    /// High-water mark of admitted cache bytes (≤ the budget by the gate
    /// invariant).
    pub fn peak_cache_bytes(&self) -> u64 {
        self.gate.peak_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn engine(budget: Option<u64>, workers: usize) -> BatchEngine {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 1));
        BatchEngine::new(w, EngineConfig { workers, budget, ..EngineConfig::default() })
    }

    #[test]
    fn empty_prompt_fails_cleanly() {
        let mut e = engine(None, 1);
        e.submit(GenRequest { prompt: vec![], max_new: 4 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.as_deref().unwrap().contains("empty prompt"));
    }

    #[test]
    fn zero_max_new_succeeds_without_a_lease() {
        // Budget far below one prompt's cache: a 0-token request must
        // not be charged (or rejected) for cache it will never hold.
        let mut e = engine(Some(16), 1);
        e.submit(GenRequest { prompt: vec![1, 2, 3, 4], max_new: 0 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.is_none());
        assert!(r[0].tokens.is_empty());
        assert_eq!(e.peak_cache_bytes(), 0);
    }

    #[test]
    fn oversized_request_is_rejected_not_wedged() {
        let mut e = engine(Some(64), 1); // budget far below any session cache
        e.submit(GenRequest { prompt: vec![1, 2, 3], max_new: 8 });
        let r = e.run().unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.as_deref().unwrap().contains("memory budget"));
        assert!(matches!(e.events()[0], EngineEvent::Rejected { id: 0, .. }));
    }

    #[test]
    fn generates_max_new_tokens_per_request() {
        let mut e = engine(None, 2);
        e.submit(GenRequest { prompt: vec![3, 1, 4], max_new: 5 });
        e.submit(GenRequest { prompt: vec![1, 5], max_new: 2 });
        let r = e.run().unwrap().to_vec();
        assert_eq!(r[0].tokens.len(), 5);
        assert_eq!(r[1].tokens.len(), 2);
        assert!(r.iter().all(|x| x.error.is_none()));
        // peak stayed charged and is visible
        assert!(e.peak_cache_bytes() > 0);
        assert_eq!(e.active_cache_bytes(), 0, "all sessions retired");
    }
}
