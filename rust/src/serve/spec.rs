//! Self-speculative decoding from the quantization grid.
//!
//! DartQuant's registry emits the *same checkpoint* at several
//! precisions, which is exactly the pairing speculative decoding wants:
//! a [`SpecSession`] wraps two [`DecodeSession`]s over the same weights
//! — an aggressive packed low-bit **draft** (e.g. W4A4) that proposes
//! `k` tokens per round, and a higher-precision **verifier** that scores
//! all `k` proposals in one chunked-prefill-style batched step. Rotation
//! keeps the low-bit token distribution close to the verifier's, which
//! is what makes the cheap draft's proposals worth verifying.
//!
//! # Round protocol
//!
//! Both sessions hold independent [`KvCache`]s (contiguous or paged) and
//! track the same committed token sequence. The invariant between
//! rounds: each cache holds every committed token *except* a short
//! pending tail (the newest committed token, plus — draft side, after an
//! all-accept round — the proposal it never consumed).
//!
//! 1. **Propose.** The draft consumes its pending tail, then steps
//!    `k − 1` more times, sampling (or argmaxing) each of its own logit
//!    rows: proposals `d₁ … d_k`.
//! 2. **Verify.** The verifier prefills `[t, d₁, …, d_k]` in one batched
//!    step — `k + 1` positions, `k + 1` logit rows, each row the
//!    verifier's distribution after consuming the tokens before it.
//!    Greedy mode accepts the longest prefix where the verifier argmax
//!    equals the proposal; sampled mode runs standard rejection sampling
//!    (accept `d_j` with probability `min(1, p_j(d_j)/q_j(d_j))`), with
//!    every random draw taken from the caller's seeded `Pcg64` in
//!    deterministic round order. The round always commits one closing
//!    token: the verifier's own choice at the first disagreement (the
//!    residual sample in sampled mode), or its bonus row after `k`
//!    accepts.
//! 3. **Roll back.** Both caches truncate to the committed length minus
//!    one ([`DecodeSession::truncate`]) — rejected positions vanish from
//!    storage (contiguous rows shrink; whole pages are released), so the
//!    next round starts from a cache bit-identical to one that never saw
//!    the rejected tail.
//!
//! # Correctness contract
//!
//! Greedy speculative decode is **token-for-token identical** to the
//! verifier decoding alone, at any `k`, worker count, shard count, and
//! KV backend: the verifier consumes exactly the committed tokens in
//! order, its chunked scoring prefill produces the same logits as
//! one-token stepping (the chunked-prefill equivalence gated by
//! `rust/tests/serving.rs`), every greedy pick uses the same tie-low
//! argmax as [`sample_logits`], and rollback is bit-exact. Sampled mode
//! preserves the verifier's distribution (standard rejection-sampling
//! argument) and is deterministic per `(seed, k)` — the realized stream
//! legitimately differs across `k`. The gating suite is
//! `rust/tests/spec.rs`; protocol docs live in `docs/SERVING.md`.

use super::session::{sample_logits, DecodeSession};
use crate::util::prng::Pcg64;
use anyhow::{ensure, Result};

/// Speculation knobs — `Copy`, so it rides inside `EngineConfig`
/// (`serve::engine` plumbs the draft weights separately: an
/// `Arc<Weights>` cannot live in a `Copy` config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecConfig {
    /// Draft tokens proposed per round (`k ≥ 1`). Per-round cost is one
    /// draft step per proposal plus one batched verifier step; per-round
    /// yield is `accepted + 1` committed tokens.
    pub k: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { k: 4 }
    }
}

/// Counters a [`SpecSession`] accumulates across rounds — the accept
/// rate and effective tokens/round the `perf_spec` bench reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Speculative rounds run (excludes [`SpecSession::begin`] and the
    /// final-token plain steps).
    pub rounds: u64,
    /// Draft tokens proposed.
    pub proposed: u64,
    /// Draft tokens the verifier accepted.
    pub accepted: u64,
    /// Positions pushed through the draft forward (prefill + steps).
    pub draft_positions: u64,
    /// Positions pushed through the verifier forward.
    pub verify_positions: u64,
    /// Non-speculative verifier steps (the ≤ 1-token headroom path).
    pub plain_steps: u64,
}

impl SpecStats {
    /// Accepted / proposed (0 when nothing was proposed).
    pub fn accept_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Committed tokens per speculative round (`accepted/rounds + 1`):
    /// the effective speedup numerator — a plain decode commits exactly
    /// 1 token per verifier step.
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.accepted as f64 / self.rounds as f64 + 1.0
        }
    }

    /// Fold another session's counters into this one — how the engine
    /// and `serve-bench` aggregate accept rate across retired sessions.
    pub fn merge(&mut self, other: &SpecStats) {
        self.rounds += other.rounds;
        self.proposed += other.proposed;
        self.accepted += other.accepted;
        self.draft_positions += other.draft_positions;
        self.verify_positions += other.verify_positions;
        self.plain_steps += other.plain_steps;
    }
}

/// Softmax probabilities of one logits row at `temperature` — f64, the
/// same max-shifted exponentials [`sample_logits`] integrates, so the
/// rejection-sampling ratios line up with how tokens were drawn.
fn softmax64(row: &[f32], temperature: f32) -> Vec<f64> {
    let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
    let exps: Vec<f64> = row.iter().map(|&v| (((v - mx) / temperature) as f64).exp()).collect();
    let total: f64 = exps.iter().sum();
    exps.iter().map(|e| e / total).collect()
}

/// Sample an index from non-negative weights summing to `total` with one
/// uniform draw `u01 ∈ [0, 1)` (same scan order as [`sample_logits`]).
fn sample_weights(weights: &[f64], total: f64, u01: f64) -> usize {
    let mut u = u01 * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 && w > 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

/// Greedy pick: delegate to [`sample_logits`] at temperature 0 so ties
/// break identically to plain decoding (lowest index). Draws nothing.
fn argmax(row: &[f32]) -> i32 {
    sample_logits(row, 0.0, &mut Pcg64::new(0)) as i32
}

/// Two decode sessions over the same checkpoint at two precisions,
/// committing draft proposals the verifier agrees with (module docs).
///
/// ```no_run
/// use dartquant::model::{FwdOptions, ModelConfig, Weights};
/// use dartquant::quant::rtn_quantize_model_packed;
/// use dartquant::serve::{DecodeSession, SpecSession};
/// use dartquant::util::prng::Pcg64;
/// use std::sync::Arc;
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let verifier_w = Arc::new(Weights::default_synthetic(&cfg, 1));
/// let draft_w = Arc::new(rtn_quantize_model_packed(&verifier_w, 4));
/// let mut spec = SpecSession::new(
///     DecodeSession::new(draft_w, FwdOptions::quant(4, 4, false)), // W4A4 draft
///     DecodeSession::new(verifier_w, FwdOptions::FP),              // fp verifier
///     4,                                                           // k
/// );
/// let out = spec.generate(&[1, 2, 3], 16, 0.0, &mut Pcg64::new(0))?;
/// assert_eq!(out.len(), 16); // token-for-token the verifier's greedy stream
/// # Ok(()) }
/// ```
pub struct SpecSession {
    draft: DecodeSession,
    verifier: DecodeSession,
    k: usize,
    /// Whether this session reserves paged working sets itself
    /// ([`DecodeSession::reserve`] before every chunk). The engine turns
    /// this off: it prepares all selected sessions' pages on the engine
    /// thread before the step, with the full protected set.
    auto_reserve: bool,
    /// Committed tokens the draft cache has not consumed yet (1 between
    /// rounds; 2 after an all-accept round — the unconsumed proposal
    /// plus the bonus token).
    draft_pending: Vec<i32>,
    /// Committed tokens the verifier cache has not consumed yet (always
    /// the single newest token between rounds).
    verifier_pending: Vec<i32>,
    primed: bool,
    stats: SpecStats,
}

impl SpecSession {
    /// Pair `draft` and `verifier` sessions at proposal width `k`. The
    /// sessions must be over the same checkpoint (same vocab and
    /// tokenization) — precisions are free to differ; that is the point.
    pub fn new(draft: DecodeSession, verifier: DecodeSession, k: usize) -> SpecSession {
        assert!(k >= 1, "speculation needs at least one proposal per round");
        SpecSession {
            draft,
            verifier,
            k,
            auto_reserve: true,
            draft_pending: Vec::new(),
            verifier_pending: Vec::new(),
            primed: false,
            stats: SpecStats::default(),
        }
    }

    /// [`SpecSession::new`] with paged reservation delegated to the
    /// caller — the engine variant (see `auto_reserve`). The caller must
    /// make both caches' working sets resident before `begin`/`round`,
    /// sized by [`SpecSession::reserve_hint`].
    pub fn engine_managed(draft: DecodeSession, verifier: DecodeSession, k: usize) -> SpecSession {
        let mut s = SpecSession::new(draft, verifier, k);
        s.auto_reserve = false;
        s
    }

    /// Proposal width.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Whether [`SpecSession::begin`] has run.
    pub fn primed(&self) -> bool {
        self.primed
    }

    /// Verifier cache positions (the committed-prefix length the engine
    /// accounts by).
    pub fn verifier_positions(&self) -> usize {
        self.verifier.positions()
    }

    /// Draft cache positions.
    pub fn draft_positions(&self) -> usize {
        self.draft.positions()
    }

    /// Mapped KV bytes across both caches.
    pub fn cache_nbytes(&self) -> u64 {
        self.draft.cache_nbytes() + self.verifier.cache_nbytes()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> SpecStats {
        self.stats
    }

    /// Positions the next call will append as `(draft, verifier)` —
    /// exact, so an engine-managed caller can pre-allocate pages without
    /// over-reserving. `remaining` is the tokens still to generate
    /// (must be ≥ 1); `prompt_len` sizes the initial prefill.
    pub fn reserve_hint(&self, prompt_len: usize, remaining: usize) -> (usize, usize) {
        if !self.primed {
            return (
                prompt_len - self.draft.positions(),
                prompt_len - self.verifier.positions(),
            );
        }
        let k = self.k.min(remaining.saturating_sub(1));
        if k == 0 {
            (0, self.verifier_pending.len())
        } else {
            (self.draft_pending.len() + k - 1, k + 1)
        }
    }

    /// Prefill both caches with the prompt (each from its own cached
    /// position — a paged verifier admitted onto shared prefix pages
    /// prefills only its suffix) and commit the first token from the
    /// verifier's final-row logits: bit-identical to how a plain session
    /// opens, so speculation changes nothing about token 0.
    pub fn begin(&mut self, prompt: &[i32], temperature: f32, rng: &mut Pcg64) -> Result<i32> {
        assert!(!self.primed, "begin on a primed session");
        assert!(!prompt.is_empty(), "speculation needs a prompt");
        let vfrom = self.verifier.positions();
        if self.auto_reserve {
            ensure!(self.verifier.reserve(prompt.len() - vfrom)?, "verifier pages not resident");
        }
        let row = self.verifier.prefill_last(&prompt[vfrom..]);
        self.stats.verify_positions += (prompt.len() - vfrom) as u64;
        let t = sample_logits(&row, temperature, rng) as i32;
        let dfrom = self.draft.positions();
        if self.auto_reserve {
            ensure!(self.draft.reserve(prompt.len() - dfrom)?, "draft pages not resident");
        }
        self.draft.prefill_last(&prompt[dfrom..]);
        self.stats.draft_positions += (prompt.len() - dfrom) as u64;
        self.draft_pending = vec![t];
        self.verifier_pending = vec![t];
        self.primed = true;
        Ok(t)
    }

    /// One speculative round; returns the 1 ..= `min(k, remaining−1)+1`
    /// tokens it committed (never more than `remaining`). With less than
    /// 2 tokens of headroom the round degrades to one plain verifier
    /// step — proposing past `remaining` would grow the caches past the
    /// admission target for tokens nobody may emit.
    pub fn round(&mut self, temperature: f32, rng: &mut Pcg64, remaining: usize) -> Result<Vec<i32>> {
        assert!(self.primed, "round before begin");
        if remaining == 0 {
            return Ok(Vec::new());
        }
        let k = self.k.min(remaining - 1);
        if k == 0 {
            // Final token: a plain verifier step, exactly like
            // non-speculative decode.
            let chunk = std::mem::take(&mut self.verifier_pending);
            if self.auto_reserve {
                ensure!(self.verifier.reserve(chunk.len())?, "verifier pages not resident");
            }
            let row = self.verifier.prefill_last(&chunk);
            self.stats.verify_positions += chunk.len() as u64;
            self.stats.plain_steps += 1;
            let t = sample_logits(&row, temperature, rng) as i32;
            self.verifier_pending.push(t);
            self.draft_pending.push(t);
            return Ok(vec![t]);
        }

        // 1. Propose: consume the draft's pending tail, then step k − 1
        //    more times; sampled mode keeps each draft distribution q_j
        //    for the acceptance ratios.
        let chunk = std::mem::take(&mut self.draft_pending);
        if self.auto_reserve {
            ensure!(self.draft.reserve(chunk.len() + k - 1)?, "draft pages not resident");
        }
        let mut proposals: Vec<i32> = Vec::with_capacity(k);
        let mut qs: Vec<Vec<f64>> = Vec::new();
        let mut row = self.draft.prefill_last(&chunk);
        self.stats.draft_positions += (chunk.len() + k - 1) as u64;
        for j in 0..k {
            let d = if temperature > 0.0 {
                let q = softmax64(&row, temperature);
                let d = sample_weights(&q, 1.0, rng.uniform());
                qs.push(q);
                d as i32
            } else {
                argmax(&row)
            };
            proposals.push(d);
            if j + 1 < k {
                row = self.draft.step(d);
            }
        }

        // 2. Verify: score the pending token + all k proposals in one
        //    batched prefill; row j is the verifier's distribution after
        //    consuming everything before it.
        let base = self.verifier.positions();
        let vchunk: Vec<i32> = self
            .verifier_pending
            .drain(..)
            .chain(proposals.iter().copied())
            .collect();
        if self.auto_reserve {
            ensure!(self.verifier.reserve(vchunk.len())?, "verifier pages not resident");
        }
        let logits = self.verifier.prefill(&vchunk);
        self.stats.verify_positions += vchunk.len() as u64;

        let mut accepted = 0usize;
        let closing: i32;
        if temperature <= 0.0 {
            // Greedy: longest prefix of exact argmax agreement; the
            // closing token is the verifier's pick at the first
            // disagreement, or its bonus row after k accepts.
            loop {
                let v = argmax(logits.row(accepted));
                if accepted < k && v == proposals[accepted] {
                    accepted += 1;
                } else {
                    closing = v;
                    break;
                }
            }
        } else {
            // Rejection sampling: accept d_j with prob min(1, p/q); on
            // the first rejection sample the residual max(0, p − q).
            // All draws come from `rng` in round order — deterministic
            // per (seed, k).
            let mut rejected_at: Option<usize> = None;
            for j in 0..k {
                let p = softmax64(logits.row(j), temperature);
                let d = proposals[j] as usize;
                if rng.uniform() < (p[d] / qs[j][d]).min(1.0) {
                    accepted += 1;
                } else {
                    rejected_at = Some(j);
                    break;
                }
            }
            closing = match rejected_at {
                Some(j) => {
                    let p = softmax64(logits.row(j), temperature);
                    let res: Vec<f64> =
                        p.iter().zip(&qs[j]).map(|(&pv, &qv)| (pv - qv).max(0.0)).collect();
                    let total: f64 = res.iter().sum();
                    if total > 0.0 {
                        sample_weights(&res, total, rng.uniform()) as i32
                    } else {
                        // p == q exactly: the residual is empty; any
                        // draw from p preserves the distribution.
                        sample_weights(&p, 1.0, rng.uniform()) as i32
                    }
                }
                None => sample_logits(logits.row(k), temperature, rng) as i32,
            };
        }
        self.stats.rounds += 1;
        self.stats.proposed += k as u64;
        self.stats.accepted += accepted as u64;

        // 3. Roll back: both caches keep exactly the committed prefix
        //    minus the (new) pending tail.
        let keep = base + 1 + accepted;
        self.verifier.truncate(keep);
        if accepted == k {
            // All accepted: the draft never consumed its own last
            // proposal — it rides in the pending tail instead of costing
            // a catch-up forward pass.
            self.draft_pending = vec![proposals[k - 1], closing];
        } else {
            self.draft.truncate(keep);
            self.draft_pending = vec![closing];
        }
        self.verifier_pending = vec![closing];

        let mut out = proposals;
        out.truncate(accepted);
        out.push(closing);
        Ok(out)
    }

    /// Generate `max_new` tokens after `prompt`: [`SpecSession::begin`]
    /// once, then rounds until done. Greedy (`temperature <= 0`) output
    /// is token-for-token the verifier's own stream; sampled output is
    /// deterministic per `(seed, k)`.
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(max_new);
        if max_new == 0 {
            return Ok(out);
        }
        out.push(self.begin(prompt, temperature, rng)?);
        while out.len() < max_new {
            let committed = self.round(temperature, rng, max_new - out.len())?;
            out.extend(committed);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{FwdOptions, ModelConfig, Weights};
    use std::sync::Arc;

    fn sessions(seed: u64) -> (DecodeSession, DecodeSession) {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, seed));
        (
            DecodeSession::new(Arc::clone(&w), FwdOptions::quant(4, 4, false)),
            DecodeSession::new(w, FwdOptions::FP),
        )
    }

    fn verifier_only(seed: u64, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let (_, mut v) = sessions(seed);
        let mut rng = Pcg64::new(0);
        let mut tok = sample_logits(&v.prefill_last(prompt), 0.0, &mut rng) as i32;
        let mut out = vec![tok];
        while out.len() < max_new {
            tok = sample_logits(&v.step(tok), 0.0, &mut rng) as i32;
            out.push(tok);
        }
        out
    }

    #[test]
    fn greedy_stream_matches_the_verifier_alone() {
        let prompt = [3i32, 1, 4, 1, 5, 9, 2, 6];
        let want = verifier_only(11, &prompt, 14);
        for k in [1usize, 2, 4, 8] {
            let (d, v) = sessions(11);
            let mut spec = SpecSession::new(d, v, k);
            let got = spec.generate(&prompt, 14, 0.0, &mut Pcg64::new(0)).unwrap();
            assert_eq!(got, want, "k={k} diverged from the verifier-only stream");
        }
    }

    #[test]
    fn identical_precisions_accept_every_proposal() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 5));
        let mk = || DecodeSession::new(Arc::clone(&w), FwdOptions::FP);
        let mut spec = SpecSession::new(mk(), mk(), 4);
        let out = spec.generate(&[7, 2, 9], 13, 0.0, &mut Pcg64::new(0)).unwrap();
        assert_eq!(out.len(), 13);
        let st = spec.stats();
        assert_eq!(st.accepted, st.proposed, "draft ≡ verifier must accept everything");
        assert!(st.proposed > 0);
    }

    #[test]
    fn stats_account_every_committed_token() {
        let (d, v) = sessions(3);
        let mut spec = SpecSession::new(d, v, 3);
        let out = spec.generate(&[1, 2, 3, 4], 17, 0.0, &mut Pcg64::new(0)).unwrap();
        assert_eq!(out.len(), 17);
        let st = spec.stats();
        // begin commits 1; each round commits accepted+1; plain steps 1.
        assert_eq!(1 + st.accepted + st.rounds + st.plain_steps, 17);
        assert!(st.accept_rate() >= 0.0 && st.accept_rate() <= 1.0);
        assert!(st.tokens_per_round() >= 1.0);
    }

    #[test]
    fn round_never_overshoots_remaining() {
        let (d, v) = sessions(9);
        let mut spec = SpecSession::new(d, v, 8);
        let mut rng = Pcg64::new(1);
        spec.begin(&[5, 5, 5], 0.0, &mut rng).unwrap();
        let got = spec.round(0.0, &mut rng, 2).unwrap();
        assert!(got.len() <= 2, "round returned {} tokens for remaining=2", got.len());
        let got = spec.round(0.0, &mut rng, 1).unwrap();
        assert_eq!(got.len(), 1, "1-token headroom must take the plain-step path");
        assert!(spec.stats().plain_steps >= 1);
    }

    #[test]
    fn sampled_mode_is_deterministic_per_seed() {
        let prompt = [2i32, 7, 1, 8];
        for k in [1usize, 4] {
            let run = |seed: u64| {
                let (d, v) = sessions(13);
                SpecSession::new(d, v, k)
                    .generate(&prompt, 12, 0.8, &mut Pcg64::new(seed))
                    .unwrap()
            };
            assert_eq!(run(42), run(42), "k={k}: same seed must replay the same stream");
        }
    }

    #[test]
    fn reserve_hint_is_exact_for_every_phase() {
        let (d, v) = sessions(1);
        let mut spec = SpecSession::new(d, v, 4);
        assert_eq!(spec.reserve_hint(6, 10), (6, 6), "prefill phase: whole prompt");
        let mut rng = Pcg64::new(0);
        spec.begin(&[1, 2, 3, 4, 5, 6], 0.0, &mut rng).unwrap();
        // Pending tails are 1 token each: draft consumes 1 + k − 1,
        // verifier k + 1.
        assert_eq!(spec.reserve_hint(6, 10), (4, 5));
        assert_eq!(spec.reserve_hint(6, 3), (2, 3), "k capped by remaining − 1");
        assert_eq!(spec.reserve_hint(6, 1), (0, 1), "plain-step phase");
        // The hint must cover what the round actually appends.
        let before = (spec.draft_positions(), spec.verifier_positions());
        let hint = spec.reserve_hint(6, 10);
        spec.round(0.0, &mut rng, 10).unwrap();
        // After rollback positions can only have shrunk below the peak,
        // which is exactly before + hint.
        assert!(spec.draft_positions() <= before.0 + hint.0);
        assert!(spec.verifier_positions() <= before.1 + hint.1);
    }
}
