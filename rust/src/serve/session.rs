//! One autoregressive decode session: prefill once, then O(1)-per-token
//! steps against a persistent [`KvCache`].
//!
//! A session drives the same block body as the full-sequence oracle
//! (`model::forward::block_step`), so its logits are bit-identical to
//! `forward_one` in fp32 and land on the same fake-quant grids under
//! activation/KV quantization — the decode-parity contract enforced by
//! `rust/tests/serving.rs`. The cache uses compact code storage whenever
//! the KV grid fits (≤ 8-bit), which is where the serving memory story
//! comes from.

use super::kv_cache::KvCache;
use crate::model::forward::{self, FwdOptions, NoCapture};
use crate::model::Weights;
use crate::tensor::Mat;
use crate::util::prng::Pcg64;
use std::sync::Arc;

/// Incremental decode state over shared read-only weights.
///
/// Prefill the prompt once, then decode one token per
/// [`DecodeSession::step`] — attention stays O(prefix), never
/// O(prefix²):
///
/// ```no_run
/// use dartquant::model::{FwdOptions, ModelConfig, Weights};
/// use dartquant::serve::{sample_logits, DecodeSession};
/// use dartquant::util::prng::Pcg64;
/// use std::sync::Arc;
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let weights = Arc::new(Weights::default_synthetic(&cfg, 1));
/// let mut sess = DecodeSession::new(weights, FwdOptions::quant(4, 4, false));
/// let mut rng = Pcg64::new(0);
/// let last = sess.prefill_last(&[1, 2, 3, 4]); // the prompt, once
/// let mut tok = sample_logits(&last, 0.0, &mut rng) as i32;
/// for _ in 0..8 {
///     let row = sess.step(tok); // O(1) linears + O(prefix) attention
///     tok = sample_logits(&row, 0.0, &mut rng) as i32;
/// }
/// assert_eq!(sess.positions(), 4 + 8);
/// # Ok(()) }
/// ```
pub struct DecodeSession {
    weights: Arc<Weights>,
    opt: FwdOptions,
    cache: KvCache,
}

impl DecodeSession {
    /// A fresh session (no cached positions) on `weights`.
    pub fn new(weights: Arc<Weights>, opt: FwdOptions) -> DecodeSession {
        let cache = KvCache::new(&weights.cfg, opt.kv_levels, true);
        DecodeSession { weights, opt, cache }
    }

    /// A session over a caller-built cache — how the engine mounts a
    /// paged [`KvCache`] (which may already hold shared prefix
    /// positions) instead of the contiguous default.
    pub fn with_cache(weights: Arc<Weights>, opt: FwdOptions, cache: KvCache) -> DecodeSession {
        DecodeSession { weights, opt, cache }
    }

    /// Positions processed so far.
    pub fn positions(&self) -> usize {
        self.cache.positions()
    }

    /// Resident KV-cache bytes across all layers.
    pub fn cache_nbytes(&self) -> u64 {
        self.cache.nbytes()
    }

    /// The forward options this session decodes with.
    pub fn options(&self) -> FwdOptions {
        self.opt
    }

    /// Roll the cache back to `positions` cached positions — the
    /// speculative-decode rejection path (`serve::spec`). The discarded
    /// tail is gone for good: storage shrinks (contiguous) or whole
    /// pages are released (paged), and re-decoding from the kept prefix
    /// is bit-identical to never having cached the tail.
    pub fn truncate(&mut self, positions: usize) {
        self.cache.truncate(positions);
    }

    /// Make the cache writable for `new_positions` more positions — a
    /// no-op for contiguous caches, `Pager::prepare_step` for paged ones
    /// (see [`KvCache::reserve`]). Standalone drivers (CLI single
    /// session, `serve::spec`) call this before each prefill chunk; the
    /// engine prepares its whole step's sessions itself.
    pub fn reserve(&mut self, new_positions: usize) -> anyhow::Result<bool> {
        self.cache.reserve(new_positions)
    }

    /// Run the transformer blocks over `tokens` as the next positions,
    /// extending the cache; returns the new positions' residual rows.
    fn advance_blocks(&mut self, tokens: &[i32]) -> Mat {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let w = Arc::clone(&self.weights);
        let mut x = forward::embed_tokens(&w, tokens);
        for l in 0..w.cfg.n_layers {
            forward::block_step(&w, l, &mut x, self.cache.layer_mut(l), self.opt, &mut NoCapture);
        }
        x
    }

    /// Process `tokens` as the next positions (a prompt, a prompt chunk,
    /// or a single decoded token), extending the cache. Returns the
    /// logits of every processed position (`tokens.len() × vocab`) —
    /// what the decode-parity tests compare position-by-position.
    pub fn prefill(&mut self, tokens: &[i32]) -> Mat {
        let x = self.advance_blocks(tokens);
        forward::head_logits(&self.weights, &x)
    }

    /// [`DecodeSession::prefill`] evaluating the LM head only for the
    /// final position — all generation ever reads. Skips the other
    /// `tokens.len() - 1` vocab-wide head rows on the serving hot path;
    /// the returned row is bit-identical to `prefill`'s last row (the
    /// head is per-row).
    pub fn prefill_last(&mut self, tokens: &[i32]) -> Vec<f32> {
        let x = self.advance_blocks(tokens);
        forward::head_logits_range(&self.weights, &x, x.rows - 1, x.rows).data
    }

    /// Decode one token at the next position; returns its logits row.
    /// Per-step cost is O(prefix) attention + O(1) linears — independent
    /// of how the prefix was fed in.
    pub fn step(&mut self, token: i32) -> Vec<f32> {
        self.prefill_last(&[token])
    }
}

/// Sample a token id from a logits row: greedy argmax at
/// `temperature <= 0` (ties break to the lowest index), softmax sampling
/// at `temperature > 0`. All randomness comes from the caller's
/// generator — the serving engine hands every session its own seeded
/// `Pcg64`, which is what keeps batched decode deterministic at any
/// worker count (the `docs/CONCURRENCY.md` contract).
pub fn sample_logits(row: &[f32], temperature: f32, rng: &mut Pcg64) -> usize {
    if temperature <= 0.0 {
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        return best;
    }
    let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
    let exps: Vec<f64> = row.iter().map(|&v| (((v - mx) / temperature) as f64).exp()).collect();
    let total: f64 = exps.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i;
        }
    }
    row.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn step_returns_last_row_of_prefill() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 3));
        let mut a = DecodeSession::new(Arc::clone(&w), FwdOptions::FP);
        let mut b = DecodeSession::new(w, FwdOptions::FP);
        let toks = [5i32, 9, 2];
        let la = a.prefill(&toks);
        b.prefill(&toks[..2]);
        let row = b.step(toks[2]);
        assert_eq!(la.row(2), &row[..]);
        assert_eq!(a.positions(), 3);
        assert_eq!(b.positions(), 3);
        assert!(a.cache_nbytes() > 0);
    }

    #[test]
    fn prefill_last_matches_full_prefill_tail() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Arc::new(Weights::default_synthetic(&cfg, 4));
        let toks = [7i32, 3, 11, 2];
        let mut full = DecodeSession::new(Arc::clone(&w), FwdOptions::FP);
        let all = full.prefill(&toks);
        let mut fast = DecodeSession::new(w, FwdOptions::FP);
        let last = fast.prefill_last(&toks);
        assert_eq!(all.row(all.rows - 1), &last[..]);
        assert_eq!(fast.positions(), toks.len());
    }

    #[test]
    fn greedy_sampling_breaks_ties_low_and_temperature_is_seeded() {
        let mut rng = Pcg64::new(1);
        assert_eq!(sample_logits(&[1.0, 3.0, 3.0, 0.0], 0.0, &mut rng), 1);
        // Seeded softmax sampling is deterministic per generator stream.
        let row = [0.1f32, 2.0, 1.5, -1.0];
        let a: Vec<usize> = (0..8).map(|_| sample_logits(&row, 0.8, &mut Pcg64::new(7))).collect();
        let b: Vec<usize> = (0..8).map(|_| sample_logits(&row, 0.8, &mut Pcg64::new(7))).collect();
        assert_eq!(a, b);
        // and always lands on a valid index
        assert!(a.iter().all(|&i| i < row.len()));
    }
}
