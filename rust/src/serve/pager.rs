//! Paged KV storage: fixed-size pages, refcounted copy-on-write prefix
//! sharing, and budget-gated eviction to a spill file.
//!
//! A **page** holds `page_positions` positions × one layer's K/V rows in
//! the same [`RowStore`] layout the contiguous cache uses, so every row
//! a page serves is bit-identical to what `model::kv::LayerKv` would
//! have stored. The [`Pager`] owns all pages behind one metadata lock:
//!
//! * a **free list** recycles page slots LIFO (engine-thread-only
//!   mutation keeps it deterministic);
//! * **prefix sharing** — after a session prefills, its *full* prompt
//!   pages are registered under the prompt-prefix tokens; a later
//!   session admitted with the same prefix maps those pages read-only
//!   (refcount + 1) and prefills only its suffix. Sharing whole pages
//!   only (and always leaving ≥ 1 suffix token to prefill) means shared
//!   pages are content-complete and never re-written, which is what
//!   makes the skipped prefill bit-exact — the chunked-prefill
//!   equivalence `rust/tests/serving.rs` already proves;
//! * **copy-on-write** — a write into a page with `refs > 1` is a
//!   contract violation caught by an assert; `prepare_step` forks such
//!   pages (fresh slot, deep copy, refcount swap) *before* the step, so
//!   worker threads only ever write exclusively-owned pages;
//! * **eviction/spill** — under budget pressure (`spill = true`) the
//!   least-recently-prepared unprotected resident page is serialized to
//!   a temp spill file ([`RowStore::to_bytes`]) and its `MemoryGate`
//!   lease released; `prepare_step` faults a session's spilled pages
//!   back in ([`RowStore::from_bytes`]) bit-identically before the
//!   session advances.
//!
//! **Determinism.** All metadata mutation (allocate, free, spill, fault,
//! fork, refcounts, the prefix index) happens on the engine thread, in
//! admission/scheduling order; worker threads only read shared pages and
//! write pages they own exclusively. Recency is a logical tick (one per
//! [`Pager::prepare_step`] call), never wallclock. Maps are `BTreeMap`s.
//! Together that keeps token streams and event logs identical at any
//! worker count, page size, and eviction pressure — the gate in
//! `rust/tests/serving.rs`.
//!
//! `docs/SERVING.md` walks through the page layout, the CoW fork rule,
//! and the eviction/spill lifecycle.

use crate::coordinator::budget::{MemoryGate, OverBudget, OwnedLease};
use crate::model::kv::{KvSlot, RowStore};
use crate::model::ModelConfig;
use crate::tensor::Mat;
use crate::util::sync::lock_or_poisoned;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Page geometry and storage mode — everything needed to size, allocate,
/// and (de)serialize one page.
#[derive(Clone, Debug)]
pub struct PageLayout {
    /// Positions per page (`P`).
    pub page_positions: usize,
    /// Transformer layers (a session maps `n_layers` page tables).
    pub n_layers: usize,
    /// KV heads per layer.
    pub nkv: usize,
    /// Values per K/V row.
    pub hd: usize,
    /// KV fake-quant levels (decides the `RowStore` layout with
    /// `compact`).
    pub levels: f32,
    /// Compact u8 code storage when the grid fits (the serving default).
    pub compact: bool,
}

impl PageLayout {
    /// The layout for one layer of `cfg` at `kv_levels`, `page_positions`
    /// positions per page (compact storage, like every serving cache).
    pub fn for_model(cfg: &ModelConfig, kv_levels: f32, page_positions: usize) -> PageLayout {
        assert!(page_positions >= 1, "page size must be at least one position");
        PageLayout {
            page_positions,
            n_layers: cfg.n_layers,
            nkv: cfg.n_kv_heads,
            hd: cfg.head_dim,
            levels: kv_levels,
            compact: true,
        }
    }

    /// K/V row slots per page side.
    pub fn rows(&self) -> usize {
        self.page_positions * self.nkv
    }

    /// Bytes of one side (K or V) of a page.
    fn side_bytes(&self) -> u64 {
        RowStore::estimate_nbytes(self.rows() as u64, self.hd as u64, self.levels, self.compact)
    }

    /// Bytes one full page holds (K + V) — the unit every gate lease and
    /// spill slot is denominated in. Pages are charged at full capacity
    /// even while partially filled, so accounting never depends on fill
    /// order.
    pub fn page_bytes(&self) -> u64 {
        2 * self.side_bytes()
    }

    /// Pages needed per layer to hold `positions` positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_positions)
    }

    /// Bytes a session caching `positions` positions maps across all
    /// layers — its maximum working set, the paged analogue of
    /// `KvCache::estimate_nbytes`.
    pub fn session_max_bytes(&self, positions: usize) -> u64 {
        self.pages_for(positions) as u64 * self.n_layers as u64 * self.page_bytes()
    }
}

/// Counters the serve bench and CLI report (`prefix_pages_*` feed the
/// prefix-page hit rate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagerStats {
    /// Prompt pages served from the prefix index instead of prefilled.
    pub prefix_pages_hit: u64,
    /// Prompt pages admitted sessions needed in total.
    pub prefix_pages_total: u64,
    /// Pages spilled to the temp file under budget pressure.
    pub spilled_pages: u64,
    /// Spilled pages faulted back in before a step.
    pub faulted_pages: u64,
    /// Copy-on-write forks (defense in depth — unreachable from the
    /// engine's append-only write pattern, see the module docs).
    pub cow_forks: u64,
}

impl PagerStats {
    /// Fraction of prompt pages served from the prefix index (0 when no
    /// session was admitted yet).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_pages_total == 0 {
            0.0
        } else {
            self.prefix_pages_hit as f64 / self.prefix_pages_total as f64
        }
    }
}

/// One page's row contents (K side + V side).
#[derive(Clone, Debug)]
struct PageData {
    k: RowStore,
    v: RowStore,
}

impl PageData {
    fn fresh(layout: &PageLayout) -> PageData {
        PageData {
            k: RowStore::with_rows(layout.levels, layout.compact, layout.rows(), layout.hd),
            v: RowStore::with_rows(layout.levels, layout.compact, layout.rows(), layout.hd),
        }
    }

    /// Serialize K then V — exactly `layout.page_bytes()` long.
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = self.k.to_bytes();
        out.extend_from_slice(&self.v.to_bytes());
        out
    }

    fn from_bytes(layout: &PageLayout, bytes: &[u8]) -> Result<PageData> {
        let side = layout.side_bytes() as usize;
        if bytes.len() != 2 * side {
            bail!("spill page blob is {} bytes, layout needs {}", bytes.len(), 2 * side);
        }
        let decode = |b: &[u8]| {
            RowStore::from_bytes(layout.levels, layout.compact, layout.rows(), layout.hd, b)
        };
        Ok(PageData { k: decode(&bytes[..side])?, v: decode(&bytes[side..])? })
    }
}

/// Fixed-slot spill file: one slot per page, LIFO free-slot reuse,
/// removed from disk on drop. All I/O happens on the engine thread
/// inside `prepare_step`'s `Result` path.
struct SpillFile {
    file: std::fs::File,
    path: std::path::PathBuf,
    slot_bytes: u64,
    slots: usize,
    free: Vec<usize>,
}

/// Disambiguates spill files of pagers created by the same process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Filename prefix of KV spill files in the OS temp dir — shared by
/// [`SpillFile::create`] and the stale-file sweep in [`Pager::new`].
/// Name shape: `dartquant-kv-spill-<pid>-<seq>.bin`.
const SPILL_PREFIX: &str = "dartquant-kv-spill-";

impl SpillFile {
    fn create(slot_bytes: u64) -> Result<SpillFile> {
        let path = std::env::temp_dir().join(format!(
            "{SPILL_PREFIX}{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("create KV spill file {}", path.display()))?;
        Ok(SpillFile { file, path, slot_bytes, slots: 0, free: Vec::new() })
    }

    fn write_page(&mut self, bytes: &[u8]) -> Result<usize> {
        assert_eq!(bytes.len() as u64, self.slot_bytes, "spill slot size");
        let slot = self.free.pop().unwrap_or_else(|| {
            self.slots += 1;
            self.slots - 1
        });
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.slot_bytes))
            .and_then(|_| self.file.write_all(bytes))
            .with_context(|| format!("write KV spill slot {slot} in {}", self.path.display()))?;
        Ok(slot)
    }

    fn read_page(&mut self, slot: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; self.slot_bytes as usize];
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.slot_bytes))
            .and_then(|_| self.file.read_exact(&mut buf))
            .with_context(|| format!("read KV spill slot {slot} in {}", self.path.display()))?;
        Ok(buf)
    }

    fn free_slot(&mut self, slot: usize) {
        self.free.push(slot);
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        // Best effort, but never silent: a leaked spill file costs disk
        // until the next sweep, so report which one failed and why.
        if let Err(e) = std::fs::remove_file(&self.path) {
            eprintln!(
                "warning: failed to remove KV spill file {}: {e}",
                self.path.display()
            );
        }
    }
}

/// Whether `pid` looks like a live process. Uses `/proc/<pid>` where
/// procfs exists; elsewhere assume alive — the sweep must never delete a
/// running process's spill file.
fn process_alive(pid: u32) -> bool {
    if cfg!(target_os = "linux") {
        std::path::Path::new(&format!("/proc/{pid}")).exists()
    } else {
        true
    }
}

/// Best-effort sweep of spill files leaked by dead processes (a crash or
/// `kill -9` never runs [`SpillFile::drop`]). Keyed on the
/// [`SPILL_PREFIX`] name shape; files owned by live pids — including this
/// process — are left alone, and every removal (or failed removal) is
/// reported. Runs at [`Pager::new`], so long-lived servers reclaim the
/// previous crash's disk before they start spilling themselves.
fn sweep_stale_spill_files() {
    let Ok(entries) = std::fs::read_dir(std::env::temp_dir()) else { return };
    let me = std::process::id();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(SPILL_PREFIX) else { continue };
        let Some(pid) = rest.split('-').next().and_then(|p| p.parse::<u32>().ok()) else {
            continue;
        };
        if pid == me || process_alive(pid) {
            continue;
        }
        let path = entry.path();
        match std::fs::remove_file(&path) {
            Ok(()) => eprintln!("note: removed stale KV spill file {}", path.display()),
            Err(e) => eprintln!(
                "warning: failed to remove stale KV spill file {}: {e}",
                path.display()
            ),
        }
    }
}

/// One page slot: contents (when resident), its gate lease, and the
/// sharing/eviction metadata.
struct PageSlot {
    /// Sessions mapping this page (0 = on the free list).
    refs: usize,
    /// Logical tick of the last `prepare_step` that touched it.
    last_use: u64,
    /// Contents — `None` while spilled. Behind its own mutex so workers
    /// of different sessions never serialize on the metadata lock while
    /// reading/writing rows.
    data: Option<Arc<Mutex<PageData>>>,
    /// Gate lease held while resident.
    lease: Option<OwnedLease>,
    /// Spill-file slot while spilled.
    spill_slot: Option<usize>,
}

/// Per-session page tables and position counters.
struct SessionState {
    /// `[layer][page index] → slot` — uniform length across layers
    /// between steps (pages are allocated for every layer up front in
    /// `prepare_step`).
    tables: Vec<Vec<usize>>,
    /// Cached positions per layer (layers advance in sequence inside a
    /// step; equal between steps).
    positions: Vec<usize>,
    /// Most positions this session will ever cache (prompt + max_new - 1)
    /// — the admission commitment.
    target: usize,
    /// Positions served by shared prefix pages at admission.
    shared_positions: usize,
}

impl SessionState {
    fn mapped_pages(&self) -> usize {
        self.tables.first().map(|t| t.len()).unwrap_or(0)
    }
}

/// Everything behind the metadata lock.
struct PagerState {
    slots: Vec<PageSlot>,
    free: Vec<usize>,
    sessions: BTreeMap<u64, SessionState>,
    next_sid: u64,
    /// Prompt-prefix tokens (a whole number of pages) → per-layer page
    /// slots. Weak: holds no refcounts; entries are dropped when a
    /// member page is freed.
    prefix_index: BTreeMap<Vec<i32>, Vec<Vec<usize>>>,
    spill: Option<SpillFile>,
    /// Logical clock: + 1 per `prepare_step` call (engine thread), the
    /// only recency source — wallclock never enters scheduling.
    tick: u64,
    stats: PagerStats,
}

/// The paged KV allocator (module docs). One per `BatchEngine` in paged
/// mode; sessions hold it through [`PagedKv`] handles.
pub struct Pager {
    layout: PageLayout,
    gate: Arc<MemoryGate>,
    spill_enabled: bool,
    state: Mutex<PagerState>,
}

/// Charge one page against the gate; by the pager's admission invariants
/// the lease must be grantable, so both failure shapes are internal
/// errors, surfaced with context instead of unwrapped.
fn charge_page(gate: &Arc<MemoryGate>, bytes: u64) -> Result<OwnedLease> {
    match MemoryGate::try_admit_owned(gate, bytes) {
        Ok(Some(lease)) => Ok(lease),
        Ok(None) => bail!(
            "pager admission invariant violated: no headroom for a {bytes}-byte page \
             (commitment accounting or eviction should have guaranteed it)"
        ),
        Err(e) => Err(e).context("pager page charge"),
    }
}

/// Allocate a fresh zeroed resident page (free-list LIFO, else a new
/// slot); refcount starts at 1.
fn alloc_page(layout: &PageLayout, gate: &Arc<MemoryGate>, st: &mut PagerState) -> Result<usize> {
    let lease = charge_page(gate, layout.page_bytes())?;
    let slot = PageSlot {
        refs: 1,
        last_use: st.tick,
        data: Some(Arc::new(Mutex::new(PageData::fresh(layout)))),
        lease: Some(lease),
        spill_slot: None,
    };
    match st.free.pop() {
        Some(i) => {
            st.slots[i] = slot;
            Ok(i)
        }
        None => {
            st.slots.push(slot);
            Ok(st.slots.len() - 1)
        }
    }
}

/// Return a refcount-0 page to the free list, releasing its lease and
/// spill slot.
fn free_page(st: &mut PagerState, slot: usize) {
    debug_assert_eq!(st.slots[slot].refs, 0, "freeing a mapped page");
    st.slots[slot].data = None;
    st.slots[slot].lease = None;
    if let Some(s) = st.slots[slot].spill_slot.take() {
        if let Some(spill) = st.spill.as_mut() {
            spill.free_slot(s);
        }
    }
    st.free.push(slot);
}

/// Serialize a resident page to the spill file and release its lease.
fn spill_page(layout: &PageLayout, st: &mut PagerState, slot: usize) -> Result<()> {
    let bytes = {
        let data = st.slots[slot].data.as_ref().expect("spilling a resident page");
        lock_or_poisoned(data).to_bytes()
    };
    if st.spill.is_none() {
        st.spill = Some(SpillFile::create(layout.page_bytes())?);
    }
    let sslot = st.spill.as_mut().expect("spill file just ensured").write_page(&bytes)?;
    let sl = &mut st.slots[slot];
    sl.data = None;
    sl.lease = None; // releases the gate bytes
    sl.spill_slot = Some(sslot);
    st.stats.spilled_pages += 1;
    Ok(())
}

/// Fault a spilled page back in, bit-identically, re-charging the gate.
fn fault_page(
    layout: &PageLayout,
    gate: &Arc<MemoryGate>,
    st: &mut PagerState,
    slot: usize,
) -> Result<()> {
    let sslot = st.slots[slot].spill_slot.take().expect("faulting a spilled page");
    let spill = st.spill.as_mut().expect("spilled pages imply a spill file");
    let bytes = spill.read_page(sslot)?;
    spill.free_slot(sslot);
    let data = PageData::from_bytes(layout, &bytes)?;
    let lease = charge_page(gate, layout.page_bytes())?;
    let sl = &mut st.slots[slot];
    sl.data = Some(Arc::new(Mutex::new(data)));
    sl.lease = Some(lease);
    st.stats.faulted_pages += 1;
    Ok(())
}

impl Pager {
    /// A pager for `cfg` at `kv_levels`, `page_positions` positions per
    /// page, charging every resident page against `gate`. `spill`
    /// selects the eviction mode: `true` spills cold pages to a temp
    /// file under pressure; `false` keeps everything resident and makes
    /// admission conservative instead (virtual commitment accounting),
    /// so gate charges can never fail mid-flight.
    pub fn new(
        cfg: &ModelConfig,
        kv_levels: f32,
        page_positions: usize,
        spill: bool,
        gate: Arc<MemoryGate>,
    ) -> Pager {
        sweep_stale_spill_files();
        Pager {
            layout: PageLayout::for_model(cfg, kv_levels, page_positions),
            gate,
            spill_enabled: spill,
            state: Mutex::new(PagerState {
                slots: Vec::new(),
                free: Vec::new(),
                sessions: BTreeMap::new(),
                next_sid: 0,
                prefix_index: BTreeMap::new(),
                spill: None,
                tick: 0,
                stats: PagerStats::default(),
            }),
        }
    }

    /// The page geometry.
    pub fn layout(&self) -> &PageLayout {
        &self.layout
    }

    /// The gate resident pages are charged against.
    pub fn gate(&self) -> &Arc<MemoryGate> {
        &self.gate
    }

    /// Bytes the sessions of `st` can still grow by — every future page
    /// is private (only materialized prefix pages are ever shared), so
    /// this plus the gate's live bytes bounds what the no-spill mode can
    /// ever charge.
    fn future_bytes(&self, st: &PagerState) -> u64 {
        let pb = self.layout.page_bytes();
        st.sessions
            .values()
            .map(|s| {
                (self.layout.pages_for(s.target).saturating_sub(s.mapped_pages())) as u64
                    * self.layout.n_layers as u64
                    * pb
            })
            .sum()
    }

    /// Admit a session that will cache at most `target` positions
    /// (prompt + continuation − 1), mapping the longest registered
    /// full-page prompt prefix read-only. Mirrors
    /// `MemoryGate::try_admit_owned`: `Ok(Some(session id))` on
    /// admission, `Ok(None)` to wait (no-spill mode: commitment doesn't
    /// fit *yet*), `Err` when the session's maximum working set can
    /// never fit the budget.
    pub fn admit(&self, prompt: &[i32], target: usize) -> Result<Option<u64>, OverBudget> {
        self.admit_inner(prompt, target, true)
    }

    /// [`Pager::admit`] without prefix sharing: the session maps no
    /// registered prompt pages, ever. Speculative decoding's draft
    /// sessions use this — their KV rows come from a different-precision
    /// forward, so sharing a verifier session's prefill pages (keyed by
    /// prompt tokens alone) would silently mix precisions. Pair it with
    /// never calling [`Pager::register_prefix`] for the session.
    pub fn admit_private(&self, prompt: &[i32], target: usize) -> Result<Option<u64>, OverBudget> {
        self.admit_inner(prompt, target, false)
    }

    fn admit_inner(
        &self,
        prompt: &[i32],
        target: usize,
        share: bool,
    ) -> Result<Option<u64>, OverBudget> {
        assert!(!prompt.is_empty(), "admission needs a prompt");
        assert!(target >= prompt.len(), "target below prompt length");
        let p = self.layout.page_positions;
        let mut st = lock_or_poisoned(&self.state);
        // Longest registered full-page prefix, always leaving ≥ 1 suffix
        // token for this session to prefill itself.
        let max_shared = if share { (prompt.len() - 1) / p } else { 0 };
        let mut shared = 0;
        for k in (1..=max_shared).rev() {
            if st.prefix_index.contains_key(&prompt[..k * p]) {
                shared = k;
                break;
            }
        }
        let pb = self.layout.page_bytes();
        let nl = self.layout.n_layers as u64;
        let marginal =
            (self.layout.pages_for(target).saturating_sub(shared)) as u64 * nl * pb;
        if let Some(b) = self.gate.budget() {
            let max_ws = self.layout.session_max_bytes(target);
            if max_ws > b {
                return Err(OverBudget { need: max_ws, budget: b });
            }
            if !self.spill_enabled {
                // Virtual commitment: live unique page bytes + everyone's
                // future private growth must stay under budget, so page
                // charges never fail and nothing ever needs eviction.
                let live = self.gate.current_bytes();
                if live + self.future_bytes(&st) + marginal > b {
                    return Ok(None);
                }
            }
        }
        let sid = st.next_sid;
        st.next_sid += 1;
        let mut tables = vec![Vec::new(); self.layout.n_layers];
        if shared > 0 {
            let pages = st.prefix_index[&prompt[..shared * p]].clone();
            for (table, layer_pages) in tables.iter_mut().zip(&pages) {
                for &slot in layer_pages {
                    st.slots[slot].refs += 1;
                    table.push(slot);
                }
            }
        }
        st.stats.prefix_pages_hit += shared as u64;
        st.stats.prefix_pages_total += self.layout.pages_for(prompt.len()) as u64;
        st.sessions.insert(
            sid,
            SessionState {
                tables,
                positions: vec![shared * p; self.layout.n_layers],
                target,
                shared_positions: shared * p,
            },
        );
        Ok(Some(sid))
    }

    /// Positions session `sid` inherited from shared prefix pages.
    pub fn shared_positions(&self, sid: u64) -> usize {
        lock_or_poisoned(&self.state).sessions[&sid].shared_positions
    }

    /// The most bytes session `sid` can newly allocate over its lifetime
    /// (its maximum working set minus the shared pages it mapped at
    /// admission) — what the engine reports as the session's
    /// `cache_bytes` in paged mode.
    pub fn session_marginal_max_bytes(&self, sid: u64) -> u64 {
        let st = lock_or_poisoned(&self.state);
        let s = &st.sessions[&sid];
        let shared_pages = s.shared_positions / self.layout.page_positions;
        (self.layout.pages_for(s.target).saturating_sub(shared_pages)) as u64
            * self.layout.n_layers as u64
            * self.layout.page_bytes()
    }

    /// Make session `sid` runnable for a step that appends
    /// `new_positions` positions: fork any shared page the step would
    /// write (unreachable from the engine's append-only pattern, kept as
    /// defense in depth), evict cold unprotected pages until the
    /// session's faults + forks + fresh pages fit the gate, fault its
    /// spilled pages back in, allocate the fresh pages for every layer,
    /// and touch everything with the new logical tick.
    ///
    /// `protected` lists sessions (including `sid`) whose pages must not
    /// be evicted — the engine passes the sessions already selected for
    /// this step. Returns `Ok(false)` when the working set cannot be
    /// made resident right now (spill mode under pressure): the engine
    /// stops selecting and the session, now least-recently stepped, goes
    /// first next step.
    pub fn prepare_step(&self, sid: u64, new_positions: usize, protected: &[u64]) -> Result<bool> {
        let mut st = lock_or_poisoned(&self.state);
        let st = &mut *st;
        st.tick += 1;
        let now = st.tick;
        let p = self.layout.page_positions;
        let pb = self.layout.page_bytes();
        let s = st.sessions.get(&sid).context("prepare_step: unknown session")?;
        let cur = s.positions.first().copied().unwrap_or(0);
        let have = s.mapped_pages();
        let need_pages = self.layout.pages_for(cur + new_positions);
        assert!(need_pages >= have, "session page table ahead of its positions");
        let fresh_per_layer = need_pages - have;
        // Shared pages this step would write (CoW fork targets).
        let first_written = cur / p;
        let forks: Vec<(usize, usize)> = (0..self.layout.n_layers)
            .flat_map(|l| {
                (first_written..have)
                    .filter(|&pi| st.slots[s.tables[l][pi]].refs > 1)
                    .map(move |pi| (l, pi))
            })
            .collect();
        // Spilled session pages to fault back in.
        let faults: Vec<usize> = s
            .tables
            .iter()
            .flatten()
            .copied()
            .filter(|&slot| st.slots[slot].data.is_none())
            .collect();
        let need_bytes = (forks.len()
            + faults.len()
            + fresh_per_layer * self.layout.n_layers) as u64
            * pb;
        // Make room under a finite budget.
        if let Some(b) = self.gate.budget() {
            let protected_slots: BTreeSet<usize> = protected
                .iter()
                .chain(std::iter::once(&sid))
                .filter_map(|id| st.sessions.get(id))
                .flat_map(|s| s.tables.iter().flatten().copied())
                .collect();
            while b.saturating_sub(self.gate.current_bytes()) < need_bytes {
                if !self.spill_enabled {
                    bail!(
                        "pager commitment invariant violated: session {sid} needs \
                         {need_bytes} bytes with no headroom and spill disabled"
                    );
                }
                // Deterministic LRU victim: least-recently-prepared
                // resident page of an unprotected session (ties break to
                // the lowest slot id).
                let victim = st
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(i, sl)| {
                        sl.refs > 0 && sl.data.is_some() && !protected_slots.contains(i)
                    })
                    .min_by_key(|(i, sl)| (sl.last_use, *i))
                    .map(|(i, _)| i);
                match victim {
                    Some(v) => spill_page(&self.layout, st, v)?,
                    None => return Ok(false), // nothing evictable: defer this session
                }
            }
        }
        for slot in faults {
            fault_page(&self.layout, &self.gate, st, slot)?;
        }
        for (l, pi) in forks {
            let fresh = alloc_page(&self.layout, &self.gate, st)?;
            let old = st.sessions[&sid].tables[l][pi];
            let copied = {
                let src = st.slots[old].data.as_ref().expect("fork source faulted in above");
                lock_or_poisoned(src).clone()
            };
            let dst = st.slots[fresh].data.as_ref().expect("fresh page is resident");
            *lock_or_poisoned(dst) = copied;
            st.slots[old].refs -= 1;
            st.sessions.get_mut(&sid).expect("session exists").tables[l][pi] = fresh;
            st.stats.cow_forks += 1;
        }
        for l in 0..self.layout.n_layers {
            for _ in 0..fresh_per_layer {
                let slot = alloc_page(&self.layout, &self.gate, st)?;
                st.sessions.get_mut(&sid).expect("session exists").tables[l].push(slot);
            }
        }
        let touched: Vec<usize> =
            st.sessions[&sid].tables.iter().flatten().copied().collect();
        for slot in touched {
            st.slots[slot].last_use = now;
        }
        Ok(true)
    }

    /// Register session `sid`'s full prompt pages under every whole-page
    /// prefix of `prompt` (first registration wins — identical prompts
    /// admitted together register once, deterministically). The engine
    /// calls this after the step in which the session prefilled, so
    /// registered pages are content-complete before anyone maps them.
    pub fn register_prefix(&self, sid: u64, prompt: &[i32]) {
        let p = self.layout.page_positions;
        let mut st = lock_or_poisoned(&self.state);
        let Some(s) = st.sessions.get(&sid) else { return };
        let avail = s.positions.first().copied().unwrap_or(0).min(prompt.len());
        let full = (avail / p).min(s.mapped_pages());
        let tables = s.tables.clone();
        for k in 1..=full {
            let key = prompt[..k * p].to_vec();
            if st.prefix_index.contains_key(&key) {
                continue;
            }
            let pages: Vec<Vec<usize>> = tables.iter().map(|t| t[..k].to_vec()).collect();
            st.prefix_index.insert(key, pages);
        }
    }

    /// Release session `sid`: unmap its pages, free the ones nobody else
    /// maps, and drop prefix-index entries that referenced a freed page.
    pub fn release_session(&self, sid: u64) {
        let mut st = lock_or_poisoned(&self.state);
        let st = &mut *st;
        let Some(s) = st.sessions.remove(&sid) else { return };
        let mut freed = BTreeSet::new();
        for table in &s.tables {
            for &slot in table {
                st.slots[slot].refs -= 1;
                if st.slots[slot].refs == 0 {
                    free_page(st, slot);
                    freed.insert(slot);
                }
            }
        }
        if !freed.is_empty() {
            st.prefix_index
                .retain(|_, pages| !pages.iter().flatten().any(|slot| freed.contains(slot)));
        }
    }

    /// Bytes charged against the gate right now — `page_bytes` × unique
    /// resident pages, by construction (shared pages count once).
    pub fn charged_bytes(&self) -> u64 {
        self.gate.current_bytes()
    }

    /// Unique resident (mapped, in-memory) pages.
    pub fn resident_pages(&self) -> usize {
        lock_or_poisoned(&self.state)
            .slots
            .iter()
            .filter(|sl| sl.refs > 0 && sl.data.is_some())
            .count()
    }

    /// Pages session `sid` maps (`× page_bytes` = its
    /// [`PagedKv::nbytes`]), resident or spilled, shared or private.
    pub fn session_pages(&self, sid: u64) -> usize {
        let st = lock_or_poisoned(&self.state);
        st.sessions[&sid].mapped_pages() * self.layout.n_layers
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PagerStats {
        lock_or_poisoned(&self.state).stats
    }

    // ---- row operations (the `KvSlot` backing; worker threads call
    // these during a step, taking the metadata lock only long enough to
    // resolve a page handle) ----

    fn positions(&self, sid: u64, layer: usize) -> usize {
        lock_or_poisoned(&self.state).sessions[&sid].positions[layer]
    }

    fn extend(&self, sid: u64, layer: usize, tn: usize) {
        let mut st = lock_or_poisoned(&self.state);
        let s = st.sessions.get_mut(&sid).expect("extend on a live session");
        let newpos = s.positions[layer] + tn;
        assert!(
            self.layout.pages_for(newpos) <= s.tables[layer].len(),
            "prepare_step must pre-allocate pages before a step extends the cache"
        );
        s.positions[layer] = newpos;
    }

    /// Roll layer `layer` of session `sid` back to `positions` cached
    /// positions (speculative-decode rejection): reset the position
    /// counter, unmap page-table entries past `pages_for(positions)`,
    /// free the unmapped pages nobody else maps, and drop prefix-index
    /// entries that referenced a freed page (the same weak-index rule as
    /// [`Pager::release_session`]). Rows inside the kept last page past
    /// `positions` become unreachable and are overwritten by the next
    /// extend; shared kept pages stay shared and are CoW-forked by
    /// `prepare_step` before any rewrite.
    fn truncate(&self, sid: u64, layer: usize, positions: usize) {
        let keep = self.layout.pages_for(positions);
        let mut st = lock_or_poisoned(&self.state);
        let st = &mut *st;
        let dropped: Vec<usize> = {
            let s = st.sessions.get_mut(&sid).expect("truncate on a live session");
            assert!(positions <= s.positions[layer], "paged truncate beyond cached positions");
            s.positions[layer] = positions;
            let table = &mut s.tables[layer];
            table.split_off(keep.min(table.len()))
        };
        let mut freed = BTreeSet::new();
        for slot in dropped {
            st.slots[slot].refs -= 1;
            if st.slots[slot].refs == 0 {
                free_page(st, slot);
                freed.insert(slot);
            }
        }
        if !freed.is_empty() {
            st.prefix_index
                .retain(|_, pages| !pages.iter().flatten().any(|slot| freed.contains(slot)));
        }
    }

    fn set_row(&self, sid: u64, layer: usize, is_k: bool, pos: usize, head: usize, row: &[f32]) {
        let p = self.layout.page_positions;
        let (page, idx) = {
            let st = lock_or_poisoned(&self.state);
            let s = &st.sessions[&sid];
            debug_assert!(pos < s.positions[layer], "kv position out of range");
            let slot = s.tables[layer][pos / p];
            let sl = &st.slots[slot];
            assert_eq!(sl.refs, 1, "copy-on-write violation: write to a shared page");
            let data = sl.data.as_ref().expect("written page resident (prepare_step)");
            (Arc::clone(data), (pos % p) * self.layout.nkv + head)
        };
        let mut data = lock_or_poisoned(&page);
        let store = if is_k { &mut data.k } else { &mut data.v };
        store.set_row(idx, self.layout.hd, row, self.layout.levels);
    }

    fn head_into(&self, sid: u64, layer: usize, is_k: bool, head: usize, out: &mut Mat) {
        let p = self.layout.page_positions;
        let (pages, positions) = {
            let st = lock_or_poisoned(&self.state);
            let s = &st.sessions[&sid];
            let positions = s.positions[layer];
            let pages: Vec<Arc<Mutex<PageData>>> = s.tables[layer]
                [..self.layout.pages_for(positions)]
                .iter()
                .map(|&slot| {
                    Arc::clone(
                        st.slots[slot].data.as_ref().expect("read page resident (prepare_step)"),
                    )
                })
                .collect();
            (pages, positions)
        };
        assert_eq!(out.shape(), (positions, self.layout.hd), "kv scratch shape");
        for (pi, page) in pages.iter().enumerate() {
            let data = lock_or_poisoned(page);
            let store = if is_k { &data.k } else { &data.v };
            let lo = pi * p;
            for pos in lo..positions.min(lo + p) {
                store.decode_row((pos - lo) * self.layout.nkv + head, self.layout.hd, out.row_mut(pos));
            }
        }
    }
}

/// One layer's paged KV view — the [`KvSlot`] `block_step` drives in
/// paged mode. Every operation resolves through the pager's page tables,
/// so the rows live wherever the pager put them.
pub struct PagedLayerKv {
    pager: Arc<Pager>,
    sid: u64,
    layer: usize,
}

impl KvSlot for PagedLayerKv {
    fn positions(&self) -> usize {
        self.pager.positions(self.sid, self.layer)
    }
    fn extend(&mut self, tn: usize) {
        self.pager.extend(self.sid, self.layer, tn);
    }
    fn truncate(&mut self, positions: usize) {
        self.pager.truncate(self.sid, self.layer, positions);
    }
    fn set_k(&mut self, pos: usize, head: usize, row: &[f32]) {
        self.pager.set_row(self.sid, self.layer, true, pos, head, row);
    }
    fn set_v(&mut self, pos: usize, head: usize, row: &[f32]) {
        self.pager.set_row(self.sid, self.layer, false, pos, head, row);
    }
    fn k_head_into(&self, head: usize, out: &mut Mat) {
        self.pager.head_into(self.sid, self.layer, true, head, out);
    }
    fn v_head_into(&self, head: usize, out: &mut Mat) {
        self.pager.head_into(self.sid, self.layer, false, head, out);
    }
}

/// A session's handle on its paged KV state: one [`PagedLayerKv`] per
/// layer plus RAII release — dropping the handle unmaps the session's
/// pages (freeing unshared ones) on every engine path, error or not.
pub struct PagedKv {
    pager: Arc<Pager>,
    sid: u64,
    layers: Vec<PagedLayerKv>,
}

impl PagedKv {
    /// The handle for pager session `sid` (created by [`Pager::admit`]).
    pub fn new(pager: &Arc<Pager>, sid: u64) -> PagedKv {
        let layers = (0..pager.layout().n_layers)
            .map(|layer| PagedLayerKv { pager: Arc::clone(pager), sid, layer })
            .collect();
        PagedKv { pager: Arc::clone(pager), sid, layers }
    }

    /// The pager session id.
    pub fn sid(&self) -> u64 {
        self.sid
    }

    /// Layer `l`'s slot.
    pub fn layer_mut(&mut self, l: usize) -> &mut PagedLayerKv {
        &mut self.layers[l]
    }

    /// Cached positions (layer 0; identical across layers between steps).
    pub fn positions(&self) -> usize {
        self.pager.positions(self.sid, 0)
    }

    /// Bytes of every page this session maps (full page granularity —
    /// shared pages count toward each mapper here, but only once against
    /// the gate; `rust/tests/serving.rs` pins both sides).
    pub fn nbytes(&self) -> u64 {
        self.pager.session_pages(self.sid) as u64 * self.pager.layout().page_bytes()
    }

    /// Make the session runnable for a step appending `new_positions`
    /// positions (the standalone-session analogue of the engine's
    /// per-step [`Pager::prepare_step`] call; `serve::spec` drives this
    /// before every draft/verifier chunk in paged mode).
    pub fn prepare(&self, new_positions: usize) -> Result<bool> {
        self.pager.prepare_step(self.sid, new_positions, &[self.sid])
    }

    /// Roll every layer back to `positions` cached positions, releasing
    /// whole pages past `pages_for(positions)` ([`KvSlot::truncate`]
    /// contract; speculative-decode rejection).
    pub fn truncate(&mut self, positions: usize) {
        for l in 0..self.layers.len() {
            self.layers[l].truncate(positions);
        }
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.pager.release_session(self.sid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pager(page_positions: usize, spill: bool, budget: Option<u64>) -> Arc<Pager> {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        Arc::new(Pager::new(&cfg, 16.0, page_positions, spill, Arc::new(MemoryGate::new(budget))))
    }

    /// Drive a full prefill of `prompt` through the KvSlot surface the
    /// way the engine would: prepare, then extend + write rows per layer.
    fn prefill(pager: &Arc<Pager>, kv: &mut PagedKv, prompt: usize, seed: f32) {
        let from = kv.positions();
        assert!(pager.prepare_step(kv.sid(), prompt - from, &[kv.sid()]).unwrap());
        let (nl, nkv, hd) = {
            let l = pager.layout();
            (l.n_layers, l.nkv, l.hd)
        };
        for l in 0..nl {
            let slot = kv.layer_mut(l);
            slot.extend(prompt - from);
            for pos in from..prompt {
                for head in 0..nkv {
                    let row: Vec<f32> =
                        (0..hd).map(|i| seed + (pos * nkv + head) as f32 + i as f32 * 0.25).collect();
                    slot.set_k(pos, head, &row);
                    slot.set_v(pos, head, &row);
                }
            }
        }
    }

    fn read_head(kv: &PagedKv, pager: &Arc<Pager>, layer: usize, head: usize) -> Mat {
        let positions = pager.positions(kv.sid(), layer);
        let mut out = Mat::zeros(positions, pager.layout().hd);
        kv.layers[layer].k_head_into(head, &mut out);
        out
    }

    #[test]
    fn stale_spill_files_are_swept_at_construction() {
        // A dead pid's leaked file (crashes skip SpillFile::drop): pid
        // 999_999_999 is far above any Linux pid_max, so it can't be
        // alive. A live pid's file — our own — must survive the sweep.
        let dir = std::env::temp_dir();
        let stale = dir.join(format!("{SPILL_PREFIX}999999999-0.bin"));
        let live = dir.join(format!("{SPILL_PREFIX}{}-987654321.bin", std::process::id()));
        std::fs::write(&stale, b"leaked").unwrap();
        std::fs::write(&live, b"in use").unwrap();
        let _pager = tiny_pager(4, true, None);
        let stale_gone = !stale.exists();
        let live_kept = live.exists();
        let _ = std::fs::remove_file(&stale);
        let _ = std::fs::remove_file(&live);
        assert!(stale_gone, "pre-seeded dead-pid spill file survived the sweep");
        assert!(live_kept, "the sweep removed a live process's spill file");
    }

    #[test]
    fn page_accounting_is_exact() {
        let pager = tiny_pager(4, false, None);
        let sid = pager.admit(&[1, 2, 3, 4, 5], 9).unwrap().unwrap();
        let mut kv = PagedKv::new(&pager, sid);
        prefill(&pager, &mut kv, 5, 0.0);
        let pb = pager.layout().page_bytes();
        // 5 positions at P=4 → 2 pages per layer.
        assert_eq!(pager.layout().pages_for(5), 2);
        assert_eq!(kv.nbytes(), 2 * pager.layout().n_layers as u64 * pb);
        assert_eq!(pager.charged_bytes(), kv.nbytes(), "single session: mapped == charged");
        assert_eq!(pager.resident_pages() as u64 * pb, pager.charged_bytes());
        drop(kv);
        assert_eq!(pager.charged_bytes(), 0, "release frees every page");
        assert_eq!(pager.resident_pages(), 0);
    }

    #[test]
    fn free_list_recycles_slots() {
        let pager = tiny_pager(4, false, None);
        let a = pager.admit(&[1, 2, 3, 4], 4).unwrap().unwrap();
        let mut kv = PagedKv::new(&pager, a);
        prefill(&pager, &mut kv, 4, 0.0);
        let slots_before = lock_or_poisoned(&pager.state).slots.len();
        drop(kv);
        let b = pager.admit(&[9, 8, 7, 6], 4).unwrap().unwrap();
        let mut kv = PagedKv::new(&pager, b);
        prefill(&pager, &mut kv, 4, 1.0);
        assert_eq!(
            lock_or_poisoned(&pager.state).slots.len(),
            slots_before,
            "second session reuses freed slots"
        );
    }

    #[test]
    fn prefix_sharing_maps_full_pages_and_counts_once() {
        let pager = tiny_pager(4, false, None);
        let prompt: Vec<i32> = (0..9).collect(); // 2 full pages + 1 token
        let a = pager.admit(&prompt, 12).unwrap().unwrap();
        let mut kva = PagedKv::new(&pager, a);
        prefill(&pager, &mut kva, 9, 0.0);
        pager.register_prefix(a, &prompt);
        let b = pager.admit(&prompt, 12).unwrap().unwrap();
        assert_eq!(pager.shared_positions(b), 8, "two full pages shared");
        let mut kvb = PagedKv::new(&pager, b);
        // B prefills only its suffix (position 8).
        prefill(&pager, &mut kvb, 9, 0.0);
        for l in [0, pager.layout().n_layers - 1] {
            for head in 0..pager.layout().nkv {
                assert_eq!(
                    read_head(&kva, &pager, l, head).data,
                    read_head(&kvb, &pager, l, head).data,
                    "shared prefix reads bit-identically"
                );
            }
        }
        // Charged bytes: A's full set + only B's private tail page/layer.
        let pb = pager.layout().page_bytes();
        let nl = pager.layout().n_layers as u64;
        assert_eq!(pager.charged_bytes(), (3 + 1) * nl * pb, "shared pages charged once");
        assert_eq!(kva.nbytes(), 3 * nl * pb);
        assert_eq!(kvb.nbytes(), 3 * nl * pb, "B maps 3 pages/layer too");
        let stats = pager.stats();
        assert_eq!(stats.prefix_pages_hit, 2);
        assert_eq!(stats.prefix_pages_total, 6, "3 prompt pages per admission");
        // A retires; shared pages stay alive under B.
        drop(kva);
        assert_eq!(pager.charged_bytes(), 3 * nl * pb);
        drop(kvb);
        assert_eq!(pager.charged_bytes(), 0);
    }

    #[test]
    fn spill_and_fault_roundtrip_bit_identically() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let gate = Arc::new(MemoryGate::new(None));
        let pager = Arc::new(Pager::new(&cfg, 16.0, 4, true, gate));
        let a = pager.admit(&[1, 2, 3, 4, 5, 6], 6).unwrap().unwrap();
        let mut kv = PagedKv::new(&pager, a);
        prefill(&pager, &mut kv, 6, 0.5);
        let before: Vec<Vec<f32>> = (0..pager.layout().n_layers)
            .map(|l| read_head(&kv, &pager, l, 0).data)
            .collect();
        // Spill every page by hand, then fault back via prepare_step.
        {
            let mut st = lock_or_poisoned(&pager.state);
            let st = &mut *st;
            let slots: Vec<usize> =
                st.sessions[&a].tables.iter().flatten().copied().collect();
            for slot in slots {
                spill_page(pager.layout(), st, slot).unwrap();
            }
        }
        assert_eq!(pager.charged_bytes(), 0, "spilled pages release their leases");
        assert!(pager.prepare_step(a, 0, &[a]).unwrap());
        let after: Vec<Vec<f32>> = (0..pager.layout().n_layers)
            .map(|l| read_head(&kv, &pager, l, 0).data)
            .collect();
        let bits = |v: &Vec<Vec<f32>>| -> Vec<Vec<u32>> {
            v.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
        };
        assert_eq!(bits(&before), bits(&after), "fault-in is bit-identical");
        let stats = pager.stats();
        assert_eq!(stats.spilled_pages, 2 * pager.layout().n_layers as u64);
        assert_eq!(stats.faulted_pages, stats.spilled_pages);
    }

    #[test]
    fn cow_fork_isolates_a_diverging_writer() {
        // Forks are unreachable from the engine's append-only writes
        // (shared pages are full by construction); simulate divergence by
        // rolling a sharer's position counter back into its shared page.
        let pager = tiny_pager(4, false, None);
        let prompt: Vec<i32> = (0..5).collect(); // 1 full page + 1 token
        let a = pager.admit(&prompt, 8).unwrap().unwrap();
        let mut kva = PagedKv::new(&pager, a);
        prefill(&pager, &mut kva, 5, 0.0);
        pager.register_prefix(a, &prompt);
        let b = pager.admit(&prompt, 8).unwrap().unwrap();
        assert_eq!(pager.shared_positions(b), 4);
        let nl = pager.layout().n_layers;
        {
            let mut st = lock_or_poisoned(&pager.state);
            let s = st.sessions.get_mut(&b).unwrap();
            s.positions = vec![3; nl]; // diverge inside the shared page
        }
        // Preparing a 1-position step now forks the shared page per layer.
        assert!(pager.prepare_step(b, 1, &[b]).unwrap());
        assert_eq!(pager.stats().cow_forks, nl as u64);
        let kvb = PagedKv::new(&pager, b);
        let a_before = read_head(&kva, &pager, 0, 0).data;
        // B overwrites position 3 in its (now private) copy.
        {
            let mut st = lock_or_poisoned(&pager.state);
            if let Some(s) = st.sessions.get_mut(&b) {
                s.positions = vec![4; nl];
            }
        }
        pager.set_row(b, 0, true, 3, 0, &vec![99.0; pager.layout().hd]);
        assert_eq!(read_head(&kva, &pager, 0, 0).data, a_before, "A's page untouched");
        let b_row = read_head(&kvb, &pager, 0, 0);
        assert!(b_row.row(3).iter().all(|&v| v > 90.0), "B sees its own write");
        drop(kvb);
        drop(kva);
        assert_eq!(pager.charged_bytes(), 0);
    }

    #[test]
    fn truncate_releases_whole_pages_and_keeps_shared_ones() {
        let pager = tiny_pager(4, false, None);
        let pb = pager.layout().page_bytes();
        let nl = pager.layout().n_layers as u64;
        let prompt: Vec<i32> = (0..4).collect(); // exactly 1 full page
        let a = pager.admit(&prompt, 12).unwrap().unwrap();
        let mut kva = PagedKv::new(&pager, a);
        prefill(&pager, &mut kva, 10, 0.0); // 3 pages/layer
        pager.register_prefix(a, &prompt);
        // B's 5-token prompt shares A's full prompt page (admission always
        // leaves ≥ 1 suffix token, so B's own prompt must be longer).
        let b = pager.admit(&[0, 1, 2, 3, 9], 12).unwrap().unwrap();
        assert_eq!(pager.shared_positions(b), 4, "B maps A's prompt page");
        let kvb = PagedKv::new(&pager, b);
        assert_eq!(pager.charged_bytes(), 3 * nl * pb, "shared page charged once");

        // Rolling A back to 5 positions drops its third page per layer
        // (pages_for(5) = 2) but keeps the partially-filled second one.
        let before = read_head(&kva, &pager, 0, 0).data[..5 * pager.layout().hd].to_vec();
        kva.truncate(5);
        assert_eq!(kva.positions(), 5);
        assert_eq!(kva.nbytes(), 2 * nl * pb, "one page released per layer");
        assert_eq!(pager.charged_bytes(), 2 * nl * pb);
        let after = read_head(&kva, &pager, 0, 0);
        assert_eq!(after.shape().0, 5, "reads stop at the truncated length");
        assert_eq!(&after.data[..before.len()], &before[..], "kept rows untouched");

        // Rolling A back to its prompt page leaves the page B shares
        // mapped — truncation unmaps A's reference, it doesn't free a
        // shared page out from under another session.
        kva.truncate(4);
        assert_eq!(pager.charged_bytes(), nl * pb, "only the shared prompt page left");
        drop(kva);
        assert_eq!(pager.charged_bytes(), nl * pb, "shared page survives under B");
        drop(kvb);
        assert_eq!(pager.charged_bytes(), 0);
    }

    #[test]
    fn truncate_frees_pages_for_reuse_and_purges_the_prefix_index() {
        let pager = tiny_pager(4, false, None);
        let prompt: Vec<i32> = (0..8).collect(); // 2 full pages
        let a = pager.admit(&prompt, 12).unwrap().unwrap();
        let mut kva = PagedKv::new(&pager, a);
        prefill(&pager, &mut kva, 8, 0.0);
        pager.register_prefix(a, &prompt);
        assert_eq!(lock_or_poisoned(&pager.state).prefix_index.len(), 2);
        // Truncating into the second prompt page frees it (refs hit 0) and
        // must drop the index entry that referenced it — a later admission
        // may only share pages that still exist.
        kva.truncate(4);
        {
            let st = lock_or_poisoned(&pager.state);
            assert_eq!(st.prefix_index.len(), 1, "entry referencing the freed page dropped");
            assert_eq!(st.free.len(), pager.layout().n_layers, "freed slots recycled");
        }
        // A 12-token prompt whose first 8 tokens match: without the purge
        // it would map the freed 8-token entry's dead pages.
        let long: Vec<i32> = (0..12).collect();
        let b = pager.admit(&long, 12).unwrap().unwrap();
        assert_eq!(pager.shared_positions(b), 4, "only the surviving page is shared");
        pager.release_session(b);
    }

    #[test]
    fn admit_private_never_maps_registered_prefix_pages() {
        let pager = tiny_pager(4, false, None);
        let prompt: Vec<i32> = (0..9).collect(); // 2 full pages + 1 token
        let a = pager.admit(&prompt, 12).unwrap().unwrap();
        let mut kva = PagedKv::new(&pager, a);
        prefill(&pager, &mut kva, 9, 0.0);
        pager.register_prefix(a, &prompt);
        // A sharing admit maps both full prompt pages; a private admit of
        // the *same* prompt maps none — its rows will come from a
        // different-precision forward (the speculative draft), and mixing
        // grids through the index would corrupt whoever shared them.
        let shared = pager.admit(&prompt, 12).unwrap().unwrap();
        assert_eq!(pager.shared_positions(shared), 8);
        let private = pager.admit_private(&prompt, 12).unwrap().unwrap();
        assert_eq!(pager.shared_positions(private), 0, "private sessions start cold");
        assert_eq!(pager.session_pages(private), 0, "no pages mapped at private admission");
        pager.release_session(shared);
        pager.release_session(private);
        // The private release touched nothing shared: A still reads back.
        assert_eq!(pager.positions(a, 0), 9);
    }

    #[test]
    fn no_spill_admission_waits_instead_of_overcommitting() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let gate = Arc::new(MemoryGate::new(None));
        let probe = Pager::new(&cfg, 16.0, 4, false, gate);
        let one_session = probe.layout().session_max_bytes(8);
        // Budget fits one session's full commitment, not two.
        let gate = Arc::new(MemoryGate::new(Some(one_session + one_session / 2)));
        let pager = Arc::new(Pager::new(&cfg, 16.0, 4, false, gate));
        let a = pager.admit(&[1, 2, 3, 4], 8).unwrap().unwrap();
        let kva = PagedKv::new(&pager, a);
        assert!(pager.admit(&[5, 6, 7, 8], 8).unwrap().is_none(), "second must wait");
        drop(kva);
        assert!(pager.admit(&[5, 6, 7, 8], 8).unwrap().is_some(), "fits after release");
        // And a session that can never fit is rejected outright.
        assert!(pager.admit(&[1; 64], 64).is_err());
    }
}
