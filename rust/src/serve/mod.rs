//! Autoregressive serving: KV-cached incremental decode, paged KV
//! storage with prefix sharing, and continuous batching on top of the
//! shared `model::forward::block_step` block body.
//!
//! Five pieces (see `docs/SERVING.md` for the contracts):
//!
//! * [`kv_cache`] — [`KvCache`]: a session's per-layer KV state (fp32 or
//!   u8 codes at ≤ 8-bit KV settings, bit-identical to the full-sequence
//!   oracle's fake-quant values either way) with two backends —
//!   contiguous `model::kv::LayerKv`s (the parity oracle) or paged
//!   handles — plus the exact byte accounting the engine charges the
//!   budget gate.
//! * [`pager`] — [`Pager`]: fixed-size KV pages behind a free list,
//!   refcounted copy-on-write prefix sharing (identical prompt prefixes
//!   map the same prefill pages), and budget-gated LRU eviction to a
//!   temp spill file, faulting back bit-identically.
//! * [`session`] — [`DecodeSession`]: prefill once, then O(1)-per-token
//!   steps (attention stays O(prefix); every full-sequence recompute the
//!   pre-serving code did was O(prefix²)).
//! * [`engine`] — [`BatchEngine`]: continuous batching with admission
//!   charged against the `coordinator::budget` gate — full-lifetime
//!   reservation (contiguous) or page-granular growth (paged) — and
//!   per-session seeded sampling, deterministic at any worker count,
//!   page size, and eviction pressure.
//! * [`spec`] — [`SpecSession`]: self-speculative decoding from the
//!   quantization grid — a packed low-bit draft proposes `k` tokens per
//!   round, a higher-precision verifier over the *same* checkpoint
//!   scores all of them in one batched prefill, and rejected positions
//!   are rolled back bit-exactly; greedy output is token-for-token the
//!   verifier's own stream.
//!
//! CLI entry points: `dartquant generate` (`--speculate`),
//! `dartquant serve-bench`; throughput numbers come from the
//! `perf_decode`, `perf_serve`, and `perf_spec` benches. Parity with the
//! full-sequence forward and the paged-vs-contiguous bit-identity gate
//! are enforced by `rust/tests/serving.rs`; the speculative equality
//! gate is `rust/tests/spec.rs`.

pub mod engine;
pub mod kv_cache;
pub mod pager;
pub mod session;
pub mod spec;

pub use engine::{
    request_cache_bytes, BatchEngine, EngineConfig, EngineEvent, GenRequest, GenResult,
    PagedConfig,
};
pub use kv_cache::{KvCache, KvSlot, LayerKv};
pub use pager::{PageLayout, PagedKv, Pager, PagerStats};
pub use session::{sample_logits, DecodeSession};
pub use spec::{SpecConfig, SpecSession, SpecStats};
