//! Autoregressive serving: KV-cached incremental decode and continuous
//! batching on top of the shared `model::forward::block_step` block body.
//!
//! Three pieces (see `docs/SERVING.md` for the contracts):
//!
//! * [`kv_cache`] — [`KvCache`]: one `model::kv::LayerKv` per layer
//!   (fp32 or u8 codes at ≤ 8-bit KV settings, bit-identical to the
//!   full-sequence oracle's fake-quant values either way) plus the
//!   exact byte accounting the engine charges the budget gate.
//! * [`session`] — [`DecodeSession`]: prefill once, then O(1)-per-token
//!   steps (attention stays O(prefix); every full-sequence recompute the
//!   pre-serving code did was O(prefix²)).
//! * [`engine`] — [`BatchEngine`]: continuous batching with admission
//!   charged against the `coordinator::budget` gate and per-session
//!   seeded sampling, deterministic at any worker count.
//!
//! CLI entry points: `dartquant generate`, `dartquant serve-bench`;
//! throughput numbers come from the `perf_decode` bench. Parity with the
//! full-sequence forward is enforced by `rust/tests/serving.rs`.

pub mod engine;
pub mod kv_cache;
pub mod session;

pub use engine::{
    request_cache_bytes, BatchEngine, EngineConfig, EngineEvent, GenRequest, GenResult,
};
pub use kv_cache::{KvCache, LayerKv};
pub use session::{sample_logits, DecodeSession};
