//! `dqlint` — walk the tree and enforce the determinism / panic-safety
//! lints (see `docs/LINTS.md` and [`dartquant::lint`]).
//!
//! ```text
//! dqlint [--json] [--root <dir>] [path ...]
//! ```
//!
//! With no paths, scans `rust/src` and `rust/benches` under `--root`
//! (default: the current directory). Paths may be files or directories.
//! Exits 0 when clean, 1 on any error-severity diagnostic, 2 on usage
//! or I/O errors — so `set -e` in `ci.sh` fails the build on a hit.

use dartquant::lint::{self, Severity};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    json: bool,
    root: PathBuf,
    paths: Vec<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut json = false;
    let mut root = PathBuf::from(".");
    let mut paths = Vec::new();
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => {
                root = PathBuf::from(
                    argv.next().ok_or_else(|| "--root requires a directory".to_string())?,
                );
            }
            "--help" | "-h" => {
                return Err("usage: dqlint [--json] [--root <dir>] [path ...]".to_string())
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag {other:?} (try --help)"))
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    Ok(Args { json, root, paths })
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    let roots: Vec<PathBuf> = if args.paths.is_empty() {
        lint::DEFAULT_ROOTS.iter().map(|r| args.root.join(r)).collect()
    } else {
        args.paths.clone()
    };
    let (diags, files) = match lint::scan_paths(&roots) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("dqlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    if args.json {
        println!("{}", lint::report_json(&diags, files));
    } else {
        for d in &diags {
            println!("{d}");
        }
        if diags.is_empty() {
            println!("dqlint: clean ({files} files scanned)");
        } else {
            println!(
                "dqlint: {} diagnostic{} ({errors} error{}) across {files} files",
                diags.len(),
                if diags.len() == 1 { "" } else { "s" },
                if errors == 1 { "" } else { "s" },
            );
        }
    }
    if errors > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
