//! Host-side tensor values crossing the PJRT boundary, plus conversions to
//! and from `xla::Literal`.

use super::manifest::{DType, TensorSpec};
use crate::tensor::Mat;
use anyhow::{bail, Result};

/// An N-dimensional host tensor (f32 or i32).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar(x: f32) -> Value {
        Value::F32 { shape: vec![], data: vec![x] }
    }

    pub fn zeros(shape: &[usize]) -> Value {
        Value::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_f32(shape: Vec<usize>, data: Vec<f32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Value::F32 { shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Value::I32 { shape, data }
    }

    pub fn from_mat(m: &Mat) -> Value {
        Value::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn shape(&self) -> Vec<usize> {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape.clone(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32 { .. } => DType::F32,
            Value::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32 { data, .. } => data.len(),
            Value::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scalar extraction (errors on non-1-element tensors).
    pub fn to_scalar(&self) -> Result<f32> {
        match self {
            Value::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            Value::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f32),
            v => bail!("expected scalar, got shape {:?}", v.shape()),
        }
    }

    /// View as a 2-D matrix (errors unless rank ≤ 2; rank-1 becomes 1×n).
    pub fn to_mat(&self) -> Result<Mat> {
        match self {
            Value::F32 { shape, data } => match shape.len() {
                0 => Ok(Mat::from_vec(1, 1, data.clone())),
                1 => Ok(Mat::from_vec(1, shape[0], data.clone())),
                2 => Ok(Mat::from_vec(shape[0], shape[1], data.clone())),
                r => bail!("cannot view rank-{r} tensor as Mat"),
            },
            Value::I32 { .. } => bail!("i32 tensor cannot be viewed as f32 Mat"),
        }
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            Value::I32 { .. } => bail!("expected f32 tensor"),
        }
    }

    pub fn nbytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

/// Convert to an `xla::Literal` for execution.
pub fn to_literal(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64>;
    let lit = match v {
        Value::F32 { shape, data } => {
            dims = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data)
        }
        Value::I32 { shape, data } => {
            dims = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data)
        }
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshaping literal to {dims:?}: {e:?}"))
}

/// Convert an output literal back to a host value, checked against the
/// manifest output spec.
pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
    let count = lit.element_count();
    if count != spec.elements() {
        bail!(
            "output {:?}: literal has {count} elements, manifest shape {:?} wants {}",
            spec.name,
            spec.shape,
            spec.elements()
        );
    }
    match spec.dtype {
        DType::F32 => {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading f32 output {:?}: {e:?}", spec.name))?;
            Ok(Value::F32 { shape: spec.shape.clone(), data })
        }
        DType::I32 => {
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("reading i32 output {:?}: {e:?}", spec.name))?;
            Ok(Value::I32 { shape: spec.shape.clone(), data })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_dtype() {
        let v = Value::from_f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(v.shape(), vec![2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        assert_eq!(v.nbytes(), 24);
        let i = Value::from_i32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(i.dtype(), DType::I32);
    }

    #[test]
    fn mat_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let v = Value::from_mat(&m);
        assert_eq!(v.to_mat().unwrap(), m);
    }

    #[test]
    fn scalar_conversions() {
        assert_eq!(Value::scalar(2.5).to_scalar().unwrap(), 2.5);
        assert!(Value::zeros(&[2, 2]).to_scalar().is_err());
        assert_eq!(Value::from_i32(vec![], vec![7]).to_scalar().unwrap(), 7.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_f32_checks_shape() {
        let _ = Value::from_f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn rank_checks() {
        let v = Value::from_f32(vec![2, 2, 2], vec![0.0; 8]);
        assert!(v.to_mat().is_err());
        let r1 = Value::from_f32(vec![5], vec![1.0; 5]);
        assert_eq!(r1.to_mat().unwrap().shape(), (1, 5));
    }
}
