//! Artifact manifest: the typed contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json` next to the HLO text files) and
//! the rust runtime (which validates every call against it).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor crossing the boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "i32" | "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?} (expected f32/i32)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// Shape + dtype + name of one input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get_str("name").unwrap_or("?").to_string();
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("tensor spec missing shape")?
            .iter()
            .map(|d| d.as_usize().context("non-integer dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.get_str("dtype").unwrap_or("f32"))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Optional FLOP estimate recorded at lowering time.
    pub flops: u64,
    /// Free-form tags (e.g. {"objective": "whip", "n": "256"}).
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    /// Check a runtime argument list against the declared signature.
    pub fn validate_inputs(&self, inputs: &[super::Value]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: got {} inputs, signature wants {} ({})",
                self.name,
                inputs.len(),
                self.inputs.len(),
                self.inputs.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
            );
        }
        for (v, spec) in inputs.iter().zip(&self.inputs) {
            if v.shape() != spec.shape {
                bail!(
                    "{}: input {:?} shape {:?} != expected {:?}",
                    self.name,
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "{}: input {:?} dtype {} != expected {}",
                    self.name,
                    spec.name,
                    v.dtype().name(),
                    spec.dtype.name()
                );
            }
        }
        Ok(())
    }
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest is not valid JSON")?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .context("manifest missing \"artifacts\" object")?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                spec.get(key)
                    .and_then(|v| v.as_arr())
                    .with_context(|| format!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut meta = BTreeMap::new();
            if let Some(m) = spec.get("meta").and_then(|m| m.as_obj()) {
                for (k, v) in m {
                    let s = match v {
                        Json::Str(s) => s.clone(),
                        other => other.to_string(),
                    };
                    meta.insert(k.clone(), s);
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: spec
                        .get_str("file")
                        .map(str::to_string)
                        .unwrap_or_else(|| format!("{name}.hlo.txt")),
                    inputs: parse_tensors("inputs")?,
                    outputs: parse_tensors("outputs")?,
                    flops: spec.get_f64("flops").unwrap_or(0.0) as u64,
                    meta,
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// All artifacts whose meta matches every given (key, value) pair —
    /// e.g. find the calib step for a given objective and hidden size.
    pub fn find_by_meta(&self, pairs: &[(&str, &str)]) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| pairs.iter().all(|(k, v)| a.meta.get(*k).map(|s| s == v).unwrap_or(false)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Value;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "calib_whip_n8": {
          "file": "calib_whip_n8.hlo.txt",
          "inputs": [
            {"name": "Z", "shape": [8, 8], "dtype": "f32"},
            {"name": "lr", "shape": [], "dtype": "f32"}
          ],
          "outputs": [{"name": "Z_new", "shape": [8, 8], "dtype": "f32"}],
          "flops": 1234,
          "meta": {"objective": "whip", "n": 8}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("calib_whip_n8").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![8, 8]);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.flops, 1234);
        assert_eq!(a.meta.get("objective").unwrap(), "whip");
        assert_eq!(a.meta.get("n").unwrap(), "8");
    }

    #[test]
    fn find_by_meta_matches() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.find_by_meta(&[("objective", "whip"), ("n", "8")]).len(), 1);
        assert!(m.find_by_meta(&[("objective", "kurtosis")]).is_empty());
    }

    #[test]
    fn validate_inputs_catches_mismatches() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("calib_whip_n8").unwrap();
        let good = vec![Value::zeros(&[8, 8]), Value::scalar(0.1)];
        assert!(a.validate_inputs(&good).is_ok());
        // wrong arity
        assert!(a.validate_inputs(&good[..1]).is_err());
        // wrong shape
        let bad = vec![Value::zeros(&[4, 8]), Value::scalar(0.1)];
        assert!(a.validate_inputs(&bad).is_err());
        // wrong dtype
        let bad = vec![Value::zeros(&[8, 8]), Value::from_i32(vec![], vec![1])];
        assert!(a.validate_inputs(&bad).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"x": {"inputs": 3}}}"#).is_err());
    }
}
