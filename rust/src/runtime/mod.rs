//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them on the XLA CPU client.
//!
//! This is the only bridge between L3 (rust) and L2/L1 (jax/pallas): HLO
//! **text** files plus a JSON manifest describing each entry point's typed
//! input/output signature. Python never runs at request time.
//!
//! Threading note: the `xla` crate's `PjRtClient` is `Rc`-based (not Send),
//! so a `Runtime` is bound to the thread that created it. The coordinator
//! gives each worker thread its own `Runtime`; XLA's internal thread pool
//! still parallelizes individual executions.

mod manifest;
mod value;

pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use value::Value;

use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A PJRT runtime bound to an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

/// A compiled entry point with its typed signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Runtime {
    /// Open `dir` (default: `artifacts/`), reading `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::load(&manifest_path)
            .with_context(|| format!("loading {manifest_path:?} — run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(BTreeMap::new()) })
    }

    /// The default artifacts directory: `$DARTQUANT_ARTIFACTS` or
    /// `artifacts/` found by walking up from the current directory.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("DARTQUANT_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// True if a usable artifacts directory exists (tests use this to skip
    /// gracefully before `make artifacts`).
    pub fn artifacts_available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an entry point by manifest name; compiled executables
    /// are cached for the lifetime of the runtime.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let spec = self
            .manifest
            .get(name)
            .with_context(|| {
                format!(
                    "artifact {name:?} not in manifest (have: {})",
                    self.manifest.names().join(", ")
                )
            })?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(Executable { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Convenience: load-and-run in one call.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.load(name)?.run(inputs)
    }
}

impl Executable {
    /// Execute with typed validation against the manifest signature.
    /// Outputs are decomposed from the jax `return_tuple=True` tuple.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        self.spec.validate_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(value::to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.name))?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("no output buffers from {}", self.spec.name))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output of {}: {e:?}", self.spec.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling output of {}: {e:?}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{} returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| value::from_literal(&lit, spec))
            .collect()
    }

    /// Rough FLOP estimate recorded by the lowering (0 if absent); used by
    /// the perf accounting in EXPERIMENTS.md §Perf.
    pub fn flops_estimate(&self) -> u64 {
        self.spec.flops
    }
}

thread_local! {
    static THREAD_RT: RefCell<Option<(PathBuf, Rc<Runtime>)>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's cached `Runtime` for `dir` (creating it on
/// first use). The `xla` crate's client is `Rc`-based (not Send), so the
/// coordinator's worker threads each own one runtime through this hook.
pub fn with_thread_runtime<R>(dir: &Path, f: impl FnOnce(&Runtime) -> R) -> Result<R> {
    THREAD_RT.with(|cell| {
        let mut slot = cell.borrow_mut();
        let needs_new = match &*slot {
            Some((d, _)) => d != dir,
            None => true,
        };
        if needs_new {
            *slot = Some((dir.to_path_buf(), Rc::new(Runtime::open(dir)?)));
        }
        let rt = Rc::clone(&slot.as_ref().unwrap().1);
        drop(slot);
        Ok(f(&rt))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_errors_helpfully() {
        let Err(err) = Runtime::open("/nonexistent-dartquant") else {
            panic!("expected error")
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "got: {msg}");
    }
}
