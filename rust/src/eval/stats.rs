//! Activation statistics: outlier counts, quantization error, histograms
//! and kurtosis — the measurements behind Figures 2/3/6/10/11 and Table 19.

use crate::tensor::Mat;

/// Count of elements with |x| > tau — Fig 3a / Fig 10's outlier metric.
pub fn count_outliers(x: &Mat, tau: f32) -> usize {
    x.data.iter().filter(|v| v.abs() > tau).count()
}

/// The paper sets τ from the unrotated activations; we use a high quantile
/// so τ tracks each model's scale (Fig 3 protocol).
pub fn outlier_threshold(x: &Mat, quantile: f64) -> f32 {
    let mut mags: Vec<f32> = x.data.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.total_cmp(b));
    let idx = ((mags.len() - 1) as f64 * quantile) as usize;
    mags[idx]
}

/// Mean per-token asymmetric fake-quant MSE — Fig 3b's quantization error.
pub fn quant_error(x: &Mat, bits: u8) -> f64 {
    let levels = (1u32 << bits) as f32;
    let mut total = 0f64;
    for i in 0..x.rows {
        let row = x.row(i);
        let (mut mn, mut mx) = (f32::MAX, f32::MIN);
        for &v in row {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let scale = (mx - mn) / (levels - 1.0);
        if scale <= 0.0 {
            continue;
        }
        for &v in row {
            let q = ((v - mn) / scale).round() * scale + mn;
            total += ((q - v) as f64).powi(2);
        }
    }
    total / x.data.len() as f64
}

/// Histogram of all elements over [lo, hi] with `bins` buckets (+ under/
/// overflow folded into the edge buckets) — Figures 2/6/11.
pub fn histogram(x: &Mat, lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &v in &x.data {
        let b = if v <= lo {
            0
        } else if v >= hi {
            bins - 1
        } else {
            (((v - lo) / w) as usize).min(bins - 1)
        };
        h[b] += 1;
    }
    h
}

/// Render a histogram as ASCII rows (bench output for the figure benches).
pub fn render_histogram(h: &[usize], lo: f32, hi: f32, width: usize) -> String {
    let max = *h.iter().max().unwrap_or(&1) as f64;
    let bins = h.len();
    let mut out = String::new();
    for (i, &c) in h.iter().enumerate() {
        let a = lo + (hi - lo) * i as f32 / bins as f32;
        let bar = "#".repeat(((c as f64 / max) * width as f64).round() as usize);
        out.push_str(&format!("{a:>8.2} | {bar} {c}\n"));
    }
    out
}

/// Activation summary for Table 19.
#[derive(Clone, Copy, Debug)]
pub struct ActivationStats {
    pub mean: f64,
    pub variance: f64,
    pub kurtosis: f64,
    pub max_abs: f64,
}

pub fn activation_stats(x: &Mat) -> ActivationStats {
    let xs: Vec<f64> = x.data.iter().map(|&v| v as f64).collect();
    ActivationStats {
        mean: crate::util::mean(&xs),
        variance: crate::util::variance(&xs),
        kurtosis: crate::util::excess_kurtosis(&xs),
        max_abs: xs.iter().fold(0.0, |a, b| a.max(b.abs())),
    }
}

/// Normalize rows to unit RMS (the paper reports stats of RMSNorm-ed
/// activations: mean ~0, var ~1, high kurtosis).
pub fn normalize_rows_rms(x: &Mat) -> Mat {
    let mut out = x.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let rms = (row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32).sqrt();
        if rms > 0.0 {
            for v in row.iter_mut() {
                *v /= rms;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn spiky(rows: usize, cols: usize) -> Mat {
        let mut rng = Pcg64::new(1);
        let mut m = Mat::from_fn(rows, cols, |_, _| rng.laplace(1.0));
        for i in 0..rows {
            *m.at_mut(i, 3) *= 30.0;
        }
        m
    }

    #[test]
    fn outlier_count_and_threshold() {
        let m = spiky(64, 64);
        let tau = outlier_threshold(&m, 0.99);
        let n = count_outliers(&m, tau);
        // ~1% of elements above the 99th percentile.
        assert!((20..=60).contains(&n), "n={n}");
        assert_eq!(count_outliers(&m, f32::MAX), 0);
    }

    #[test]
    fn quant_error_decreases_with_bits_and_smoothing() {
        let m = spiky(64, 64);
        assert!(quant_error(&m, 8) < quant_error(&m, 4));
        // Hadamard rotation spreads the spike → lower quant error.
        let mut r = m.clone();
        crate::linalg::fwht_rows(&mut r);
        assert!(quant_error(&r, 4) < quant_error(&m, 4));
    }

    #[test]
    fn histogram_partitions_everything() {
        let m = spiky(16, 64);
        let h = histogram(&m, -5.0, 5.0, 20);
        assert_eq!(h.iter().sum::<usize>(), m.data.len());
        let rendered = render_histogram(&h, -5.0, 5.0, 40);
        assert_eq!(rendered.lines().count(), 20);
    }

    #[test]
    fn stats_of_spiky_have_high_kurtosis() {
        let m = spiky(128, 64);
        let s = activation_stats(&normalize_rows_rms(&m));
        assert!(s.kurtosis > 5.0, "kurtosis {}", s.kurtosis);
        assert!(s.mean.abs() < 0.2);
        assert!((s.variance - 1.0).abs() < 0.3, "var {}", s.variance);
    }

    #[test]
    fn outlier_threshold_survives_nan_and_inf() {
        // An overflowed activation column must not panic the quantile
        // scan (DFRot-style massive activations are expected inputs).
        // total_cmp sorts NaN above +inf, so a high-but-not-1.0 quantile
        // still lands on a finite magnitude.
        let mut m = spiky(16, 16);
        *m.at_mut(0, 0) = f32::NAN;
        *m.at_mut(1, 1) = f32::INFINITY;
        *m.at_mut(2, 2) = f32::NEG_INFINITY;
        let tau = outlier_threshold(&m, 0.9);
        assert!(tau.is_finite(), "tau={tau}");
        // The extreme slots sort to the top of the magnitude order.
        let top = outlier_threshold(&m, 1.0);
        assert!(top.is_nan(), "NaN is the total_cmp maximum, got {top}");
    }

    #[test]
    fn rotation_reduces_kurtosis() {
        let m = spiky(128, 64);
        let mut r = m.clone();
        crate::linalg::fwht_rows(&mut r);
        assert!(
            activation_stats(&r).kurtosis < activation_stats(&m).kurtosis / 2.0,
            "hadamard should gaussianize"
        );
    }
}
