//! Perplexity evaluation — the paper's primary metric (WikiText2/PTB/C4
//! columns of Tables 2/5/7/9/...).
//!
//! Two execution paths: the PJRT `fwd_*`/`fwdq_*` artifacts (fast path —
//! XLA-compiled, used by the benches) and the native forward (oracle /
//! fallback). Both consume held-out batches from a [`Corpus`] dialect.

use crate::data::Corpus;
use crate::model::{self, FwdOptions, TokenBatch, Weights};
use crate::runtime::Runtime;
use anyhow::Result;

/// How many held-out batches one PPL number averages over.
pub const DEFAULT_EVAL_BATCHES: usize = 4;

/// Evaluation geometry — must match the artifact shapes for the PJRT path.
#[derive(Clone, Copy, Debug)]
pub struct EvalSpec {
    pub batch: usize,
    pub seq: usize,
    pub n_batches: usize,
}

impl Default for EvalSpec {
    fn default() -> Self {
        EvalSpec { batch: 8, seq: 256, n_batches: DEFAULT_EVAL_BATCHES }
    }
}

/// PPL through the PJRT quantized-forward artifact.
pub fn ppl_artifact(
    rt: &Runtime,
    w: &Weights,
    corpus: &Corpus,
    spec: EvalSpec,
    a_levels: f32,
    kv_levels: f32,
    use_had: bool,
) -> Result<f64> {
    let mut total = 0f64;
    let mut count = 0usize;
    for i in 0..spec.n_batches {
        let toks = TokenBatch::new(&corpus.valid_batch(spec.batch, spec.seq, i as u64));
        // Same disable threshold as `model::forward::fq_row_grid`
        // (levels >= 32768 means no fake-quant), so the artifact routing
        // agrees with the native forward for any FwdOptions.
        let nll = if a_levels >= 32768.0 && kv_levels >= 32768.0 && !use_had {
            model::artifact_io::run_fwd(rt, w, &toks)?
        } else {
            model::artifact_io::run_fwdq(rt, w, &toks, a_levels, kv_levels, use_had)?
        };
        total += nll.data.iter().map(|&v| v as f64).sum::<f64>();
        count += nll.data.len();
    }
    Ok((total / count as f64).exp())
}

/// PPL through the native forward (no artifacts needed).
pub fn ppl_native(w: &Weights, corpus: &Corpus, spec: EvalSpec, opt: FwdOptions) -> f64 {
    let mut total = 0f64;
    let mut count = 0usize;
    for i in 0..spec.n_batches {
        let batch = corpus.valid_batch(spec.batch, spec.seq, i as u64);
        for nll in model::forward_batch(w, &batch, opt) {
            total += nll.iter().map(|&v| v as f64).sum::<f64>();
            count += nll.len();
        }
    }
    (total / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dialect;
    use crate::model::ModelConfig;

    #[test]
    fn native_ppl_beats_uniform_on_matching_dialect() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let spec = EvalSpec { batch: 2, seq: 64, n_batches: 1 };
        let ppl = ppl_native(&w, &corpus, spec, FwdOptions::FP);
        // Short-sequence eval on the grammar model: clearly below the
        // uniform PPL (=vocab) with margin.
        assert!(ppl < cfg.vocab as f64 / 2.0, "ppl {ppl}");
        assert!(ppl > 1.5);
    }

    #[test]
    fn quantization_hurts_ppl_monotonically() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let spec = EvalSpec { batch: 2, seq: 64, n_batches: 1 };
        let fp = ppl_native(&w, &corpus, spec, FwdOptions::FP);
        let a8 = ppl_native(&w, &corpus, spec, FwdOptions::quant(8, 16, false));
        let a4 = ppl_native(&w, &corpus, spec, FwdOptions::quant(4, 16, false));
        assert!((a8 - fp).abs() / fp < 0.2, "8-bit ~lossless: {fp} vs {a8}");
        assert!(a4 > fp, "4-bit must hurt: {fp} vs {a4}");
    }
}
