//! Evaluation harness: perplexity, the nine-task zero-shot suite, and the
//! activation statistics behind the paper's figures.

pub mod ppl;
pub mod stats;
pub mod zeroshot;

pub use ppl::{ppl_artifact, ppl_native, EvalSpec};
pub use stats::{activation_stats, count_outliers, histogram, outlier_threshold, quant_error};
