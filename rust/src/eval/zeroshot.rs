//! Synthetic zero-shot task suite — nine tasks standing in for the paper's
//! WG / SIQA / PIQA / OBQA / LAMBADA / HS / ARC-E / ARC-C / MMLU columns.
//!
//! Every task is multiple-choice and scored exactly like the real harness
//! scores LLMs: the model's total NLL over each candidate continuation
//! given the context, lowest NLL wins. The candidates are built from the
//! corpus process (the true continuation) plus controlled corruptions, so
//! a model that knows the corpus grammar scores above chance and
//! quantization damage shows up as accuracy loss.

use crate::data::{Corpus, Dialect};
use crate::model::{self, TokenBatch, Weights};
use crate::runtime::Runtime;
use crate::util::prng::Pcg64;
use anyhow::Result;

/// One multiple-choice item: full candidate sequences (context + option)
/// and which option is correct. All candidates share the context prefix.
#[derive(Clone)]
pub struct Item {
    pub candidates: Vec<Vec<i32>>,
    pub option_start: usize,
    pub correct: usize,
}

/// Task descriptor: name + how candidates are generated.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_options: usize,
    pub option_len: usize,
    /// Corruption style for distractors.
    pub corruption: Corruption,
}

#[derive(Clone, Copy, Debug)]
pub enum Corruption {
    /// Replace the continuation with fresh Zipf draws (easy).
    Resample,
    /// Shuffle the true continuation's tokens (harder — right unigrams).
    Shuffle,
    /// Perturb a fraction of tokens in place (hardest).
    Perturb(f32),
}

/// The nine-task suite (names mirror the paper's Table 2 columns).
pub const SUITE: [TaskSpec; 9] = [
    TaskSpec { name: "WG", n_options: 2, option_len: 8, corruption: Corruption::Perturb(0.5) },
    TaskSpec { name: "SIQA", n_options: 3, option_len: 8, corruption: Corruption::Shuffle },
    TaskSpec { name: "PIQA", n_options: 2, option_len: 8, corruption: Corruption::Resample },
    TaskSpec { name: "OBQA", n_options: 4, option_len: 6, corruption: Corruption::Resample },
    TaskSpec { name: "LAMB", n_options: 4, option_len: 2, corruption: Corruption::Resample },
    TaskSpec { name: "HS", n_options: 4, option_len: 12, corruption: Corruption::Shuffle },
    TaskSpec { name: "ARC-E", n_options: 4, option_len: 8, corruption: Corruption::Resample },
    TaskSpec { name: "ARC-C", n_options: 4, option_len: 8, corruption: Corruption::Perturb(0.35) },
    TaskSpec { name: "MMLU", n_options: 4, option_len: 8, corruption: Corruption::Perturb(0.5) },
];

/// Generate `count` items for a task from a corpus dialect.
pub fn generate_items(
    task: &TaskSpec,
    corpus: &Corpus,
    count: usize,
    seq_len: usize,
    seed: u64,
) -> Vec<Item> {
    let mut rng = Pcg64::new(seed ^ fxhash(task.name));
    let ctx_len = seq_len - task.option_len;
    (0..count)
        .map(|i| {
            let full = corpus.sequence(seq_len, 3, (seed << 16) ^ i as u64);
            let truth = full[ctx_len..].to_vec();
            let correct = rng.below(task.n_options);
            let candidates = (0..task.n_options)
                .map(|o| {
                    let mut cand = full[..ctx_len].to_vec();
                    if o == correct {
                        cand.extend_from_slice(&truth);
                    } else {
                        cand.extend(corrupt(&truth, task.corruption, corpus, &mut rng));
                    }
                    cand
                })
                .collect();
            Item { candidates, option_start: ctx_len, correct }
        })
        .collect()
}

fn corrupt(truth: &[i32], c: Corruption, corpus: &Corpus, rng: &mut Pcg64) -> Vec<i32> {
    match c {
        Corruption::Resample => {
            // Fresh draw decoupled from the context.
            corpus.sequence(truth.len(), 4, rng.next_u64())
        }
        Corruption::Shuffle => {
            let mut v = truth.to_vec();
            // Derangement-ish shuffle; retry once if it lands on identity.
            rng.shuffle(&mut v);
            if v == truth {
                let k = 1.min(v.len().saturating_sub(1));
                v.rotate_left(k);
            }
            v
        }
        Corruption::Perturb(frac) => {
            let mut v = truth.to_vec();
            let n = ((v.len() as f32 * frac).ceil() as usize).max(1);
            for _ in 0..n {
                let i = rng.below(v.len());
                v[i] = rng.below(corpus.vocab) as i32;
            }
            v
        }
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Accuracy of one task, scoring through the PJRT `fwdq_*` artifact.
/// Candidates are packed into fixed (batch, seq) artifact calls.
#[allow(clippy::too_many_arguments)]
pub fn task_accuracy_artifact(
    rt: &Runtime,
    w: &Weights,
    items: &[Item],
    batch: usize,
    a_levels: f32,
    kv_levels: f32,
    use_had: bool,
) -> Result<f64> {
    // Flatten all candidates, remembering (item, option).
    let mut rows: Vec<&Vec<i32>> = Vec::new();
    let mut tags: Vec<(usize, usize)> = Vec::new();
    for (ii, item) in items.iter().enumerate() {
        for (oi, c) in item.candidates.iter().enumerate() {
            rows.push(c);
            tags.push((ii, oi));
        }
    }
    let seq = rows[0].len();
    let mut scores = vec![vec![f64::INFINITY; 8]; items.len()];
    let mut idx = 0;
    while idx < rows.len() {
        // Pack a full batch (pad by repeating the last row; padded rows'
        // scores are discarded).
        let mut seqs: Vec<Vec<i32>> = Vec::with_capacity(batch);
        for b in 0..batch {
            seqs.push(rows[(idx + b).min(rows.len() - 1)].clone());
        }
        let toks = TokenBatch::new(&seqs);
        let nll = model::artifact_io::run_fwdq(rt, w, &toks, a_levels, kv_levels, use_had)?;
        for b in 0..batch {
            let r = idx + b;
            if r >= rows.len() {
                break;
            }
            let (ii, oi) = tags[r];
            let start = items[ii].option_start.saturating_sub(1); // NLL[t] predicts token t+1
            let s: f64 = (start..seq - 1).map(|t| nll.at(b, t) as f64).sum();
            scores[ii][oi] = s;
        }
        idx += batch;
    }
    Ok(fraction_correct(items, &scores))
}

/// Accuracy via the native forward (no artifacts).
pub fn task_accuracy_native(w: &Weights, items: &[Item], opt: model::FwdOptions) -> f64 {
    let mut scores = vec![vec![f64::INFINITY; 8]; items.len()];
    for (ii, item) in items.iter().enumerate() {
        for (oi, cand) in item.candidates.iter().enumerate() {
            let nll = model::forward_one(w, cand, opt, &mut model::NoCapture);
            let start = item.option_start.saturating_sub(1);
            scores[ii][oi] = (start..nll.len()).map(|t| nll[t] as f64).sum();
        }
    }
    fraction_correct(items, &scores)
}

fn fraction_correct(items: &[Item], scores: &[Vec<f64>]) -> f64 {
    let correct = items
        .iter()
        .zip(scores)
        .filter(|(item, s)| {
            let best = s[..item.candidates.len()]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            best == item.correct
        })
        .count();
    correct as f64 / items.len() as f64
}

/// Run the whole nine-task suite; returns (task name, accuracy) pairs plus
/// the average — the paper's "0-shot⁹" column.
#[allow(clippy::too_many_arguments)]
pub fn suite_accuracy_artifact(
    rt: &Runtime,
    w: &Weights,
    dialect: Dialect,
    items_per_task: usize,
    seq_len: usize,
    seed: u64,
    a_levels: f32,
    kv_levels: f32,
    use_had: bool,
) -> Result<(Vec<(&'static str, f64)>, f64)> {
    let corpus = Corpus::new(dialect, w.cfg.vocab, seed);
    let mut out = Vec::new();
    for task in &SUITE {
        let items = generate_items(task, &corpus, items_per_task, seq_len, seed);
        let acc =
            task_accuracy_artifact(rt, w, &items, 8, a_levels, kv_levels, use_had)?;
        out.push((task.name, acc));
    }
    let avg = out.iter().map(|(_, a)| a).sum::<f64>() / out.len() as f64;
    Ok((out, avg))
}

/// Run the whole nine-task suite through the native forward (no
/// artifacts) — the eval path for packed models, whose weights cannot
/// feed the f32 artifact signatures.
pub fn suite_accuracy_native(
    w: &Weights,
    dialect: Dialect,
    items_per_task: usize,
    seq_len: usize,
    seed: u64,
    opt: model::FwdOptions,
) -> (Vec<(&'static str, f64)>, f64) {
    let corpus = Corpus::new(dialect, w.cfg.vocab, seed);
    let mut out = Vec::new();
    for task in &SUITE {
        let items = generate_items(task, &corpus, items_per_task, seq_len, seed);
        out.push((task.name, task_accuracy_native(w, &items, opt)));
    }
    let avg = out.iter().map(|(_, a)| a).sum::<f64>() / out.len() as f64;
    (out, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{FwdOptions, ModelConfig};

    #[test]
    fn items_have_consistent_geometry() {
        let corpus = Corpus::new(Dialect::Wiki, 512, 1);
        for task in &SUITE {
            let items = generate_items(task, &corpus, 4, 64, 9);
            for item in &items {
                assert_eq!(item.candidates.len(), task.n_options);
                assert!(item.correct < task.n_options);
                for c in &item.candidates {
                    assert_eq!(c.len(), 64);
                    // shared context prefix
                    assert_eq!(c[..item.option_start], item.candidates[0][..item.option_start]);
                }
                // distractors differ from truth
                let truth = &item.candidates[item.correct];
                for (i, c) in item.candidates.iter().enumerate() {
                    if i != item.correct {
                        assert_ne!(c, truth);
                    }
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let corpus = Corpus::new(Dialect::Ptb, 512, 2);
        let a = generate_items(&SUITE[0], &corpus, 3, 48, 5);
        let b = generate_items(&SUITE[0], &corpus, 3, 48, 5);
        assert_eq!(a[1].candidates, b[1].candidates);
        assert_eq!(a[1].correct, b[1].correct);
    }

    #[test]
    fn grammar_model_beats_chance_on_resample_tasks() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        // LAMBADA-like: 4 options, 2-token continuation — the boundary
        // token carries the grammar signal (resampled distractors are
        // internally grammar-consistent, so long spans dilute the margin).
        let items = generate_items(&SUITE[4], &corpus, 24, 48, 11);
        let acc = task_accuracy_native(&w, &items, FwdOptions::FP);
        assert!(acc >= 0.45, "accuracy {acc} not above chance (0.25)");
    }

    #[test]
    fn nan_scores_do_not_panic_the_argmin() {
        // A NaN candidate score (overflowed logits) must neither panic
        // nor win the argmin: total_cmp puts NaN above every finite
        // score, so the finite best still decides the item.
        let item = Item {
            candidates: vec![vec![0; 4]; 3],
            option_start: 1,
            correct: 1,
        };
        let scores = vec![vec![f64::NAN, 2.0, 3.0]];
        assert_eq!(fraction_correct(&[item.clone()], &scores), 1.0);
        // All-NaN degrades deterministically to option 0.
        let scores = vec![vec![f64::NAN; 3]];
        assert_eq!(fraction_correct(&[item], &scores), 0.0);
    }

    #[test]
    fn random_model_is_near_chance() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_synthetic(&cfg, 1); // no grammar
        let items = generate_items(&SUITE[2], &corpus, 16, 48, 11);
        let acc = task_accuracy_native(&w, &items, FwdOptions::FP);
        assert!((0.15..=0.85).contains(&acc), "accuracy {acc} suspiciously far from chance");
    }
}
