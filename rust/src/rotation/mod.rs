//! Rotation machinery: fusing the learned R1/R2 into weights (Appendix A's
//! computational invariance), the online R3/R4 Hadamard sites, and rotation
//! initializers (random Hadamard — QuaRot; random orthogonal; identity).
//!
//! Fusion and smoothing are **layer-local**: the whole-model passes
//! ([`fuse`], [`smooth_scales`]) and the out-of-core passes
//! ([`fuse_streamed`], [`smooth_streamed`]) share the same per-tensor
//! helpers, so a streamed run (one layer checked out at a time from a
//! `model::WeightStore`) produces bit-identical weights — the
//! determinism contract of `docs/STREAMING.md`.

use crate::linalg::{self, hadamard_matrix, randomized_hadamard};
use crate::model::{forward_one, CaptureHook, FwdOptions, WeightStore, Weights};
use crate::tensor::{matmul, Mat};
use crate::util::prng::Pcg64;
use anyhow::Result;

/// Which rotations a calibration/quantization run applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotationScheme {
    /// No rotation (RTN/GPTQ/SmoothQuant baselines).
    None,
    /// Random Hadamard R1/R2 (+ online R3/R4) — QuaRot.
    Hadamard,
    /// Learned R1/R2 (+ online R3/R4) — DartQuant / SpinQuant-sim /
    /// OSTQuant-sim (they differ in *how* R is learned, not where).
    Learned,
}

/// A full rotation set for a model: one global R1 (dim×dim) and one shared
/// per-layer R2 (head_dim×head_dim) per layer.
#[derive(Clone, Debug)]
pub struct RotationSet {
    pub r1: Mat,
    /// One R2 per layer (shared across heads, as in SpinQuant).
    pub r2: Vec<Mat>,
    /// Whether the online R3/R4 Hadamards are enabled at inference.
    pub online_had: bool,
}

impl RotationSet {
    pub fn identity(dim: usize, head_dim: usize, n_layers: usize) -> RotationSet {
        RotationSet {
            r1: Mat::eye(dim),
            r2: (0..n_layers).map(|_| Mat::eye(head_dim)).collect(),
            online_had: false,
        }
    }

    /// QuaRot-style random Hadamard rotations.
    pub fn random_hadamard(
        dim: usize,
        head_dim: usize,
        n_layers: usize,
        rng: &mut Pcg64,
    ) -> RotationSet {
        RotationSet {
            r1: randomized_hadamard(dim, rng),
            r2: (0..n_layers).map(|_| randomized_hadamard(head_dim, rng)).collect(),
            online_had: true,
        }
    }

    /// Haar-random orthogonal rotations (the "random orthogonal" ablation
    /// QuaRot found inferior to Hadamard).
    pub fn random_orthogonal(
        dim: usize,
        head_dim: usize,
        n_layers: usize,
        rng: &mut Pcg64,
    ) -> RotationSet {
        RotationSet {
            r1: linalg::random_orthogonal(dim, rng),
            r2: (0..n_layers).map(|_| linalg::random_orthogonal(head_dim, rng)).collect(),
            online_had: true,
        }
    }

    /// Orthogonality defect across all members (sanity checks).
    pub fn max_defect(&self) -> f32 {
        let mut d = linalg::orthogonality_defect(&self.r1);
        for r in &self.r2 {
            d = d.max(linalg::orthogonality_defect(r));
        }
        d
    }
}

/// Expand a per-head R2 (hd×hd) to the block-diagonal form acting on a
/// (heads·hd)-dim space.
fn block_diag(r: &Mat, heads: usize) -> Mat {
    let hd = r.rows;
    Mat::from_fn(heads * hd, heads * hd, |i, j| {
        if i / hd == j / hd {
            r.at(i % hd, j % hd)
        } else {
            0.0
        }
    })
}

/// Fuse a rotation set into model weights (exact; fp outputs unchanged):
///
/// * R1: input-side weights (wq wk wv wg wu router) ← W·R1; output-side
///   (wo wd) ← R1ᵀ·W; embed/head rotate rows (W·R1).
/// * R2 (per layer ℓ): wv ← blockdiag(R2)ᵀ·wv, wo ← wo·blockdiag(R2)
///   (value channels rotate per head; GQA repeats kv heads across groups).
/// * R4 (when `online_had`): wd ← wd·H_f — cancels the in-graph Hadamard
///   applied to the FFN activation. (R3 needs no weight change: it cancels
///   inside attention and only re-bases the quantized K cache.)
pub fn fuse(weights: &Weights, rot: &RotationSet) -> Weights {
    let cfg = weights.cfg.clone();
    let mut out = weights.clone();
    let r1t = rot.r1.t();
    let had = rot.online_had.then(|| hadamard_matrix(cfg.ffn_dim));
    assert_eq!(rot.r1.rows, cfg.dim);
    assert_eq!(rot.r2.len(), cfg.n_layers);
    for name in ["embed", "head"] {
        let fused = fuse_r1(name, out.get(name), &rot.r1, &r1t);
        out.set(name, fused);
    }
    for l in 0..cfg.n_layers {
        fuse_layer(&mut out, l, rot, &r1t, had.as_ref());
    }
    out
}

/// R1 fusion of one tensor: input-side weights ← W·R1, output-side
/// weights ← R1ᵀ·W, embed/head rotate rows. Shared by [`fuse`] and
/// [`fuse_streamed`].
fn fuse_r1(leaf: &str, w: &Mat, r1: &Mat, r1t: &Mat) -> Mat {
    match leaf {
        "embed" | "head" => matmul(w, r1),
        "wq" | "wk" | "wv" | "wg" | "wu" | "router" => matmul(w, r1),
        "wo" | "wd" => matmul(r1t, w),
        other => panic!("unknown leaf {other}"),
    }
}

/// Fuse everything that touches layer `l`'s tensors: R1 on every weight,
/// then R2 on wv/wo, then (when `had` carries H_f, i.e. `online_had`)
/// H_f into wd. The per-tensor composition order matches the historical
/// whole-model pass exactly, so per-layer (streamed) fusion is
/// bit-identical to in-memory fusion. `w` may be the full model or a
/// checked-out partial holding layer `l`; `had` is built once per run by
/// the callers.
fn fuse_layer(w: &mut Weights, l: usize, rot: &RotationSet, r1t: &Mat, had: Option<&Mat>) {
    let cfg = w.cfg.clone();
    let prefix = format!("l{l}.");
    let names: Vec<String> =
        w.names().iter().filter(|n| n.starts_with(&prefix)).cloned().collect();
    for name in names {
        let leaf = name.rsplit('.').next().unwrap().to_string();
        let fused = fuse_r1(&leaf, w.get(&name), &rot.r1, r1t);
        w.set(&name, fused);
    }
    // R2: v' = v·B ⇒ wv' = Bᵀ·wv ; attention output per q-head carries
    // the (repeated) rotated v ⇒ wo' = wo·B_q.
    let r2 = &rot.r2[l];
    assert_eq!(r2.rows, cfg.head_dim);
    let bd_kv = block_diag(r2, cfg.n_kv_heads);
    let bd_q = block_diag(r2, cfg.n_heads);
    let wv_name = format!("l{l}.wv");
    let wo_name = format!("l{l}.wo");
    let wv = matmul(&bd_kv.t(), w.get(&wv_name));
    w.set(&wv_name, wv);
    let wo = matmul(w.get(&wo_name), &bd_q);
    w.set(&wo_name, wo);
    // R4: fold H_f into wd so the online activation Hadamard cancels.
    if let Some(h) = had {
        if cfg.is_moe() {
            for e in 0..cfg.n_experts {
                let name = format!("l{l}.e{e}.wd");
                let fused = matmul(w.get(&name), h);
                w.set(&name, fused);
            }
        } else {
            let name = format!("l{l}.wd");
            let fused = matmul(w.get(&name), h);
            w.set(&name, fused);
        }
    }
}

/// [`fuse`] over a `WeightStore` instead of an in-memory model: embed and
/// head are checked out together, then one layer at a time — peak weight
/// residency is one checkout, and the written-back weights are
/// **bit-identical** to what [`fuse`] produces (same per-tensor matmuls
/// on the same operands; see `docs/STREAMING.md`).
pub fn fuse_streamed(store: &WeightStore, rot: &RotationSet) -> Result<()> {
    let cfg = store.cfg().clone();
    let r1t = rot.r1.t();
    let had = rot.online_had.then(|| hadamard_matrix(cfg.ffn_dim));
    assert_eq!(rot.r1.rows, cfg.dim);
    assert_eq!(rot.r2.len(), cfg.n_layers);
    {
        let mut lease = store.checkout(&["embed", "head"])?;
        for name in ["embed", "head"] {
            let fused = fuse_r1(name, lease.weights().get(name), &rot.r1, &r1t);
            lease.weights_mut().set(name, fused);
        }
        lease.commit()?;
    }
    for l in 0..cfg.n_layers {
        let mut lease = store.checkout_layer(l)?;
        fuse_layer(lease.weights_mut(), l, rot, &r1t, had.as_ref());
        lease.commit()?;
    }
    Ok(())
}

/// SmoothQuant-style per-channel scaling (the scaling baseline, and the
/// "+scale" part of OSTQuant-sim).
///
/// Scaling is applied at the two sites where it is an *exact* invariance
/// for a gain-free RMSNorm model: the attention-output linear (wo) and
/// the FFN down-projection (wd) — in real Llamas the down-projection is
/// the dominant outlier site. (SmoothQuant's residual-stream sites need a
/// norm gain to fold into, which this architecture deliberately omits;
/// see DESIGN.md.) For site inputs X and consumer W: X ← X·S⁻¹,
/// W ← W·S with s_c = max|X_c|^α / max|W_c|^(1-α).
pub struct SmoothStats {
    /// Per layer: abs-max per channel of the wo input (attention output).
    pub wo_absmax: Vec<Vec<f32>>,
    /// Per layer: abs-max per channel of the wd input (FFN activation).
    pub wd_absmax: Vec<Vec<f32>>,
}

/// Per-site abs-max accumulator shared by [`SmoothStats::capture`] and
/// [`SmoothStats::capture_streamed`]. Maxima commute, so capture order
/// (sequence-major vs layer-major) cannot change the result.
struct SmoothHook {
    wo: Vec<Vec<f32>>,
    wd: Vec<Vec<f32>>,
}

impl CaptureHook for SmoothHook {
    fn on_linear_input(&mut self, name: &str, x: &Mat) {
        let leaf = name.rsplit('.').next().unwrap();
        let l: usize = name[1..name.find('.').unwrap()].parse().unwrap();
        let target = match leaf {
            "wo" => &mut self.wo[l],
            "wd" => &mut self.wd[l],
            _ => return,
        };
        if target.is_empty() {
            target.resize(x.cols, 0.0);
        }
        for i in 0..x.rows {
            for (c, m) in target.iter_mut().enumerate() {
                *m = m.max(x.at(i, c).abs());
            }
        }
    }
}

impl SmoothStats {
    /// Capture from a native forward pass over calibration sequences.
    pub fn capture(weights: &Weights, seqs: &[Vec<i32>]) -> SmoothStats {
        let l = weights.cfg.n_layers;
        let mut hook = SmoothHook { wo: vec![vec![]; l], wd: vec![vec![]; l] };
        for seq in seqs {
            forward_one(weights, seq, FwdOptions::FP, &mut hook);
        }
        SmoothStats { wo_absmax: hook.wo, wd_absmax: hook.wd }
    }

    /// [`SmoothStats::capture`] over a `WeightStore`: a layer-at-a-time
    /// forward (`model::stream_blocks`) feeds the same abs-max hook.
    /// Per-site maxima are order-independent and the streamed residuals
    /// are bit-identical to `forward_one`'s, so the stats are
    /// **identical** to the in-memory capture.
    pub fn capture_streamed(store: &WeightStore, seqs: &[Vec<i32>]) -> Result<SmoothStats> {
        let l = store.cfg().n_layers;
        let mut hook = SmoothHook { wo: vec![vec![]; l], wd: vec![vec![]; l] };
        crate::model::stream_blocks(store, seqs, FwdOptions::FP, &mut hook, |_, _, _| Ok(()))?;
        Ok(SmoothStats { wo_absmax: hook.wo, wd_absmax: hook.wd })
    }
}

/// Apply SmoothQuant scaling. Exact fp invariance (up to f32 rounding).
pub fn smooth_scales(weights: &Weights, stats: &SmoothStats, alpha: f32) -> Weights {
    let cfg = weights.cfg.clone();
    assert!(!cfg.is_moe(), "SmoothQuant baseline implemented for dense configs");
    let mut out = weights.clone();
    for l in 0..cfg.n_layers {
        smooth_layer(&mut out, l, stats, alpha);
    }
    out
}

/// [`smooth_scales`] over a `WeightStore`: each layer's wv/wo/wu/wd are
/// checked out, scaled by the same layer-local helper, and written back —
/// bit-identical to the in-memory pass (see `docs/STREAMING.md`).
pub fn smooth_streamed(store: &WeightStore, stats: &SmoothStats, alpha: f32) -> Result<()> {
    let cfg = store.cfg().clone();
    assert!(!cfg.is_moe(), "SmoothQuant baseline implemented for dense configs");
    for l in 0..cfg.n_layers {
        let names =
            [format!("l{l}.wv"), format!("l{l}.wo"), format!("l{l}.wu"), format!("l{l}.wd")];
        let mut lease = store.checkout(&names)?;
        smooth_layer(lease.weights_mut(), l, stats, alpha);
        lease.commit()?;
    }
    Ok(())
}

/// One layer's SmoothQuant scaling, shared by [`smooth_scales`] and
/// [`smooth_streamed`]. Reads each site's pre-scale weights before
/// mutating them (the two sites touch disjoint tensors), so operating on
/// one `&mut Weights` reproduces the historical read-from-source /
/// write-to-copy pass bit-for-bit. `w` may be the full model or a
/// checkout holding the layer's wv/wo/wu/wd.
fn smooth_layer(w: &mut Weights, l: usize, stats: &SmoothStats, alpha: f32) {
    let cfg = w.cfg.clone();
    // --- wo site: attn_out ← attn_out·S⁻¹ via wv rows; wo cols ← ·S.
    // GQA note: attn_out channel j carries v channel (j/hd/rep)*hd+j%hd,
    // so scales must be shared within each kv-head group; we take the
    // max over the group.
    let (hd, rep) = (cfg.head_dim, cfg.n_heads / cfg.n_kv_heads);
    let act = &stats.wo_absmax[l];
    if !act.is_empty() {
        let mut w_absmax = vec![1e-6f32; cfg.kv_dim()];
        let mut a_absmax = vec![1e-6f32; cfg.kv_dim()];
        {
            let wo = w.get(&format!("l{l}.wo"));
            for j in 0..cfg.q_dim() {
                let kv_c = (j / hd / rep) * hd + j % hd;
                a_absmax[kv_c] = a_absmax[kv_c].max(act[j]);
                for i in 0..wo.rows {
                    w_absmax[kv_c] = w_absmax[kv_c].max(wo.at(i, j).abs());
                }
            }
        }
        let s: Vec<f32> = a_absmax
            .iter()
            .zip(&w_absmax)
            .map(|(&a, &w)| {
                (a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha)).clamp(0.05, 50.0)
            })
            .collect();
        let wv = w.get_mut(&format!("l{l}.wv"));
        for (r, sv) in s.iter().enumerate() {
            for c in 0..wv.cols {
                *wv.at_mut(r, c) /= sv;
            }
        }
        let wo = w.get_mut(&format!("l{l}.wo"));
        for i in 0..wo.rows {
            for j in 0..wo.cols {
                let kv_c = (j / hd / rep) * hd + j % hd;
                *wo.at_mut(i, j) *= s[kv_c];
            }
        }
    }
    // --- wd site: a ← a·S⁻¹ via wu rows; wd cols ← ·S. (Gate wg is
    // untouched: a = silu(g)·u, scaling u alone scales a.)
    let act = &stats.wd_absmax[l];
    if !act.is_empty() {
        let mut w_absmax = vec![1e-6f32; cfg.ffn_dim];
        {
            let wd = w.get(&format!("l{l}.wd"));
            for i in 0..wd.rows {
                for (c, m) in w_absmax.iter_mut().enumerate() {
                    *m = m.max(wd.at(i, c).abs());
                }
            }
        }
        let s: Vec<f32> = act
            .iter()
            .zip(&w_absmax)
            .map(|(&a, &w)| {
                (a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha)).clamp(0.05, 50.0)
            })
            .collect();
        let wu = w.get_mut(&format!("l{l}.wu"));
        for (r, sv) in s.iter().enumerate() {
            for c in 0..wu.cols {
                *wu.at_mut(r, c) /= sv;
            }
        }
        let wd = w.get_mut(&format!("l{l}.wd"));
        for i in 0..wd.rows {
            for (c, sv) in s.iter().enumerate() {
                *wd.at_mut(i, c) *= sv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, Dialect};
    use crate::model::{forward_one, FwdOptions, ModelConfig, NoCapture};

    fn setup() -> (Weights, Vec<i32>, Corpus) {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let toks = corpus.valid_batch(1, 48, 0).remove(0);
        (w, toks, corpus)
    }

    fn mean(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
    }

    #[test]
    fn fuse_r1_r2_preserves_fp_outputs() {
        let (w, toks, _) = setup();
        let base = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let mut rng = Pcg64::new(3);
        let rot = RotationSet {
            r1: linalg::random_orthogonal(w.cfg.dim, &mut rng),
            r2: (0..w.cfg.n_layers)
                .map(|_| linalg::random_orthogonal(w.cfg.head_dim, &mut rng))
                .collect(),
            online_had: false,
        };
        let fused = fuse(&w, &rot);
        let got = forward_one(&fused, &toks, FwdOptions::FP, &mut NoCapture);
        let d = (mean(&base) - mean(&got)).abs();
        assert!(d < 2e-2, "computational invariance violated: {d}");
    }

    #[test]
    fn fuse_with_online_hadamard_preserves_fp_outputs() {
        let (w, toks, _) = setup();
        let base = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let mut rng = Pcg64::new(4);
        let rot = RotationSet::random_hadamard(w.cfg.dim, w.cfg.head_dim, w.cfg.n_layers, &mut rng);
        let fused = fuse(&w, &rot);
        let opt = FwdOptions { a_levels: 65536.0, kv_levels: 65536.0, use_had: true, shards: 1 };
        let got = forward_one(&fused, &toks, opt, &mut NoCapture);
        let d = (mean(&base) - mean(&got)).abs();
        assert!(d < 2e-2, "R3/R4 cancellation violated: {d}");
    }

    #[test]
    fn hadamard_rotation_recovers_w4_activation_quant() {
        // The paper's central mechanism: 4-bit activation quantization
        // hurts; rotating first (QuaRot) recovers most of the damage.
        let (w, _, corpus) = setup();
        let spec = crate::eval::ppl::EvalSpec { batch: 2, seq: 64, n_batches: 2 };
        let fp = crate::eval::ppl_native(&w, &corpus, spec, FwdOptions::FP);
        let quant = FwdOptions::quant(4, 16, false);
        let plain = crate::eval::ppl_native(&w, &corpus, spec, quant);
        let mut rng = Pcg64::new(5);
        let rot = RotationSet::random_hadamard(w.cfg.dim, w.cfg.head_dim, w.cfg.n_layers, &mut rng);
        let fused = fuse(&w, &rot);
        let rotated = crate::eval::ppl_native(&fused, &corpus, spec, FwdOptions::quant(4, 16, true));
        assert!(plain > fp * 1.05, "quant should hurt: fp {fp} vs {plain}");
        let recovered = (plain - rotated) / (plain - fp);
        assert!(
            recovered > 0.25,
            "rotation should recover ≥25% of quant damage: fp {fp}, plain {plain}, rotated {rotated}"
        );
    }

    #[test]
    fn identity_rotation_is_a_noop() {
        let (w, toks, _) = setup();
        let rot = RotationSet::identity(w.cfg.dim, w.cfg.head_dim, w.cfg.n_layers);
        let fused = fuse(&w, &rot);
        let a = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let b = forward_one(&fused, &toks, FwdOptions::FP, &mut NoCapture);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn rotation_set_defects_are_small() {
        let mut rng = Pcg64::new(6);
        let rot = RotationSet::random_hadamard(256, 64, 4, &mut rng);
        assert!(rot.max_defect() < 1e-3);
        let rot = RotationSet::random_orthogonal(64, 64, 2, &mut rng);
        assert!(rot.max_defect() < 1e-3);
    }

    #[test]
    fn smooth_scales_preserve_fp_outputs() {
        let (w, toks, corpus) = setup();
        let base = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let calib = corpus.calib_sequences(2, 48);
        let stats = SmoothStats::capture(&w, &calib);
        let smoothed = smooth_scales(&w, &stats, 0.5);
        let got = forward_one(&smoothed, &toks, FwdOptions::FP, &mut NoCapture);
        let d = (mean(&base) - mean(&got)).abs();
        assert!(d < 2e-2, "smoothing must be fp-invariant: {d}");
    }

    #[test]
    fn streamed_fuse_is_bit_identical_to_in_memory_fuse() {
        let (w, _, _) = setup();
        let mut rng = Pcg64::new(9);
        let rot = RotationSet::random_hadamard(w.cfg.dim, w.cfg.head_dim, w.cfg.n_layers, &mut rng);
        let inmem = fuse(&w, &rot);
        let path = std::env::temp_dir().join(format!("dq-fuse-{}.dartq", std::process::id()));
        let store = WeightStore::create(
            &path,
            &w,
            Some(crate::model::suggested_resident_budget(&w.cfg)),
        )
        .unwrap();
        fuse_streamed(&store, &rot).unwrap();
        let streamed = store.materialize().unwrap();
        for name in inmem.names() {
            assert_eq!(streamed.get(name).data, inmem.get(name).data, "{name}");
        }
        assert!(store.peak_resident_bytes() < w.nbytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn streamed_smooth_is_bit_identical_to_in_memory_smooth() {
        let (w, _, corpus) = setup();
        let calib = corpus.calib_sequences(2, 48);
        let stats = SmoothStats::capture(&w, &calib);
        let inmem = smooth_scales(&w, &stats, 0.5);
        let path = std::env::temp_dir().join(format!("dq-smooth-{}.dartq", std::process::id()));
        let store = WeightStore::create(&path, &w, None).unwrap();
        // Streamed stats capture must agree exactly (abs-max commutes).
        let sstats = SmoothStats::capture_streamed(&store, &calib).unwrap();
        assert_eq!(sstats.wo_absmax, stats.wo_absmax);
        assert_eq!(sstats.wd_absmax, stats.wd_absmax);
        smooth_streamed(&store, &sstats, 0.5).unwrap();
        let streamed = store.materialize().unwrap();
        for name in inmem.names() {
            assert_eq!(streamed.get(name).data, inmem.get(name).data, "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn smooth_scales_reduce_site_outliers() {
        let (w, _, corpus) = setup();
        let calib = corpus.calib_sequences(2, 48);
        let stats = SmoothStats::capture(&w, &calib);
        let smoothed = smooth_scales(&w, &stats, 0.6);
        let after = SmoothStats::capture(&smoothed, &calib);
        // abs-max spread across channels at the wd site should shrink.
        let spread = |v: &Vec<f32>| {
            let mx = v.iter().cloned().fold(0.0f32, f32::max);
            let mean = v.iter().sum::<f32>() / v.len() as f32;
            mx / mean.max(1e-6)
        };
        let l = w.cfg.n_layers - 1;
        assert!(
            spread(&after.wd_absmax[l]) < spread(&stats.wd_absmax[l]),
            "smoothing should flatten the wd-site channel maxima"
        );
    }
}
