//! Rotation calibration — the paper's core contribution (Algorithm 1).
//!
//! The hot loop executes pre-compiled PJRT artifacts (`calib_*_n*`,
//! `cayley_*_n*`, `spin_*`): one artifact call = one optimizer step
//! (QR → rotate → objective → grad → update, fused into a single XLA
//! executable). Rust owns token sampling, batching, convergence tracking
//! and timing; python never runs here.

pub mod objectives;
mod spin;

pub use spin::{spin_calibrate, SpinConfig, SpinResult};

use crate::linalg;
use crate::runtime::{Runtime, Value};
use crate::tensor::Mat;
use crate::util::prng::Pcg64;
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// Calibration objective (Fig 7a / Table 22 ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    Whip,
    Variance,
    Kurtosis,
    Quant,
}

impl Objective {
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Whip => "whip",
            Objective::Variance => "variance",
            Objective::Kurtosis => "kurtosis",
            Objective::Quant => "quant",
        }
    }
    pub const ALL: [Objective; 4] =
        [Objective::Whip, Objective::Variance, Objective::Kurtosis, Objective::Quant];
}

/// Orthogonality enforcement scheme (Fig 7b / Table 4 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrthScheme {
    /// QR-Orth: optimize latent Z, R = qr(Z).Q — DartQuant.
    QrOrth,
    /// Cayley SGD on the Stiefel manifold — SpinQuant's optimizer.
    Cayley,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
}

impl OptKind {
    pub fn name(&self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::Adam => "adam",
        }
    }
}

/// Calibration hyper-parameters (paper Table 23: SGD, lr model-dependent,
/// 10 epochs, batch 64 sequences; we express the loop in steps over
/// sampled token batches).
#[derive(Clone, Debug)]
pub struct CalibConfig {
    pub objective: Objective,
    pub scheme: OrthScheme,
    pub optimizer: OptKind,
    pub lr: f32,
    pub steps: usize,
    pub seed: u64,
    /// Early-stop when the relative loss improvement over a 5-step window
    /// falls below this (0 disables).
    pub tol: f32,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            objective: Objective::Whip,
            scheme: OrthScheme::QrOrth,
            optimizer: OptKind::Sgd,
            lr: 1e-2,
            steps: 60,
            seed: 0,
            tol: 0.0,
        }
    }
}

/// Result of one rotation calibration.
#[derive(Clone, Debug)]
pub struct CalibResult {
    /// The calibrated orthogonal rotation.
    pub rotation: Mat,
    /// Loss trajectory (one entry per step).
    pub losses: Vec<f32>,
    /// Wall time of the optimization loop (excludes artifact compile).
    pub wall: Duration,
    /// Steps actually executed (≤ cfg.steps with early stopping).
    pub steps_run: usize,
}

/// Paper's token sampling: keep a fraction of token rows (Algorithm 1's
/// `token_sampling`, 10% in Appendix D), by norm-stratified random choice
/// so outlier rows stay represented.
pub fn sample_tokens(pool: &Mat, count: usize, rng: &mut Pcg64) -> Mat {
    if count >= pool.rows {
        // Upsample with replacement to reach the artifact geometry.
        let idx: Vec<usize> = (0..count).map(|_| rng.below(pool.rows)).collect();
        return pool.gather_rows(&idx);
    }
    let idx = rng.sample_indices(pool.rows, count);
    pool.gather_rows(&idx)
}

/// The artifact geometry for calibration batches.
pub const CALIB_TOKENS: usize = 1024;

/// Run a rotation calibration against activation pool `x_pool` (rows =
/// tokens, cols = rotation dim). One PJRT artifact call per step.
pub fn calibrate_rotation(rt: &Runtime, x_pool: &Mat, cfg: &CalibConfig) -> Result<CalibResult> {
    let n = x_pool.cols;
    let name = match cfg.scheme {
        OrthScheme::QrOrth => {
            format!("calib_{}_{}_n{n}", cfg.objective.name(), cfg.optimizer.name())
        }
        OrthScheme::Cayley => {
            format!("cayley_{}_{}_n{n}", cfg.objective.name(), cfg.optimizer.name())
        }
    };
    let exe = rt.load(&name).with_context(|| {
        format!("no calibration artifact {name} — aot.py emits whip at every dim, ablation objectives at n∈{{256,384}}")
    })?;
    let mut rng = Pcg64::new(cfg.seed ^ 0xca11b);

    // Z0 / R0: random Hadamard init (paper Table 23 note).
    let mut z = linalg::randomized_hadamard(n, &mut rng);
    let mut m = Mat::zeros(n, n);
    let mut v = Mat::zeros(n, n); // adam only
    let mut t = 0f32;

    let mut losses = Vec::with_capacity(cfg.steps);
    // dqlint::allow(wallclock-hygiene): Table 3 wall-cost readout only;
    // canonical() strips every timing field.
    let t0 = Instant::now();
    let mut steps_run = 0;
    for _ in 0..cfg.steps {
        let x = sample_tokens(x_pool, CALIB_TOKENS, &mut rng);
        let outputs = match cfg.optimizer {
            OptKind::Sgd => exe.run(&[
                Value::from_mat(&z),
                Value::from_mat(&m),
                Value::from_mat(&x),
                Value::scalar(cfg.lr),
            ])?,
            OptKind::Adam => exe.run(&[
                Value::from_mat(&z),
                Value::from_mat(&m),
                Value::from_mat(&v),
                Value::scalar(t),
                Value::from_mat(&x),
                Value::scalar(cfg.lr),
            ])?,
        };
        match cfg.optimizer {
            OptKind::Sgd => {
                z = outputs[0].to_mat()?;
                m = outputs[1].to_mat()?;
                losses.push(outputs[2].to_scalar()?);
            }
            OptKind::Adam => {
                z = outputs[0].to_mat()?;
                m = outputs[1].to_mat()?;
                v = outputs[2].to_mat()?;
                t = outputs[3].to_scalar()?;
                losses.push(outputs[4].to_scalar()?);
            }
        }
        steps_run += 1;
        if cfg.tol > 0.0 && losses.len() > 6 {
            let prev = losses[losses.len() - 6];
            let cur = *losses.last().unwrap();
            if (prev - cur).abs() / prev.abs().max(1e-9) < cfg.tol {
                break;
            }
        }
    }
    let wall = t0.elapsed();

    let rotation = match cfg.scheme {
        OrthScheme::QrOrth => linalg::qr_orthogonalize(&z), // same convention as the jax side
        OrthScheme::Cayley => z,
    };
    let defect = linalg::orthogonality_defect(&rotation);
    if defect > 5e-2 {
        bail!("calibrated rotation drifted off the manifold (defect {defect})");
    }
    Ok(CalibResult { rotation, losses, wall, steps_run })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gen;

    #[test]
    fn sample_tokens_geometry() {
        let mut rng = Pcg64::new(1);
        let pool = Mat::from_vec(10, 4, gen::vec_f32(&mut rng, 40));
        let s = sample_tokens(&pool, 4, &mut rng);
        assert_eq!(s.shape(), (4, 4));
        let up = sample_tokens(&pool, 32, &mut rng);
        assert_eq!(up.shape(), (32, 4));
    }

    #[test]
    fn objective_and_opt_names_match_artifacts() {
        assert_eq!(Objective::Whip.name(), "whip");
        assert_eq!(OptKind::Adam.name(), "adam");
        assert_eq!(Objective::ALL.len(), 4);
    }

    // PJRT-backed calibration loops are covered in rust/tests/ (they need
    // `make artifacts`).
}
