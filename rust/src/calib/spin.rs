//! SpinQuant-sim: the end-to-end fine-tuning baseline. One `spin_{cfg}`
//! artifact call = one Cayley step of the full quantized-forward task loss
//! with respect to R1, holding the entire model + backprop graph — the
//! cost Table 3 / Fig 1 contrasts with DartQuant's local calibration.

use crate::linalg;
use crate::model::{TokenBatch, Weights};
use crate::runtime::{Runtime, Value};
use crate::tensor::Mat;
use crate::util::prng::Pcg64;
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct SpinConfig {
    pub lr: f32,
    pub steps: usize,
    pub seed: u64,
}

impl Default for SpinConfig {
    fn default() -> Self {
        SpinConfig { lr: 1.5, steps: 16, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct SpinResult {
    pub r1: Mat,
    pub losses: Vec<f32>,
    pub wall: Duration,
}

/// Run the end-to-end Cayley fine-tuning of R1 on calibration batches
/// drawn by `next_batch` (one TokenBatch per step).
pub fn spin_calibrate(
    rt: &Runtime,
    weights: &Weights,
    cfg: &SpinConfig,
    mut next_batch: impl FnMut(usize) -> TokenBatch,
) -> Result<SpinResult> {
    let name = format!("spin_{}", weights.cfg.name);
    let exe = rt.load(&name).with_context(|| {
        format!("no spin artifact for {} (emitted for the llama2 configs)", weights.cfg.name)
    })?;
    let d = weights.cfg.dim;
    let mut rng = Pcg64::new(cfg.seed ^ 0x5917);
    let mut r1 = linalg::randomized_hadamard(d, &mut rng);
    let mut m = Mat::zeros(d, d);
    let mut losses = Vec::with_capacity(cfg.steps);
    // dqlint::allow(wallclock-hygiene): Table 3 wall-cost readout only;
    // canonical() strips every timing field.
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let toks = next_batch(step);
        let mut inputs = vec![Value::from_mat(&r1), Value::from_mat(&m)];
        inputs.extend(weights.ordered().map(|(_, w)| Value::from_mat(w)));
        inputs.push(toks.to_value());
        inputs.push(Value::scalar(cfg.lr));
        let out = exe.run(&inputs)?;
        r1 = out[0].to_mat()?;
        m = out[1].to_mat()?;
        losses.push(out[2].to_scalar()?);
    }
    let wall = t0.elapsed();
    // Cayley retraction is approximate (s = 2 fixed-point iterations);
    // re-project to the manifold exactly before fusing.
    let defect = linalg::orthogonality_defect(&r1);
    if defect > 1e-3 {
        r1 = linalg::qr_orthogonalize(&r1);
    }
    Ok(SpinResult { r1, losses, wall })
}

// PJRT-backed tests live in rust/tests/calibration.rs (need artifacts).
