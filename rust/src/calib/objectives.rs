//! Native objective implementations — rust mirrors of the L2 objectives,
//! used by tests (cross-checking the artifacts) and by the figure benches
//! (evaluating an objective on rotated activations without a PJRT call).

use crate::tensor::Mat;

/// Whip loss (Eq. 4), token-averaged: mean_t Σ_c exp(-|x_tc|).
pub fn whip(x: &Mat) -> f64 {
    let mut total = 0f64;
    for i in 0..x.rows {
        total += x.row(i).iter().map(|v| (-v.abs()).exp() as f64).sum::<f64>();
    }
    total / x.rows as f64
}

/// Mean per-token variance across channels.
pub fn variance(x: &Mat) -> f64 {
    let mut total = 0f64;
    for i in 0..x.rows {
        let row = x.row(i);
        let m = row.iter().sum::<f32>() as f64 / row.len() as f64;
        total += row.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / row.len() as f64;
    }
    total / x.rows as f64
}

/// Mean per-token excess kurtosis.
pub fn kurtosis(x: &Mat) -> f64 {
    let mut total = 0f64;
    for i in 0..x.rows {
        let row = x.row(i);
        let n = row.len() as f64;
        let m = row.iter().sum::<f32>() as f64 / n;
        let var = row.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / n;
        let m4 = row.iter().map(|&v| (v as f64 - m).powi(4)).sum::<f64>() / n;
        total += m4 / (var * var + 1e-12) - 3.0;
    }
    total / x.rows as f64
}

/// Mean squared int4 fake-quant error (per-token asymmetric).
pub fn quant_mse(x: &Mat, bits: u8) -> f64 {
    crate::eval::stats::quant_error(x, bits)
}

/// Evaluate a named objective.
pub fn evaluate(obj: super::Objective, x: &Mat) -> f64 {
    match obj {
        super::Objective::Whip => whip(x),
        super::Objective::Variance => variance(x),
        super::Objective::Kurtosis => kurtosis(x),
        super::Objective::Quant => quant_mse(x, 4),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn whip_of_zeros_is_channel_count() {
        let x = Mat::zeros(8, 32);
        assert!((whip(&x) - 32.0).abs() < 1e-9);
    }

    #[test]
    fn whip_decreases_as_values_leave_zero() {
        let near = Mat::from_vec(1, 4, vec![0.1; 4]);
        let far = Mat::from_vec(1, 4, vec![3.0; 4]);
        assert!(whip(&far) < whip(&near));
    }

    #[test]
    fn variance_and_kurtosis_match_definitions() {
        let x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((variance(&x) - 1.25).abs() < 1e-9);
        let mut rng = Pcg64::new(1);
        let g = Mat::from_fn(64, 512, |_, _| rng.normal());
        assert!(kurtosis(&g).abs() < 0.3, "gaussian kurtosis ~0: {}", kurtosis(&g));
        let l = Mat::from_fn(64, 512, |_, _| rng.laplace(1.0));
        assert!(kurtosis(&l) > 2.0, "laplace kurtosis ~3: {}", kurtosis(&l));
    }

    #[test]
    fn whip_is_norm_constrained_proxy_for_outliers() {
        // Among equal-norm vectors, the uniform one minimizes whip.
        let spiky = Mat::from_vec(1, 4, vec![2.0, 0.0, 0.0, 0.0]);
        let uniform = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        assert!((spiky.fro_norm() - uniform.fro_norm()).abs() < 1e-6);
        assert!(whip(&uniform) < whip(&spiky));
    }
}
