//! `dartquant` — command-line launcher for the DartQuant reproduction.
//!
//! Subcommands:
//!   calibrate  run rotation calibration for one model (prints loss curve)
//!   quantize   staged pipeline (capture → calibrate → fuse → quantize),
//!              driven through `Pipeline::builder` with a progress observer
//!   eval       PPL + zero-shot evaluation of a checkpoint (or fresh model)
//!   pipeline   quantize + eval in one go, printing a paper-style row
//!              (`--json` emits the machine-readable PipelineReport row)
//!   generate   autoregressive generation via the KV-cached decode path
//!              (single session, or continuous batching at --sessions N;
//!              --speculate drafts from a packed low-bit copy of the model)
//!   serve-bench  continuous-batching throughput benchmark
//!   train      train the tiny config on a synthetic dialect (AOT Adam step)
//!   info       artifacts, models, registered methods, runtime platform
//!
//! Methods are resolved by name through `coordinator::MethodRegistry`.

use anyhow::{bail, Result};
use dartquant::calib::CalibConfig;
use dartquant::coordinator::{
    self, Method, MethodRegistry, Pipeline, PipelineConfig, PrintObserver, WeightQuant,
};
use dartquant::data::{Corpus, Dialect};
use dartquant::eval::{self, EvalSpec};
use dartquant::model::{BitSetting, ModelConfig, TokenBatch, TrainState, Weights};
use dartquant::runtime::Runtime;
use dartquant::util::bench::{fnum, percentile, Table};
use dartquant::util::cli::Command;
use dartquant::util::fmt_duration;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_model(args: &dartquant::util::cli::Args) -> Result<(ModelConfig, Weights, Corpus)> {
    let name = args.get_or("model", "llama2-tiny");
    let cfg = ModelConfig::builtin(name)?;
    let dialect = Dialect::parse(args.get_or("dialect", "wiki"))?;
    let corpus = Corpus::new(dialect, cfg.vocab, 7);
    let weights = match args.get("checkpoint") {
        Some(path) => Weights::load(std::path::Path::new(path))?,
        None => Weights::default_grammar(&cfg, 1, corpus.successor())?,
    };
    Ok((cfg, weights, corpus))
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd {
        "calibrate" => cmd_calibrate(rest),
        "quantize" => cmd_quantize(rest),
        "eval" => cmd_eval(rest),
        "pipeline" => cmd_pipeline(rest),
        "generate" => cmd_generate(rest),
        "serve-bench" => cmd_serve_bench(rest),
        "train" => cmd_train(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", help_text()),
    }
}

fn help_text() -> String {
    format!(
        "dartquant — rotational distribution calibration for LLM quantization\n\
         \n\
         commands:\n\
           calibrate   run rotation calibration, print the loss curve\n\
           quantize    staged pipeline (capture → calibrate → fuse → quantize),\n\
                       save the quantized checkpoint\n\
           eval        PPL + zero-shot of a model/checkpoint\n\
           pipeline    quantize + eval, print a paper-style row (--json for a\n\
                       machine-readable PipelineReport row)\n\
           generate    KV-cached autoregressive generation (continuous\n\
                       batching at --sessions N, --speculate for\n\
                       self-speculative decoding)\n\
           serve-bench continuous-batching throughput benchmark\n\
           train       train the tiny config (AOT Adam step)\n\
           info        artifacts + models + registered methods + platform\n\
         \n\
         methods are resolved by name through the MethodRegistry (rotation\n\
         strategy × weight quantizer): {}",
        MethodRegistry::builtin().names().join(", ")
    )
}

fn print_help() {
    println!("{}", help_text());
}

fn cmd_calibrate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("calibrate", "run rotation calibration for one model")
        .flag_default("model", "llama2-tiny", "model config")
        .flag_default("dialect", "wiki", "calibration dialect (wiki|ptb|c4)")
        .flag_default("steps", "60", "optimizer steps")
        .flag_default("lr", "0.01", "learning rate")
        .flag_default("objective", "whip", "whip|variance|kurtosis|quant")
        .flag_default("scheme", "qr", "qr|cayley")
        .flag_default("sequences", "32", "calibration sequences")
        .flag("checkpoint", "load weights from a checkpoint file");
    let a = cmd.parse(argv)?;
    let (_cfg, weights, corpus) = load_model(&a)?;
    let rt = Runtime::open(Runtime::default_dir())?;
    let seqs = corpus.calib_sequences(a.get_usize("sequences", 32)?, 256);
    let pools = coordinator::capture_pools(&rt, &weights, &seqs, 0.1, 0)?;
    let ccfg = CalibConfig {
        objective: match a.get_or("objective", "whip") {
            "whip" => dartquant::calib::Objective::Whip,
            "variance" => dartquant::calib::Objective::Variance,
            "kurtosis" => dartquant::calib::Objective::Kurtosis,
            "quant" => dartquant::calib::Objective::Quant,
            o => bail!("unknown objective {o}"),
        },
        scheme: match a.get_or("scheme", "qr") {
            "qr" => dartquant::calib::OrthScheme::QrOrth,
            "cayley" => dartquant::calib::OrthScheme::Cayley,
            o => bail!("unknown scheme {o}"),
        },
        steps: a.get_usize("steps", 60)?,
        lr: a.get_f64("lr", 0.01)? as f32,
        ..Default::default()
    };
    println!(
        "calibrating R1 on {} pooled activation rows (dim {})",
        pools.r1_pool.rows, pools.r1_pool.cols
    );
    let res = dartquant::calib::calibrate_rotation(&rt, &pools.r1_pool, &ccfg)?;
    for (i, l) in res.losses.iter().enumerate() {
        if i % 5 == 0 || i + 1 == res.losses.len() {
            println!("step {i:4}  loss {l:.4}");
        }
    }
    println!(
        "done in {} ({} steps); orthogonality defect {:.2e}",
        fmt_duration(res.wall),
        res.steps_run,
        dartquant::linalg::orthogonality_defect(&res.rotation)
    );
    Ok(())
}

fn pipeline_config(a: &dartquant::util::cli::Args) -> Result<PipelineConfig> {
    let method = Method::parse(a.get_or("method", "dartquant"))?;
    let bits = BitSetting::parse(a.get_or("bits", "4-4-16"))?;
    let mut cfg = PipelineConfig::new(method, bits);
    cfg.calib_dialect = Dialect::parse(a.get_or("dialect", "wiki"))?;
    cfg.calib_sequences = a.get_usize("sequences", 32)?;
    cfg.calib.steps = a.get_usize("steps", 60)?;
    cfg.workers = a.get_usize("workers", cfg.workers)?;
    cfg.shards = a.get_usize("shards", 1)?.max(1);
    cfg.packed = a.get_bool("packed");
    cfg.weight_quant = WeightQuant::parse(a.get_or("wquant", "gptq"))?;
    if a.get_bool("budget-3090") {
        cfg.memory_budget = Some(24 << 20);
    }
    if let Some(b) = a.get("budget-bytes") {
        cfg.memory_budget = Some(b.parse()?);
    }
    cfg.streaming = a.get_bool("streaming");
    if let Some(b) = a.get("resident-budget") {
        cfg.resident_budget = Some(b.parse()?);
    }
    Ok(cfg)
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let cmd = Command::new("quantize", "run the full quantization pipeline")
        .flag_default("model", "llama2-tiny", "model config")
        .flag_default("method", "dartquant", "rtn|smoothquant|gptq|quarot|spinquant|ostquant|dartquant")
        .flag_default("bits", "4-4-16", "W-A-KV bit setting")
        .flag_default("dialect", "wiki", "calibration dialect")
        .flag_default("sequences", "32", "calibration sequences")
        .flag_default("steps", "60", "calibration steps")
        .flag_default("workers", "0", "calibration worker threads (0 = all cores)")
        .flag_default("shards", "1", "within-layer shards per quantize job (bit-identical)")
        .flag_default("wquant", "gptq", "weight quantizer for rotation methods (rtn|gptq)")
        .flag("out", "write the quantized checkpoint here")
        .flag("checkpoint", "load base weights from a checkpoint")
        .flag("budget-bytes", "memory budget for calibration jobs")
        .flag("resident-budget", "resident weight-byte budget for --streaming runs")
        .switch("budget-3090", "scaled single-3090 memory budget (24 MiB)")
        .switch("streaming", "out-of-core run: stage weights through an on-disk store")
        .switch("packed", "store quantized linears as packed low-bit codes (true footprint)");
    let a = cmd.parse(argv)?;
    let (_cfg, weights, _corpus) = load_model(&a)?;
    let rt = Runtime::open(Runtime::default_dir())?;
    let pcfg = pipeline_config(&a)?;
    println!(
        "pipeline: {} {} on {} ({} params)",
        pcfg.method.name(),
        pcfg.bits.label(),
        weights.cfg.name,
        weights.cfg.n_params()
    );
    let report = Pipeline::builder(&weights)
        .config(pcfg)
        .observer(Arc::new(PrintObserver))
        .run(&rt)?;
    let s = &report.stats;
    println!(
        "capture {} | calibrate {} | fuse {} | quantize {} | total {} | peak job bytes {}",
        fmt_duration(s.capture_time),
        fmt_duration(s.calibrate_time),
        fmt_duration(s.fuse_time),
        fmt_duration(s.quantize_time),
        fmt_duration(s.total_time),
        s.peak_job_bytes
    );
    println!(
        "model bytes {} | linear compression {:.2}x",
        report.model_bytes,
        report.compression_ratio()
    );
    if report.stats.peak_weight_bytes > 0 {
        println!(
            "streamed: peak resident weight bytes {} (budget {})",
            report.stats.peak_weight_bytes,
            a.get("resident-budget").unwrap_or("unlimited")
        );
    }
    if let Some(out) = a.get("out") {
        report.weights.save(std::path::Path::new(out))?;
        if report.weights.has_packed() {
            println!(
                "saved quantized checkpoint to {out} (packed codes + scales, true low-bit footprint)"
            );
        } else {
            println!("saved quantized checkpoint to {out}");
        }
    }
    Ok(())
}

fn eval_row(
    rt: &Runtime,
    weights: &Weights,
    bits: BitSetting,
    use_had: bool,
    items: usize,
) -> Result<(f64, f64, f64, f64, f64)> {
    if weights.has_packed() {
        // Packed weights can't feed the f32 artifacts: run the native
        // quantized forward (integer path on the packed linears).
        return Ok(eval_row_native(weights, bits, use_had, items));
    }
    let spec = EvalSpec::default();
    let (a_lv, kv_lv) = (BitSetting::levels(bits.a), BitSetting::levels(bits.kv));
    let mut ppls = Vec::new();
    for d in Dialect::ALL {
        let corpus = Corpus::new(d, weights.cfg.vocab, 7);
        ppls.push(eval::ppl_artifact(rt, weights, &corpus, spec, a_lv, kv_lv, use_had)?);
    }
    let (_per_task, zs) = eval::zeroshot::suite_accuracy_artifact(
        rt, weights, Dialect::Wiki, items, 256, 99, a_lv, kv_lv, use_had,
    )?;
    Ok((ppls[0], ppls[1], ppls[2], (ppls[0] + ppls[1] + ppls[2]) / 3.0, zs * 100.0))
}

fn eval_row_native(
    weights: &Weights,
    bits: BitSetting,
    use_had: bool,
    items: usize,
) -> (f64, f64, f64, f64, f64) {
    let spec = EvalSpec::default();
    let opt = dartquant::model::FwdOptions::quant(bits.a, bits.kv, use_had);
    let mut ppls = Vec::new();
    for d in Dialect::ALL {
        let corpus = Corpus::new(d, weights.cfg.vocab, 7);
        ppls.push(eval::ppl_native(weights, &corpus, spec, opt));
    }
    let (_per_task, zs) =
        eval::zeroshot::suite_accuracy_native(weights, Dialect::Wiki, items, 256, 99, opt);
    (ppls[0], ppls[1], ppls[2], (ppls[0] + ppls[1] + ppls[2]) / 3.0, zs * 100.0)
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cmd = Command::new("eval", "PPL + zero-shot evaluation")
        .flag_default("model", "llama2-tiny", "model config")
        .flag_default("bits", "16-16-16", "W-A-KV (activations/KV applied at eval)")
        .flag_default("items", "8", "zero-shot items per task")
        .flag_default("dialect", "wiki", "model grammar dialect")
        .flag("checkpoint", "evaluate this checkpoint")
        .switch("online-had", "enable online R3/R4 hadamard (rotated ckpts)");
    let a = cmd.parse(argv)?;
    let (_cfg, weights, _corpus) = load_model(&a)?;
    let rt = Runtime::open(Runtime::default_dir())?;
    let bits = BitSetting::parse(a.get_or("bits", "16-16-16"))?;
    let (wiki, ptb, c4, avg, zs) = eval_row(
        &rt,
        &weights,
        bits,
        a.get_bool("online-had"),
        a.get_usize("items", 8)?,
    )?;
    let mut t = Table::new(&["Wiki", "PTB", "C4", "Avg PPL", "0-shot9"]);
    t.row(&[fnum(wiki, 2), fnum(ptb, 2), fnum(c4, 2), fnum(avg, 2), fnum(zs, 2)]);
    t.print(&format!("{} @ {}", weights.cfg.name, bits.label()));
    Ok(())
}

fn cmd_pipeline(argv: &[String]) -> Result<()> {
    let cmd = Command::new("pipeline", "quantize + eval, print a paper-style row")
        .flag_default("model", "llama2-tiny", "model config")
        .flag_default("method", "dartquant", "quantization method")
        .flag_default("bits", "4-4-16", "W-A-KV bit setting")
        .flag_default("dialect", "wiki", "calibration dialect")
        .flag_default("sequences", "32", "calibration sequences")
        .flag_default("steps", "60", "calibration steps")
        .flag_default("workers", "0", "scheduler worker threads (0 = all cores)")
        .flag_default("shards", "1", "within-layer shards per quantize job (bit-identical)")
        .flag_default("items", "8", "zero-shot items per task")
        .flag_default("wquant", "gptq", "weight quantizer for rotation methods (rtn|gptq)")
        .flag("checkpoint", "base weights checkpoint")
        .flag("budget-bytes", "memory budget")
        .flag("resident-budget", "resident weight-byte budget for --streaming runs")
        .switch("budget-3090", "scaled 3090 budget")
        .switch("streaming", "out-of-core run: stage weights through an on-disk store")
        .switch("packed", "packed low-bit weight storage + native integer-forward eval")
        .switch("json", "print a machine-readable PipelineReport row")
        .switch("canonical", "print the run-invariant report row (implies --json): timings and peak bytes stripped, byte-identical at any --workers");
    let a = cmd.parse(argv)?;
    let (_cfg, weights, _corpus) = load_model(&a)?;
    let rt = Runtime::open(Runtime::default_dir())?;
    let pcfg = pipeline_config(&a)?;
    let bits = pcfg.bits;
    let json = a.get_bool("json") || a.get_bool("canonical");
    let mut builder = Pipeline::builder(&weights).config(pcfg);
    if !json {
        builder = builder.observer(Arc::new(PrintObserver));
    }
    let report = builder.run(&rt)?;
    if json {
        if a.get_bool("canonical") {
            println!("{}", report.record().canonical().to_json());
        } else {
            println!("{}", report.to_json());
        }
        return Ok(());
    }
    let use_had = report.rotation.as_ref().map(|r| r.online_had).unwrap_or(false);
    let (wiki, ptb, c4, avg, zs) =
        eval_row(&rt, &report.weights, bits, use_had, a.get_usize("items", 8)?)?;
    let mut t = Table::new(&[
        "Method", "Bits", "Wiki", "PTB", "C4", "Avg", "0-shot9", "weight bytes", "calib time",
    ]);
    t.row(&[
        report.method.clone(),
        bits.label(),
        fnum(wiki, 2),
        fnum(ptb, 2),
        fnum(c4, 2),
        fnum(avg, 2),
        fnum(zs, 2),
        format!("{} ({:.1}x)", report.model_bytes, report.compression_ratio()),
        fmt_duration(report.stats.calibrate_time),
    ]);
    t.print(&format!("{} pipeline", weights.cfg.name));
    Ok(())
}

/// RTN-quantize weights for serving when the bit setting asks for it
/// (`--packed` stores the linears as integer codes + scales).
fn serving_weights(weights: Weights, bits: BitSetting, packed: bool) -> Weights {
    if bits.w >= 16 {
        weights
    } else if packed {
        dartquant::quant::rtn_quantize_model_packed(&weights, bits.w)
    } else {
        dartquant::quant::rtn_quantize_model(&weights, bits.w)
    }
}

fn serving_flags(cmd: Command) -> Command {
    cmd.flag_default("bits", "16-16-16", "W-A-KV bit setting (W<16 ⇒ RTN weight quant)")
        .flag_default("max-new", "48", "tokens to generate per session")
        .flag_default("temperature", "0", "sampling temperature (0 = greedy)")
        .flag_default("seed", "0", "base sampling seed (per-session streams derive from it)")
        .flag_default("workers", "0", "engine step worker threads (0 = all cores)")
        .flag_default("shards", "1", "within-layer shards per linear/attention (bit-identical)")
        .flag("checkpoint", "load weights from a checkpoint file")
        .flag("budget-bytes", "KV-cache admission budget in bytes")
        .switch("budget-3090", "scaled single-3090 KV budget (24 MiB)")
        .switch("packed", "packed low-bit weight storage (integer decode path)")
        .switch("online-had", "enable online R3/R4 hadamard (rotated ckpts)")
        .flag_default("page-size", "0", "paged KV cache, positions per page (0 = contiguous)")
        .switch("spill", "paged mode: evict cold KV pages to a temp spill file under pressure")
        .switch(
            "speculate",
            "self-speculative decoding: a packed low-bit draft of the same checkpoint \
             proposes, this precision verifies (greedy output identical)",
        )
        .flag_default("draft-bits", "4", "draft weight/activation bits for --speculate")
        .flag_default("spec-k", "4", "draft tokens proposed per speculative round")
}

/// Everything `generate` and `serve-bench` share after flag parsing:
/// serving weights (RTN-quantized when W < 16), the prompt corpus, the
/// parsed bit setting, the engine config, and — under `--speculate` —
/// the packed low-bit draft quantized from the same base checkpoint.
struct ServeSetup {
    weights: Arc<Weights>,
    corpus: Corpus,
    bits: BitSetting,
    ecfg: dartquant::serve::EngineConfig,
    draft: Option<(Arc<Weights>, dartquant::model::FwdOptions)>,
}

fn serving_setup(a: &dartquant::util::cli::Args) -> Result<ServeSetup> {
    let (_cfg, weights, corpus) = load_model(a)?;
    let bits = BitSetting::parse(a.get_or("bits", "16-16-16"))?;
    if a.get_bool("packed") && bits.w >= 16 {
        eprintln!(
            "note: --packed has no effect at W=16 weights — pass e.g. --bits 4-4-16 \
             to quantize and pack the linears"
        );
    }
    let shards = a.get_usize("shards", 1)?;
    let use_had = a.get_bool("online-had");
    // The draft is cut from the *base* checkpoint (before the verifier's
    // own serving quantization) so both precisions come from one model —
    // the self-speculative setup. Its KV grid stays the serving KV grid;
    // only weights and activations drop to --draft-bits.
    let draft = if a.get_bool("speculate") {
        let draft_bits = u8::try_from(a.get_usize("draft-bits", 4)?)?;
        let dw = dartquant::quant::rtn_quantize_model_packed(&weights, draft_bits);
        let dopt = dartquant::model::FwdOptions::quant(draft_bits, bits.kv, use_had)
            .with_shards(shards);
        Some((Arc::new(dw), dopt))
    } else {
        None
    };
    let weights = serving_weights(weights, bits, a.get_bool("packed"));
    let mut budget = None;
    if a.get_bool("budget-3090") {
        budget = Some(24 << 20);
    }
    if let Some(b) = a.get("budget-bytes") {
        budget = Some(b.parse()?);
    }
    let page_size = a.get_usize("page-size", 0)?;
    if a.get_bool("spill") && page_size == 0 {
        bail!("--spill needs paged mode — pass --page-size N");
    }
    let paged = (page_size > 0).then(|| dartquant::serve::PagedConfig {
        page_positions: page_size,
        spill: a.get_bool("spill"),
    });
    let spec_k = a.get_usize("spec-k", 4)?.max(1);
    let ecfg = dartquant::serve::EngineConfig {
        opt: dartquant::model::FwdOptions::quant(bits.a, bits.kv, use_had).with_shards(shards),
        seed: a.get_usize("seed", 0)? as u64,
        temperature: a.get_f64("temperature", 0.0)? as f32,
        workers: a.get_usize("workers", 0)?,
        budget,
        max_sessions: 0,
        paged,
        speculate: a.get_bool("speculate").then_some(dartquant::serve::SpecConfig { k: spec_k }),
    };
    Ok(ServeSetup { weights: Arc::new(weights), corpus, bits, ecfg, draft })
}

/// Build the engine both serving commands drive: construct it over the
/// shared setup, install the draft model when speculating, and submit
/// `sessions` dialect prompts (`prompt_len + i·stagger` tokens each) —
/// the session-submission block `generate` and `serve-bench` used to
/// duplicate.
fn serving_engine(
    setup: &ServeSetup,
    sessions: usize,
    prompt_len: usize,
    stagger: usize,
    max_new: usize,
) -> dartquant::serve::BatchEngine {
    let mut engine = dartquant::serve::BatchEngine::new(Arc::clone(&setup.weights), setup.ecfg);
    if let Some((dw, dopt)) = &setup.draft {
        engine.set_draft(Arc::clone(dw), *dopt);
    }
    for i in 0..sessions {
        let prompt = setup.corpus.sequence(prompt_len + i * stagger, 2, i as u64);
        engine.submit(dartquant::serve::GenRequest { prompt, max_new });
    }
    engine
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let cmd = serving_flags(
        Command::new("generate", "autoregressive generation (KV-cached decode)")
            .flag_default("model", "llama2-tiny", "model config")
            .flag_default("dialect", "wiki", "model grammar dialect")
            .flag_default("prompt-len", "16", "prompt tokens (sampled from the dialect corpus)")
            .flag_default("sessions", "1", "concurrent sessions (continuous batching when > 1)"),
    );
    let a = cmd.parse(argv)?;
    let setup = serving_setup(&a)?;
    let (weights, ecfg, bits) = (&setup.weights, setup.ecfg, setup.bits);
    let prompt_len = a.get_usize("prompt-len", 16)?.max(1);
    let max_new = a.get_usize("max-new", 48)?.max(1);
    let sessions = a.get_usize("sessions", 1)?.max(1);
    println!(
        "generate: {} @ {} | prompt {} | max-new {} | sessions {}{}{}",
        weights.cfg.name,
        bits.label(),
        prompt_len,
        max_new,
        sessions,
        if weights.has_packed() { " | packed weights" } else { "" },
        ecfg.speculate.map(|s| format!(" | speculative k={}", s.k)).unwrap_or_default()
    );
    if sessions == 1 {
        // Single session: drive the session types directly so prefill
        // and decode throughput are separately visible. The budget flags
        // still apply — enforce the same full-lifetime cache check the
        // engine's admission gate performs (both caches of a speculative
        // pair).
        let prompt = setup.corpus.sequence(prompt_len, 2, 0);
        if let Some(budget) = ecfg.budget {
            let one = |kv_levels: f32| {
                dartquant::serve::request_cache_bytes(&weights.cfg, kv_levels, prompt_len, max_new)
            };
            let mut need = one(ecfg.opt.kv_levels);
            if ecfg.speculate.is_some() {
                need += one(setup.draft.as_ref().map_or(ecfg.opt.kv_levels, |(_, o)| o.kv_levels));
            }
            if need > budget {
                bail!("session needs {need} KV-cache bytes but the budget is {budget}");
            }
        }
        let mut rng = dartquant::util::prng::Pcg64::new(ecfg.seed);
        if let Some(sc) = ecfg.speculate {
            // Speculative pair: begin (both prefills) then whole rounds.
            let (dw, dopt) = setup
                .draft
                .as_ref()
                .map(|(w, o)| (Arc::clone(w), *o))
                .unwrap_or_else(|| (Arc::clone(weights), ecfg.opt));
            let draft = dartquant::serve::DecodeSession::new(dw, dopt);
            let verifier = dartquant::serve::DecodeSession::new(Arc::clone(weights), ecfg.opt);
            let mut spec = dartquant::serve::SpecSession::new(draft, verifier, sc.k);
            // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
            let t0 = std::time::Instant::now();
            let first = spec.begin(&prompt, ecfg.temperature, &mut rng)?;
            let prefill_wall = t0.elapsed();
            let mut generated = vec![first];
            // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
            let t1 = std::time::Instant::now();
            while generated.len() < max_new {
                let left = max_new - generated.len();
                generated.extend(spec.round(ecfg.temperature, &mut rng, left)?);
            }
            let decode_wall = t1.elapsed();
            let st = spec.stats();
            println!("prompt     {:?}", prompt);
            println!("generated  {:?}", generated);
            println!(
                "prefill ×2 in {} | decode {} tok in {} ({:.0} tok/s) | {} rounds, accept {:.0}%, {:.2} tok/round | kv cache {} bytes",
                fmt_duration(prefill_wall),
                generated.len().saturating_sub(1),
                fmt_duration(decode_wall),
                generated.len().saturating_sub(1) as f64 / decode_wall.as_secs_f64().max(1e-9),
                st.rounds,
                100.0 * st.accept_rate(),
                st.tokens_per_round(),
                spec.cache_nbytes()
            );
            return Ok(());
        }
        let mut sess = dartquant::serve::DecodeSession::new(Arc::clone(weights), ecfg.opt);
        // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
        let t0 = std::time::Instant::now();
        let last = sess.prefill_last(&prompt);
        let prefill_wall = t0.elapsed();
        let mut tok = dartquant::serve::sample_logits(&last, ecfg.temperature, &mut rng) as i32;
        let mut generated = vec![tok];
        // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
        let t1 = std::time::Instant::now();
        for _ in 1..max_new {
            let row = sess.step(tok);
            tok = dartquant::serve::sample_logits(&row, ecfg.temperature, &mut rng) as i32;
            generated.push(tok);
        }
        let decode_wall = t1.elapsed();
        println!("prompt     {:?}", prompt);
        println!("generated  {:?}", generated);
        println!(
            "prefill {} tok in {} ({:.0} tok/s) | decode {} tok in {} ({:.0} tok/s) | kv cache {} bytes",
            prompt.len(),
            fmt_duration(prefill_wall),
            prompt.len() as f64 / prefill_wall.as_secs_f64().max(1e-9),
            generated.len().saturating_sub(1),
            fmt_duration(decode_wall),
            generated.len().saturating_sub(1) as f64 / decode_wall.as_secs_f64().max(1e-9),
            sess.cache_nbytes()
        );
        return Ok(());
    }
    let mut engine = serving_engine(&setup, sessions, prompt_len, 0, max_new);
    // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
    let t0 = std::time::Instant::now();
    let results = engine.run()?.to_vec();
    let wall = t0.elapsed();
    for r in &results {
        match &r.error {
            Some(e) => println!("session {:3}  FAILED: {e}", r.id),
            None => println!("session {:3}  {:?}", r.id, r.tokens),
        }
    }
    let total: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "{} sessions | {} tokens in {} ({:.0} tok/s) | {} engine steps | peak kv cache {} bytes{}",
        results.len(),
        total,
        fmt_duration(wall),
        total as f64 / wall.as_secs_f64().max(1e-9),
        engine.steps(),
        engine.peak_cache_bytes(),
        engine
            .spec_stats()
            .map(|s| format!(
                " | accept {:.0}%, {:.2} tok/round",
                100.0 * s.accept_rate(),
                s.tokens_per_round()
            ))
            .unwrap_or_default()
    );
    Ok(())
}

fn cmd_serve_bench(argv: &[String]) -> Result<()> {
    let cmd = serving_flags(
        Command::new("serve-bench", "continuous-batching throughput benchmark")
            .flag_default("model", "llama2-tiny", "model config")
            .flag_default("dialect", "wiki", "model grammar dialect")
            .flag_default("prompt-len", "32", "base prompt length")
            .flag_default("sessions", "8", "requests to submit")
            .flag_default("stagger", "8", "extra prompt tokens per successive request"),
    );
    let a = cmd.parse(argv)?;
    let setup = serving_setup(&a)?;
    let (ecfg, bits) = (setup.ecfg, setup.bits);
    let prompt_len = a.get_usize("prompt-len", 32)?.max(1);
    let sessions = a.get_usize("sessions", 8)?.max(1);
    let stagger = a.get_usize("stagger", 8)?;
    let max_new = a.get_usize("max-new", 48)?;
    let model_name = setup.weights.cfg.name.clone();
    let mut engine = serving_engine(&setup, sessions, prompt_len, stagger, max_new);
    // Step by hand (instead of engine.run) so per-step latency is
    // visible — the p99 column is the tentpole's tail-latency claim.
    // dqlint::allow(wallclock-hygiene): CLI throughput readout, never in canonical reports
    let t0 = std::time::Instant::now();
    let mut step_wall: Vec<std::time::Duration> = Vec::new();
    loop {
        // dqlint::allow(wallclock-hygiene): CLI step-latency readout, never in canonical reports
        let s0 = std::time::Instant::now();
        let more = engine.step()?;
        if engine.steps() > step_wall.len() {
            step_wall.push(s0.elapsed()); // idle admission-only ticks don't count
        }
        if !more {
            break;
        }
    }
    let wall = t0.elapsed();
    let results = engine.results().to_vec();
    let ok = results.iter().filter(|r| r.error.is_none()).count();
    let total: usize = results.iter().map(|r| r.tokens.len()).sum();
    step_wall.sort_unstable();
    let p99 = percentile(&step_wall, 0.99).unwrap_or_default();
    // Sessions-per-GB headline: peak concurrency over the gate budget
    // (or, unlimited, over the peak bytes actually charged).
    let denom_bytes = ecfg.budget.unwrap_or_else(|| engine.peak_cache_bytes());
    let sess_per_gb = if denom_bytes == 0 {
        "n/a".to_string()
    } else {
        fnum(engine.peak_concurrent() as f64 / dartquant::util::mem::gib(denom_bytes), 1)
    };
    let prefix_hit = engine
        .pager_stats()
        .map(|s| format!("{:.0}%", 100.0 * s.prefix_hit_rate()))
        .unwrap_or_else(|| "-".to_string());
    let accept = engine
        .spec_stats()
        .map(|s| format!("{:.0}%", 100.0 * s.accept_rate()))
        .unwrap_or_else(|| "-".to_string());
    let mut t = Table::new(&[
        "sessions",
        "ok",
        "steps",
        "tokens",
        "wall",
        "tok/s",
        "p99 step",
        "sess/GB",
        "peak kv bytes",
        "budget",
        "prefix hit",
        "accept",
    ]);
    t.row(&[
        sessions.to_string(),
        ok.to_string(),
        engine.steps().to_string(),
        total.to_string(),
        fmt_duration(wall),
        fnum(total as f64 / wall.as_secs_f64().max(1e-9), 0),
        fmt_duration(p99),
        sess_per_gb,
        engine.peak_cache_bytes().to_string(),
        ecfg.budget.map(|b| b.to_string()).unwrap_or_else(|| "unlimited".to_string()),
        prefix_hit,
        accept,
    ]);
    let mode = ecfg
        .paged
        .map(|p| format!("paged P={}{}", p.page_positions, if p.spill { "+spill" } else { "" }))
        .unwrap_or_else(|| "contiguous".to_string());
    let spec = ecfg.speculate.map(|s| format!(", spec k={}", s.k)).unwrap_or_default();
    t.print(&format!(
        "{model_name} serve-bench @ {} (workers {}, {mode}{spec})",
        bits.label(),
        ecfg.workers
    ));
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train the tiny config via the AOT Adam step")
        .flag_default("model", "llama2-tiny", "model config (must have a train artifact)")
        .flag_default("dialect", "wiki", "training dialect")
        .flag_default("steps", "100", "training steps")
        .flag_default("lr", "0.0015", "learning rate")
        .flag("out", "write the trained checkpoint here")
        .switch("from-scratch", "random init instead of the grammar init");
    let a = cmd.parse(argv)?;
    let name = a.get_or("model", "llama2-tiny");
    let cfg = ModelConfig::builtin(name)?;
    let dialect = Dialect::parse(a.get_or("dialect", "wiki"))?;
    let corpus = Corpus::new(dialect, cfg.vocab, 7);
    let weights = if a.get_bool("from-scratch") {
        Weights::default_synthetic(&cfg, 1)
    } else {
        Weights::default_grammar(&cfg, 1, corpus.successor())?
    };
    let rt = Runtime::open(Runtime::default_dir())?;
    let steps = a.get_usize("steps", 100)?;
    let lr = a.get_f64("lr", 0.0015)? as f32;
    let mut state = TrainState::new(weights);
    for step in 0..steps {
        let toks = TokenBatch::new(&corpus.train_batch(8, 256, step as u64));
        let loss = state.step(&rt, &toks, lr)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:4}  loss {loss:.4}  ppl {:.2}", (loss as f64).exp());
        }
    }
    if let Some(out) = a.get("out") {
        state.weights.save(std::path::Path::new(out))?;
        println!("saved checkpoint to {out}");
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifacts + models + registered methods + platform");
    let _a = cmd.parse(argv)?;
    println!("registered methods (rotation strategy × weight quantizer):");
    for spec in MethodRegistry::builtin().specs() {
        println!(
            "  {:14} rotation={:18} quantizer={}{}{}",
            spec.name,
            spec.rotation.name(),
            spec.quantizer.as_ref().map(|q| q.name().to_string()).unwrap_or("<config>".into()),
            if spec.smooth { " +smooth" } else { "" },
            if spec.aliases.is_empty() {
                String::new()
            } else {
                format!("  (aliases: {})", spec.aliases.join(", "))
            }
        );
    }
    println!("\nmodels:");
    for cfg in ModelConfig::all_builtin() {
        println!(
            "  {:13} d={} L={} heads={}/{} ffn={} vocab={} params={:.1}M  — {}",
            cfg.name,
            cfg.dim,
            cfg.n_layers,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.ffn_dim,
            cfg.vocab,
            cfg.n_params() as f64 / 1e6,
            cfg.paper_name()
        );
    }
    if Runtime::artifacts_available() {
        let rt = Runtime::open(Runtime::default_dir())?;
        println!("\nruntime platform: {}", rt.platform());
        println!("artifacts ({}):", rt.manifest().len());
        for name in rt.manifest().names() {
            println!("  {name}");
        }
    } else {
        println!("\nartifacts/ not built — run `make artifacts`");
    }
    Ok(())
}
