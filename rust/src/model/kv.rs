//! Per-layer K/V row storage — the cache type the shared block body
//! (`forward::block_step`) reads and extends.
//!
//! [`LayerKv`] holds the K and V rows of every cached position, indexed
//! `(position, kv head)` with `head_dim` values per row. Rows enter
//! through [`LayerKv::set_k`] / [`LayerKv::set_v`] **raw** (post-RoPE,
//! post-online-R3 for K) and are KV-fake-quantized at the cache boundary
//! — the same per-row asymmetric grid (`forward::fq_row_grid`) the
//! full-sequence oracle applies, so a cached row reads back bit-identical
//! to what `forward_one` attends over.
//!
//! Two storage modes, both provided by the reusable [`RowStore`]:
//!
//! * **f32** — rows stored as (fake-quantized) f32 values; the oracle
//!   layout, and the only representable one for fp / wide KV grids.
//! * **code** (`compact` + `kv_levels` ≤ 256) — u8 codes plus one
//!   `(mn, scale)` grid per row. Decoding evaluates
//!   `code as f32 * scale + mn`, the very expression the fake-quant
//!   kernel produces, so the dequantized row is **bit-identical** to the
//!   f32 mode at ≤ 8-bit KV settings while holding ~4× fewer bytes.
//!   Constant rows (which the fake-quant kernel leaves untouched) store
//!   `scale = 0` and decode every code to `mn` exactly. The one carve-out
//!   from bit-identity: a row containing NaN/∞ has no finite code grid
//!   and decodes **all-NaN** (the f32 store keeps only the poisoned
//!   elements non-finite) — blow-ups surface either way instead of being
//!   silently clamped.
//!
//! `block_step` consumes the cache through the [`KvSlot`] trait, so the
//! same block body serves both this contiguous layout and the paged
//! layout in `serve::pager` (whose spill path round-trips pages through
//! [`RowStore::to_bytes`] / [`RowStore::from_bytes`] bit-exactly).
//!
//! The serving layer aggregates one `LayerKv` per layer into
//! `serve::KvCache` (which also owns the engine's byte accounting); see
//! `docs/SERVING.md`.

use super::config::ModelConfig;
use super::forward::{fake_quant_row, fq_row_grid};
use crate::tensor::Mat;
use anyhow::{bail, Result};

/// Largest level count representable by the u8 code storage.
const CODE_LEVELS_MAX: f32 = 256.0;

/// Whether `(levels, compact)` selects the u8 code layout.
fn use_codes(levels: f32, compact: bool) -> bool {
    compact && levels <= CODE_LEVELS_MAX
}

/// Fixed-width row storage in one of the two KV layouts (module docs):
/// fake-quantized f32 rows, or u8 codes with one `(mn, scale)` grid per
/// row (`scale == 0` marks a constant row whose every code decodes to
/// `mn`). [`LayerKv`] holds one per K/V side; `serve::pager` holds one
/// pair per page and serializes them across the spill boundary.
#[derive(Clone, Debug)]
pub enum RowStore {
    /// Fake-quantized f32 rows, stored verbatim.
    F32 {
        /// Row-major values, `width` per row.
        data: Vec<f32>,
    },
    /// u8 codes + per-row `(mn, scale)` decode grids.
    Codes {
        /// Row-major codes, `width` per row.
        codes: Vec<u8>,
        /// One `(mn, scale)` grid per row.
        grids: Vec<(f32, f32)>,
    },
}

impl RowStore {
    /// An empty store in the layout selected by `(levels, compact)`.
    pub fn new(levels: f32, compact: bool) -> RowStore {
        if use_codes(levels, compact) {
            RowStore::Codes { codes: Vec::new(), grids: Vec::new() }
        } else {
            RowStore::F32 { data: Vec::new() }
        }
    }

    /// A store pre-sized to `rows` zeroed rows of `width` values — the
    /// pager's fixed-capacity page allocation.
    pub fn with_rows(levels: f32, compact: bool, rows: usize, width: usize) -> RowStore {
        let mut s = RowStore::new(levels, compact);
        s.grow(rows, width);
        s
    }

    /// Append `rows` zeroed row slots of `width` values.
    pub fn grow(&mut self, rows: usize, width: usize) {
        match self {
            RowStore::F32 { data } => data.resize(data.len() + rows * width, 0.0),
            RowStore::Codes { codes, grids } => {
                codes.resize(codes.len() + rows * width, 0);
                grids.resize(grids.len() + rows, (0.0, 0.0));
            }
        }
    }

    /// Drop every row slot past the first `rows` — speculative-decode
    /// rollback. Shrinks the backing vectors so [`RowStore::nbytes`]
    /// (and [`RowStore::to_bytes`]) after truncation is identical to a
    /// store that only ever held `rows` rows.
    pub fn truncate(&mut self, rows: usize, width: usize) {
        match self {
            RowStore::F32 { data } => data.truncate(rows * width),
            RowStore::Codes { codes, grids } => {
                codes.truncate(rows * width);
                grids.truncate(rows);
            }
        }
    }

    /// Store `row` into slot `idx`, fake-quantizing at `levels` (the
    /// cache-boundary quantization both layouts share).
    pub fn set_row(&mut self, idx: usize, width: usize, row: &[f32], levels: f32) {
        assert_eq!(row.len(), width, "row width");
        match self {
            RowStore::F32 { data } => {
                let out = &mut data[idx * width..(idx + 1) * width];
                out.copy_from_slice(row);
                fake_quant_row(out, levels);
            }
            RowStore::Codes { codes, grids } => {
                let out = &mut codes[idx * width..(idx + 1) * width];
                if row.iter().any(|v| !v.is_finite()) {
                    // A poisoned (NaN/∞) row has no finite code grid;
                    // decode it as all-NaN so numeric blow-ups surface
                    // loudly instead of being clamped to the grid offset
                    // (the one place the code store is not bit-identical
                    // to the f32 store — see the module docs).
                    grids[idx] = (f32::NAN, 0.0);
                    out.fill(0);
                    return;
                }
                match fq_row_grid(row, levels) {
                    Some((mn, scale)) => {
                        grids[idx] = (mn, scale);
                        for (o, &v) in out.iter_mut().zip(row) {
                            *o = ((v - mn) / scale).round() as u8;
                        }
                    }
                    None => {
                        // Constant row: the fake-quant kernel leaves it
                        // untouched, so store its value as the offset and
                        // decode codes of 0.
                        grids[idx] = (row.first().copied().unwrap_or(0.0), 0.0);
                        out.fill(0);
                    }
                }
            }
        }
    }

    /// Decode slot `idx` into `out` (bit-identical across layouts at
    /// ≤ 8-bit grids; module docs).
    pub fn decode_row(&self, idx: usize, width: usize, out: &mut [f32]) {
        match self {
            RowStore::F32 { data } => out.copy_from_slice(&data[idx * width..(idx + 1) * width]),
            RowStore::Codes { codes, grids } => {
                let (mn, scale) = grids[idx];
                for (o, &c) in out.iter_mut().zip(&codes[idx * width..(idx + 1) * width]) {
                    *o = c as f32 * scale + mn;
                }
            }
        }
    }

    /// Resident bytes (codes + grids, or f32 values) — also the exact
    /// length of [`RowStore::to_bytes`].
    pub fn nbytes(&self) -> u64 {
        match self {
            RowStore::F32 { data } => 4 * data.len() as u64,
            RowStore::Codes { codes, grids } => codes.len() as u64 + 8 * grids.len() as u64,
        }
    }

    /// [`RowStore::nbytes`] of a store holding `rows` rows of `width` —
    /// exact, before the rows exist.
    pub fn estimate_nbytes(rows: u64, width: u64, levels: f32, compact: bool) -> u64 {
        if use_codes(levels, compact) {
            rows * width + 8 * rows
        } else {
            4 * rows * width
        }
    }

    /// Serialize to little-endian bytes (f32 values, or codes followed by
    /// per-row grid pairs). Exactly [`RowStore::nbytes`] long, and
    /// bit-exact under [`RowStore::from_bytes`] — including NaN payloads,
    /// which is what makes the pager's spill/fault cycle invisible to
    /// decode.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.nbytes() as usize);
        match self {
            RowStore::F32 { data } => {
                for v in data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            RowStore::Codes { codes, grids } => {
                out.extend_from_slice(codes);
                for (mn, scale) in grids {
                    out.extend_from_slice(&mn.to_le_bytes());
                    out.extend_from_slice(&scale.to_le_bytes());
                }
            }
        }
        out
    }

    /// Inverse of [`RowStore::to_bytes`] for a store of `rows` rows of
    /// `width` values in the `(levels, compact)` layout. Errors on a
    /// length mismatch (a corrupt or mis-sized spill slot).
    pub fn from_bytes(
        levels: f32,
        compact: bool,
        rows: usize,
        width: usize,
        bytes: &[u8],
    ) -> Result<RowStore> {
        let want = RowStore::estimate_nbytes(rows as u64, width as u64, levels, compact);
        if bytes.len() as u64 != want {
            bail!("row store blob is {} bytes, layout needs {want}", bytes.len());
        }
        let f32_at = |b: &[u8], i: usize| {
            f32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]])
        };
        if use_codes(levels, compact) {
            let split = rows * width;
            let (code_b, grid_b) = bytes.split_at(split);
            let grids =
                (0..rows).map(|r| (f32_at(grid_b, 2 * r), f32_at(grid_b, 2 * r + 1))).collect();
            Ok(RowStore::Codes { codes: code_b.to_vec(), grids })
        } else {
            Ok(RowStore::F32 { data: (0..rows * width).map(|i| f32_at(bytes, i)).collect() })
        }
    }
}

/// The cache interface `forward::block_step` writes and attends over —
/// one object per layer. Implemented by the contiguous [`LayerKv`] and
/// by the paged view `serve::pager::PagedLayerKv`, which is how the same
/// block body serves both layouts bit-identically.
pub trait KvSlot {
    /// Cached positions.
    fn positions(&self) -> usize;
    /// Reserve row slots for `tn` more positions (all KV heads).
    fn extend(&mut self, tn: usize);
    /// Discard every cached position past the first `positions` —
    /// speculative-decode rollback. After truncation the slot is
    /// indistinguishable (positions, bytes, decoded rows) from one that
    /// only ever cached that prefix. `positions` must not exceed
    /// [`KvSlot::positions`].
    fn truncate(&mut self, positions: usize);
    /// Store position `pos`'s K row for `head` (raw post-RoPE/R3 values;
    /// the KV fake-quant happens at the cache boundary).
    fn set_k(&mut self, pos: usize, head: usize, row: &[f32]);
    /// Store position `pos`'s V row for `head`.
    fn set_v(&mut self, pos: usize, head: usize, row: &[f32]);
    /// Decode `head`'s K rows over all cached positions into the
    /// caller's `(positions × head_dim)` scratch.
    fn k_head_into(&self, head: usize, out: &mut Mat);
    /// Decode `head`'s V rows into the caller's scratch.
    fn v_head_into(&self, head: usize, out: &mut Mat);
}

/// One layer's cached K/V rows (see the module docs for the layout and
/// the bit-identity contract).
#[derive(Clone, Debug)]
pub struct LayerKv {
    nkv: usize,
    hd: usize,
    levels: f32,
    positions: usize,
    k: RowStore,
    v: RowStore,
}

impl LayerKv {
    /// A cache for `nkv` KV heads of `hd` values, fake-quantizing rows at
    /// `levels` (≥ 32768 = off). `compact` opts into u8 code storage,
    /// taken when the grid fits (`levels` ≤ 256); the full-sequence
    /// oracle passes `false` and always stores f32.
    pub fn new(nkv: usize, hd: usize, levels: f32, compact: bool) -> LayerKv {
        LayerKv {
            nkv,
            hd,
            levels,
            positions: 0,
            k: RowStore::new(levels, compact),
            v: RowStore::new(levels, compact),
        }
    }

    /// A cache for one layer of `cfg`.
    pub fn for_model(cfg: &ModelConfig, kv_levels: f32, compact: bool) -> LayerKv {
        LayerKv::new(cfg.n_kv_heads, cfg.head_dim, kv_levels, compact)
    }

    /// Cached positions.
    pub fn positions(&self) -> usize {
        self.positions
    }

    /// Reserve row slots for `tn` more positions (all KV heads).
    pub fn extend(&mut self, tn: usize) {
        let rows = tn * self.nkv;
        self.k.grow(rows, self.hd);
        self.v.grow(rows, self.hd);
        self.positions += tn;
    }

    /// Discard every cached position past the first `positions`
    /// (speculative-decode rollback; [`KvSlot::truncate`] contract).
    pub fn truncate(&mut self, positions: usize) {
        assert!(positions <= self.positions, "kv truncate beyond cached positions");
        let rows = positions * self.nkv;
        self.k.truncate(rows, self.hd);
        self.v.truncate(rows, self.hd);
        self.positions = positions;
    }

    fn slot(&self, pos: usize, head: usize) -> usize {
        debug_assert!(pos < self.positions && head < self.nkv, "kv slot out of range");
        pos * self.nkv + head
    }

    /// Store position `pos`'s K row for `head` (raw post-RoPE/R3 values;
    /// the KV fake-quant happens here, at the cache boundary).
    pub fn set_k(&mut self, pos: usize, head: usize, row: &[f32]) {
        let idx = self.slot(pos, head);
        self.k.set_row(idx, self.hd, row, self.levels);
    }

    /// Store position `pos`'s V row for `head`.
    pub fn set_v(&mut self, pos: usize, head: usize, row: &[f32]) {
        let idx = self.slot(pos, head);
        self.v.set_row(idx, self.hd, row, self.levels);
    }

    fn head_mat_into(&self, is_k: bool, head: usize, out: &mut Mat) {
        assert_eq!(out.shape(), (self.positions, self.hd), "kv scratch shape");
        let store = if is_k { &self.k } else { &self.v };
        for pos in 0..self.positions {
            let idx = self.slot(pos, head);
            store.decode_row(idx, self.hd, out.row_mut(pos));
        }
    }

    /// Decode `head`'s K rows over all cached positions into the
    /// caller's `(positions × hd)` buffer — the hot-path variant
    /// `block_step` uses so a decode step reuses one scratch per layer
    /// instead of allocating per kv head.
    pub fn k_head_into(&self, head: usize, out: &mut Mat) {
        self.head_mat_into(true, head, out);
    }

    /// Decode `head`'s V rows into the caller's buffer.
    pub fn v_head_into(&self, head: usize, out: &mut Mat) {
        self.head_mat_into(false, head, out);
    }

    /// Dequantized K rows of `head` over all cached positions
    /// (`positions × hd`) — what attention scores against.
    pub fn k_head(&self, head: usize) -> Mat {
        let mut out = Mat::zeros(self.positions, self.hd);
        self.head_mat_into(true, head, &mut out);
        out
    }

    /// Dequantized V rows of `head` over all cached positions.
    pub fn v_head(&self, head: usize) -> Mat {
        let mut out = Mat::zeros(self.positions, self.hd);
        self.head_mat_into(false, head, &mut out);
        out
    }

    /// Resident cache bytes (codes + grids, or f32 rows).
    pub fn nbytes(&self) -> u64 {
        self.k.nbytes() + self.v.nbytes()
    }

    /// [`LayerKv::nbytes`] of a cache holding `positions` positions —
    /// admission-time accounting before the rows exist. Exact: equals
    /// `nbytes()` after that many positions were appended.
    pub fn estimate_nbytes(
        nkv: usize,
        hd: usize,
        levels: f32,
        positions: usize,
        compact: bool,
    ) -> u64 {
        2 * RowStore::estimate_nbytes((positions * nkv) as u64, hd as u64, levels, compact)
    }
}

impl KvSlot for LayerKv {
    fn positions(&self) -> usize {
        LayerKv::positions(self)
    }
    fn extend(&mut self, tn: usize) {
        LayerKv::extend(self, tn);
    }
    fn truncate(&mut self, positions: usize) {
        LayerKv::truncate(self, positions);
    }
    fn set_k(&mut self, pos: usize, head: usize, row: &[f32]) {
        LayerKv::set_k(self, pos, head, row);
    }
    fn set_v(&mut self, pos: usize, head: usize, row: &[f32]) {
        LayerKv::set_v(self, pos, head, row);
    }
    fn k_head_into(&self, head: usize, out: &mut Mat) {
        LayerKv::k_head_into(self, head, out);
    }
    fn v_head_into(&self, head: usize, out: &mut Mat) {
        LayerKv::v_head_into(self, head, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    fn rand_row(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn code_store_is_bit_identical_to_f32_store() {
        let mut rng = Pcg64::new(1);
        for levels in [4.0f32, 16.0, 256.0] {
            let mut f = LayerKv::new(2, 8, levels, false);
            let mut c = LayerKv::new(2, 8, levels, true);
            f.extend(3);
            c.extend(3);
            for pos in 0..3 {
                for head in 0..2 {
                    let row = rand_row(&mut rng, 8);
                    f.set_k(pos, head, &row);
                    c.set_k(pos, head, &row);
                    f.set_v(pos, head, &row);
                    c.set_v(pos, head, &row);
                }
            }
            for head in 0..2 {
                assert_eq!(f.k_head(head).data, c.k_head(head).data, "levels {levels}");
                assert_eq!(f.v_head(head).data, c.v_head(head).data, "levels {levels}");
            }
            assert!(c.nbytes() < f.nbytes(), "codes must be smaller at {levels} levels");
        }
    }

    #[test]
    fn fp_mode_stores_rows_verbatim() {
        let mut rng = Pcg64::new(2);
        let mut kv = LayerKv::new(1, 16, 65536.0, true); // fp grid ⇒ f32 store
        kv.extend(2);
        let r0 = rand_row(&mut rng, 16);
        let r1 = rand_row(&mut rng, 16);
        kv.set_k(0, 0, &r0);
        kv.set_k(1, 0, &r1);
        let kh = kv.k_head(0);
        assert_eq!(kh.row(0), &r0[..]);
        assert_eq!(kh.row(1), &r1[..]);
    }

    #[test]
    fn poisoned_rows_decode_as_nan_not_clamped() {
        let mut kv = LayerKv::new(1, 4, 16.0, true); // code store
        kv.extend(2);
        kv.set_k(0, 0, &[1.0, f32::NAN, 2.0, 3.0]);
        kv.set_k(1, 0, &[1.0, f32::INFINITY, 2.0, 3.0]);
        let kh = kv.k_head(0);
        assert!(kh.row(0).iter().all(|v| v.is_nan()), "NaN row must stay non-finite");
        assert!(kh.row(1).iter().all(|v| v.is_nan()), "∞ row must stay non-finite");
    }

    #[test]
    fn head_into_matches_allocating_head() {
        let mut rng = Pcg64::new(3);
        let mut kv = LayerKv::new(2, 8, 16.0, true);
        kv.extend(4);
        for pos in 0..4 {
            for head in 0..2 {
                kv.set_k(pos, head, &rand_row(&mut rng, 8));
                kv.set_v(pos, head, &rand_row(&mut rng, 8));
            }
        }
        let mut scratch = Mat::zeros(4, 8);
        for head in 0..2 {
            kv.k_head_into(head, &mut scratch);
            assert_eq!(scratch.data, kv.k_head(head).data);
            kv.v_head_into(head, &mut scratch);
            assert_eq!(scratch.data, kv.v_head(head).data);
        }
    }

    #[test]
    fn constant_rows_roundtrip_exactly() {
        let mut kv = LayerKv::new(1, 4, 16.0, true);
        kv.extend(1);
        kv.set_k(0, 0, &[2.5, 2.5, 2.5, 2.5]);
        kv.set_v(0, 0, &[-1.0, -1.0, -1.0, -1.0]);
        assert_eq!(kv.k_head(0).data, vec![2.5; 4]);
        assert_eq!(kv.v_head(0).data, vec![-1.0; 4]);
    }

    #[test]
    fn nbytes_matches_estimate_in_both_modes() {
        for (levels, compact) in [(16.0f32, true), (16.0, false), (65536.0, true)] {
            let mut kv = LayerKv::new(3, 8, levels, compact);
            kv.extend(5);
            assert_eq!(
                kv.nbytes(),
                LayerKv::estimate_nbytes(3, 8, levels, 5, compact),
                "levels {levels} compact {compact}"
            );
        }
    }

    #[test]
    fn row_store_bytes_roundtrip_bitwise() {
        let mut rng = Pcg64::new(4);
        for (levels, compact) in [(16.0f32, true), (256.0, true), (16.0, false), (65536.0, true)] {
            let mut s = RowStore::with_rows(levels, compact, 5, 8);
            for idx in 0..4 {
                s.set_row(idx, 8, &rand_row(&mut rng, 8), levels);
            }
            // A poisoned row must survive the byte cycle non-finite.
            s.set_row(4, 8, &[f32::NAN; 8], levels);
            let bytes = s.to_bytes();
            assert_eq!(bytes.len() as u64, s.nbytes(), "blob length = nbytes");
            let back = RowStore::from_bytes(levels, compact, 5, 8, &bytes).unwrap();
            let (mut a, mut b) = (vec![0.0f32; 8], vec![0.0f32; 8]);
            for idx in 0..5 {
                s.decode_row(idx, 8, &mut a);
                back.decode_row(idx, 8, &mut b);
                // Bit-exact, NaN payloads included.
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "levels {levels} compact {compact} row {idx}");
            }
        }
    }

    #[test]
    fn row_store_from_bytes_rejects_wrong_length() {
        let s = RowStore::with_rows(16.0, true, 2, 4);
        let bytes = s.to_bytes();
        assert!(RowStore::from_bytes(16.0, true, 2, 4, &bytes[1..]).is_err());
        assert!(RowStore::from_bytes(16.0, true, 3, 4, &bytes).is_err());
    }

    #[test]
    fn layer_kv_works_through_the_kv_slot_trait() {
        let mut rng = Pcg64::new(5);
        let mut kv = LayerKv::new(2, 8, 16.0, true);
        let slot: &mut dyn KvSlot = &mut kv;
        slot.extend(2);
        let row = rand_row(&mut rng, 8);
        slot.set_k(1, 1, &row);
        slot.set_v(1, 1, &row);
        assert_eq!(slot.positions(), 2);
        let mut scratch = Mat::zeros(2, 8);
        slot.k_head_into(1, &mut scratch);
        assert_eq!(scratch.data, kv.k_head(1).data);
    }

    #[test]
    fn truncate_matches_a_fresh_cache_bit_for_bit() {
        // Rollback contract: extending to 6 positions then truncating to
        // 4 leaves exactly the cache a fresh 4-position fill produces —
        // same nbytes, same serialized bytes, same decoded rows.
        for compact in [false, true] {
            let mut rng = Pcg64::new(6);
            let rows: Vec<Vec<f32>> = (0..12).map(|_| rand_row(&mut rng, 8)).collect();
            let fill = |kv: &mut LayerKv, positions: usize| {
                kv.extend(positions);
                for pos in 0..positions {
                    for head in 0..2 {
                        kv.set_k(pos, head, &rows[pos * 2 + head]);
                        kv.set_v(pos, head, &rows[pos * 2 + head]);
                    }
                }
            };
            let mut long = LayerKv::new(2, 8, 16.0, compact);
            fill(&mut long, 6);
            long.truncate(4);
            let mut fresh = LayerKv::new(2, 8, 16.0, compact);
            fill(&mut fresh, 4);
            assert_eq!(long.positions(), 4, "compact {compact}");
            assert_eq!(long.nbytes(), fresh.nbytes(), "compact {compact}");
            assert_eq!(long.k.to_bytes(), fresh.k.to_bytes(), "compact {compact}: k bytes");
            assert_eq!(long.v.to_bytes(), fresh.v.to_bytes(), "compact {compact}: v bytes");
            for head in 0..2 {
                assert_eq!(long.k_head(head).data, fresh.k_head(head).data);
                assert_eq!(long.v_head(head).data, fresh.v_head(head).data);
            }
        }
    }

    #[test]
    fn truncate_then_extend_reuses_slots_cleanly() {
        let mut rng = Pcg64::new(7);
        let mut kv = LayerKv::new(1, 4, 16.0, true);
        kv.extend(3);
        for pos in 0..3 {
            kv.set_k(pos, 0, &rand_row(&mut rng, 4));
        }
        kv.truncate(1);
        kv.extend(2);
        assert_eq!(kv.positions(), 3);
        let row = rand_row(&mut rng, 4);
        kv.set_k(2, 0, &row);
        let mut out = vec![0.0f32; 4];
        kv.k.decode_row(2, 4, &mut out);
        let mut want = RowStore::with_rows(16.0, true, 1, 4);
        want.set_row(0, 4, &row, 16.0);
        let mut w = vec![0.0f32; 4];
        want.decode_row(0, 4, &mut w);
        assert_eq!(out, w);
    }

    #[test]
    fn prop_code_roundtrip_bounded_by_half_step() {
        Runner::new().cases(48).run("kv code roundtrip bound", |rng| {
            let hd = 1 << gen::size(rng, 2, 6);
            let levels = [4.0f32, 16.0, 64.0, 256.0][rng.below(4)];
            let row = gen::vec_f32(rng, hd);
            let mut kv = LayerKv::new(1, hd, levels, true);
            kv.extend(1);
            kv.set_k(0, 0, &row);
            let back = kv.k_head(0);
            let (mn, mx) =
                row.iter().fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
            let step = (mx - mn) / (levels - 1.0);
            for (a, b) in row.iter().zip(back.row(0)) {
                let tol = step / 2.0 + 1e-6 * (mx - mn).abs().max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!("roundtrip error {} > {tol}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cache_bytes_accounting_matches_estimate() {
        Runner::new().cases(24).run("kv nbytes accounting", |rng| {
            let nkv = gen::size(rng, 1, 4);
            let hd = 1 << gen::size(rng, 2, 6);
            let compact = rng.below(2) == 0;
            let levels = [16.0f32, 256.0, 65536.0][rng.below(3)];
            let mut kv = LayerKv::new(nkv, hd, levels, compact);
            let mut total = 0usize;
            for _ in 0..gen::size(rng, 1, 4) {
                let tn = gen::size(rng, 1, 6);
                kv.extend(tn);
                total += tn;
            }
            if kv.positions() != total {
                return Err("position count drifted".into());
            }
            let want = LayerKv::estimate_nbytes(nkv, hd, levels, total, compact);
            if kv.nbytes() != want {
                return Err(format!("nbytes {} != estimate {want}", kv.nbytes()));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_row_store_serialization_is_bit_exact() {
        Runner::new().cases(32).run("row store byte roundtrip", |rng| {
            let width = 1 << gen::size(rng, 1, 5);
            let rows = gen::size(rng, 1, 12);
            let compact = rng.below(2) == 0;
            let levels = [16.0f32, 256.0, 65536.0][rng.below(3)];
            let mut s = RowStore::with_rows(levels, compact, rows, width);
            for idx in 0..rows {
                let row = gen::vec_f32(rng, width);
                s.set_row(idx, width, &row, levels);
            }
            let back = RowStore::from_bytes(levels, compact, rows, width, &s.to_bytes())
                .map_err(|e| e.to_string())?;
            let (mut a, mut b) = (vec![0.0f32; width], vec![0.0f32; width]);
            for idx in 0..rows {
                s.decode_row(idx, width, &mut a);
                back.decode_row(idx, width, &mut b);
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                if ab != bb {
                    return Err(format!("row {idx} differs after byte roundtrip"));
                }
            }
            Ok(())
        });
    }
}
