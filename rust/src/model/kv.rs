//! Per-layer K/V row storage — the cache type the shared block body
//! (`forward::block_step`) reads and extends.
//!
//! [`LayerKv`] holds the K and V rows of every cached position, indexed
//! `(position, kv head)` with `head_dim` values per row. Rows enter
//! through [`LayerKv::set_k`] / [`LayerKv::set_v`] **raw** (post-RoPE,
//! post-online-R3 for K) and are KV-fake-quantized at the cache boundary
//! — the same per-row asymmetric grid (`forward::fq_row_grid`) the
//! full-sequence oracle applies, so a cached row reads back bit-identical
//! to what `forward_one` attends over.
//!
//! Two storage modes:
//!
//! * **f32** — rows stored as (fake-quantized) f32 values; the oracle
//!   layout, and the only representable one for fp / wide KV grids.
//! * **code** (`compact` + `kv_levels` ≤ 256) — u8 codes plus one
//!   `(mn, scale)` grid per row. Decoding evaluates
//!   `code as f32 * scale + mn`, the very expression the fake-quant
//!   kernel produces, so the dequantized row is **bit-identical** to the
//!   f32 mode at ≤ 8-bit KV settings while holding ~4× fewer bytes.
//!   Constant rows (which the fake-quant kernel leaves untouched) store
//!   `scale = 0` and decode every code to `mn` exactly. The one carve-out
//!   from bit-identity: a row containing NaN/∞ has no finite code grid
//!   and decodes **all-NaN** (the f32 store keeps only the poisoned
//!   elements non-finite) — blow-ups surface either way instead of being
//!   silently clamped.
//!
//! The serving layer aggregates one `LayerKv` per layer into
//! `serve::KvCache` (which also owns the engine's byte accounting); see
//! `docs/SERVING.md`.

use super::config::ModelConfig;
use super::forward::{fake_quant_row, fq_row_grid};
use crate::tensor::Mat;

/// Largest level count representable by the u8 code storage.
const CODE_LEVELS_MAX: f32 = 256.0;

/// u8-coded rows: one `(mn, scale)` grid per row; `scale == 0` marks a
/// constant row whose every code decodes to `mn`.
#[derive(Clone, Debug)]
struct CodeRows {
    codes: Vec<u8>,
    grids: Vec<(f32, f32)>,
}

impl CodeRows {
    fn new() -> CodeRows {
        CodeRows { codes: Vec::new(), grids: Vec::new() }
    }

    fn extend(&mut self, rows: usize, width: usize) {
        self.codes.resize(self.codes.len() + rows * width, 0);
        self.grids.resize(self.grids.len() + rows, (0.0, 0.0));
    }

    fn set(&mut self, idx: usize, width: usize, row: &[f32], levels: f32) {
        let out = &mut self.codes[idx * width..(idx + 1) * width];
        if row.iter().any(|v| !v.is_finite()) {
            // A poisoned (NaN/∞) row has no finite code grid; decode it
            // as all-NaN so numeric blow-ups surface loudly instead of
            // being clamped to the grid offset (the one place the code
            // store is not bit-identical to the f32 store — see the
            // module docs).
            self.grids[idx] = (f32::NAN, 0.0);
            out.fill(0);
            return;
        }
        match fq_row_grid(row, levels) {
            Some((mn, scale)) => {
                self.grids[idx] = (mn, scale);
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = ((v - mn) / scale).round() as u8;
                }
            }
            None => {
                // Constant row: the fake-quant kernel leaves it untouched,
                // so store its value as the offset and decode codes of 0.
                self.grids[idx] = (row.first().copied().unwrap_or(0.0), 0.0);
                out.fill(0);
            }
        }
    }

    fn decode(&self, idx: usize, width: usize, out: &mut [f32]) {
        let (mn, scale) = self.grids[idx];
        for (o, &c) in out.iter_mut().zip(&self.codes[idx * width..(idx + 1) * width]) {
            *o = c as f32 * scale + mn;
        }
    }

    fn nbytes(&self) -> u64 {
        self.codes.len() as u64 + 8 * self.grids.len() as u64
    }
}

#[derive(Clone, Debug)]
enum Store {
    F32 { k: Vec<f32>, v: Vec<f32> },
    Codes { k: CodeRows, v: CodeRows },
}

/// One layer's cached K/V rows (see the module docs for the layout and
/// the bit-identity contract).
#[derive(Clone, Debug)]
pub struct LayerKv {
    nkv: usize,
    hd: usize,
    levels: f32,
    positions: usize,
    store: Store,
}

impl LayerKv {
    /// A cache for `nkv` KV heads of `hd` values, fake-quantizing rows at
    /// `levels` (≥ 32768 = off). `compact` opts into u8 code storage,
    /// taken when the grid fits (`levels` ≤ 256); the full-sequence
    /// oracle passes `false` and always stores f32.
    pub fn new(nkv: usize, hd: usize, levels: f32, compact: bool) -> LayerKv {
        let store = if compact && levels <= CODE_LEVELS_MAX {
            Store::Codes { k: CodeRows::new(), v: CodeRows::new() }
        } else {
            Store::F32 { k: Vec::new(), v: Vec::new() }
        };
        LayerKv { nkv, hd, levels, positions: 0, store }
    }

    /// A cache for one layer of `cfg`.
    pub fn for_model(cfg: &ModelConfig, kv_levels: f32, compact: bool) -> LayerKv {
        LayerKv::new(cfg.n_kv_heads, cfg.head_dim, kv_levels, compact)
    }

    /// Cached positions.
    pub fn positions(&self) -> usize {
        self.positions
    }

    /// Reserve row slots for `tn` more positions (all KV heads).
    pub fn extend(&mut self, tn: usize) {
        let rows = tn * self.nkv;
        match &mut self.store {
            Store::F32 { k, v } => {
                k.resize(k.len() + rows * self.hd, 0.0);
                v.resize(v.len() + rows * self.hd, 0.0);
            }
            Store::Codes { k, v } => {
                k.extend(rows, self.hd);
                v.extend(rows, self.hd);
            }
        }
        self.positions += tn;
    }

    fn slot(&self, pos: usize, head: usize) -> usize {
        debug_assert!(pos < self.positions && head < self.nkv, "kv slot out of range");
        pos * self.nkv + head
    }

    fn set_row(&mut self, is_k: bool, pos: usize, head: usize, row: &[f32]) {
        assert_eq!(row.len(), self.hd, "kv row width");
        let idx = self.slot(pos, head);
        let (hd, levels) = (self.hd, self.levels);
        match &mut self.store {
            Store::F32 { k, v } => {
                let out = &mut (if is_k { k } else { v })[idx * hd..(idx + 1) * hd];
                out.copy_from_slice(row);
                fake_quant_row(out, levels);
            }
            Store::Codes { k, v } => (if is_k { k } else { v }).set(idx, hd, row, levels),
        }
    }

    /// Store position `pos`'s K row for `head` (raw post-RoPE/R3 values;
    /// the KV fake-quant happens here, at the cache boundary).
    pub fn set_k(&mut self, pos: usize, head: usize, row: &[f32]) {
        self.set_row(true, pos, head, row);
    }

    /// Store position `pos`'s V row for `head`.
    pub fn set_v(&mut self, pos: usize, head: usize, row: &[f32]) {
        self.set_row(false, pos, head, row);
    }

    fn head_mat_into(&self, is_k: bool, head: usize, out: &mut Mat) {
        assert_eq!(out.shape(), (self.positions, self.hd), "kv scratch shape");
        for pos in 0..self.positions {
            let idx = self.slot(pos, head);
            let row = out.row_mut(pos);
            match &self.store {
                Store::F32 { k, v } => row.copy_from_slice(
                    &(if is_k { k } else { v })[idx * self.hd..(idx + 1) * self.hd],
                ),
                Store::Codes { k, v } => (if is_k { k } else { v }).decode(idx, self.hd, row),
            }
        }
    }

    /// Decode `head`'s K rows over all cached positions into the
    /// caller's `(positions × hd)` buffer — the hot-path variant
    /// `block_step` uses so a decode step reuses one scratch per layer
    /// instead of allocating per kv head.
    pub fn k_head_into(&self, head: usize, out: &mut Mat) {
        self.head_mat_into(true, head, out);
    }

    /// Decode `head`'s V rows into the caller's buffer.
    pub fn v_head_into(&self, head: usize, out: &mut Mat) {
        self.head_mat_into(false, head, out);
    }

    /// Dequantized K rows of `head` over all cached positions
    /// (`positions × hd`) — what attention scores against.
    pub fn k_head(&self, head: usize) -> Mat {
        let mut out = Mat::zeros(self.positions, self.hd);
        self.head_mat_into(true, head, &mut out);
        out
    }

    /// Dequantized V rows of `head` over all cached positions.
    pub fn v_head(&self, head: usize) -> Mat {
        let mut out = Mat::zeros(self.positions, self.hd);
        self.head_mat_into(false, head, &mut out);
        out
    }

    /// Resident cache bytes (codes + grids, or f32 rows).
    pub fn nbytes(&self) -> u64 {
        match &self.store {
            Store::F32 { k, v } => 4 * (k.len() + v.len()) as u64,
            Store::Codes { k, v } => k.nbytes() + v.nbytes(),
        }
    }

    /// [`LayerKv::nbytes`] of a cache holding `positions` positions —
    /// admission-time accounting before the rows exist. Exact: equals
    /// `nbytes()` after that many positions were appended.
    pub fn estimate_nbytes(
        nkv: usize,
        hd: usize,
        levels: f32,
        positions: usize,
        compact: bool,
    ) -> u64 {
        let rows = (positions * nkv) as u64;
        if compact && levels <= CODE_LEVELS_MAX {
            2 * (rows * hd as u64 + 8 * rows)
        } else {
            2 * rows * hd as u64 * 4
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    fn rand_row(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn code_store_is_bit_identical_to_f32_store() {
        let mut rng = Pcg64::new(1);
        for levels in [4.0f32, 16.0, 256.0] {
            let mut f = LayerKv::new(2, 8, levels, false);
            let mut c = LayerKv::new(2, 8, levels, true);
            f.extend(3);
            c.extend(3);
            for pos in 0..3 {
                for head in 0..2 {
                    let row = rand_row(&mut rng, 8);
                    f.set_k(pos, head, &row);
                    c.set_k(pos, head, &row);
                    f.set_v(pos, head, &row);
                    c.set_v(pos, head, &row);
                }
            }
            for head in 0..2 {
                assert_eq!(f.k_head(head).data, c.k_head(head).data, "levels {levels}");
                assert_eq!(f.v_head(head).data, c.v_head(head).data, "levels {levels}");
            }
            assert!(c.nbytes() < f.nbytes(), "codes must be smaller at {levels} levels");
        }
    }

    #[test]
    fn fp_mode_stores_rows_verbatim() {
        let mut rng = Pcg64::new(2);
        let mut kv = LayerKv::new(1, 16, 65536.0, true); // fp grid ⇒ f32 store
        kv.extend(2);
        let r0 = rand_row(&mut rng, 16);
        let r1 = rand_row(&mut rng, 16);
        kv.set_k(0, 0, &r0);
        kv.set_k(1, 0, &r1);
        let kh = kv.k_head(0);
        assert_eq!(kh.row(0), &r0[..]);
        assert_eq!(kh.row(1), &r1[..]);
    }

    #[test]
    fn poisoned_rows_decode_as_nan_not_clamped() {
        let mut kv = LayerKv::new(1, 4, 16.0, true); // code store
        kv.extend(2);
        kv.set_k(0, 0, &[1.0, f32::NAN, 2.0, 3.0]);
        kv.set_k(1, 0, &[1.0, f32::INFINITY, 2.0, 3.0]);
        let kh = kv.k_head(0);
        assert!(kh.row(0).iter().all(|v| v.is_nan()), "NaN row must stay non-finite");
        assert!(kh.row(1).iter().all(|v| v.is_nan()), "∞ row must stay non-finite");
    }

    #[test]
    fn head_into_matches_allocating_head() {
        let mut rng = Pcg64::new(3);
        let mut kv = LayerKv::new(2, 8, 16.0, true);
        kv.extend(4);
        for pos in 0..4 {
            for head in 0..2 {
                kv.set_k(pos, head, &rand_row(&mut rng, 8));
                kv.set_v(pos, head, &rand_row(&mut rng, 8));
            }
        }
        let mut scratch = Mat::zeros(4, 8);
        for head in 0..2 {
            kv.k_head_into(head, &mut scratch);
            assert_eq!(scratch.data, kv.k_head(head).data);
            kv.v_head_into(head, &mut scratch);
            assert_eq!(scratch.data, kv.v_head(head).data);
        }
    }

    #[test]
    fn constant_rows_roundtrip_exactly() {
        let mut kv = LayerKv::new(1, 4, 16.0, true);
        kv.extend(1);
        kv.set_k(0, 0, &[2.5, 2.5, 2.5, 2.5]);
        kv.set_v(0, 0, &[-1.0, -1.0, -1.0, -1.0]);
        assert_eq!(kv.k_head(0).data, vec![2.5; 4]);
        assert_eq!(kv.v_head(0).data, vec![-1.0; 4]);
    }

    #[test]
    fn nbytes_matches_estimate_in_both_modes() {
        for (levels, compact) in [(16.0f32, true), (16.0, false), (65536.0, true)] {
            let mut kv = LayerKv::new(3, 8, levels, compact);
            kv.extend(5);
            assert_eq!(
                kv.nbytes(),
                LayerKv::estimate_nbytes(3, 8, levels, 5, compact),
                "levels {levels} compact {compact}"
            );
        }
    }

    #[test]
    fn prop_code_roundtrip_bounded_by_half_step() {
        Runner::new().cases(48).run("kv code roundtrip bound", |rng| {
            let hd = 1 << gen::size(rng, 2, 6);
            let levels = [4.0f32, 16.0, 64.0, 256.0][rng.below(4)];
            let row = gen::vec_f32(rng, hd);
            let mut kv = LayerKv::new(1, hd, levels, true);
            kv.extend(1);
            kv.set_k(0, 0, &row);
            let back = kv.k_head(0);
            let (mn, mx) =
                row.iter().fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
            let step = (mx - mn) / (levels - 1.0);
            for (a, b) in row.iter().zip(back.row(0)) {
                let tol = step / 2.0 + 1e-6 * (mx - mn).abs().max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!("roundtrip error {} > {tol}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cache_bytes_accounting_matches_estimate() {
        Runner::new().cases(24).run("kv nbytes accounting", |rng| {
            let nkv = gen::size(rng, 1, 4);
            let hd = 1 << gen::size(rng, 2, 6);
            let compact = rng.below(2) == 0;
            let levels = [16.0f32, 256.0, 65536.0][rng.below(3)];
            let mut kv = LayerKv::new(nkv, hd, levels, compact);
            let mut total = 0usize;
            for _ in 0..gen::size(rng, 1, 4) {
                let tn = gen::size(rng, 1, 6);
                kv.extend(tn);
                total += tn;
            }
            if kv.positions() != total {
                return Err("position count drifted".into());
            }
            let want = LayerKv::estimate_nbytes(nkv, hd, levels, total, compact);
            if kv.nbytes() != want {
                return Err(format!("nbytes {} != estimate {want}", kv.nbytes()));
            }
            Ok(())
        });
    }
}
