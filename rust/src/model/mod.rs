//! Model substrate: tiny Llama-architecture configs, synthetic weights
//! with planted outlier channels, the native forward oracle, and the glue
//! that feeds weights/tokens to the PJRT artifacts.

pub mod artifact_io;
pub mod config;
pub mod forward;
pub mod kv;
pub mod weights;

pub use artifact_io::{ppl_from_nll, CapturedSites, TokenBatch, TrainState};
pub use config::{BitSetting, ModelConfig};
pub use forward::{
    fake_quant_row, fake_quant_rows, forward_batch, forward_one, nll_from_logits, CaptureHook,
    FwdOptions, NoCapture,
};
pub use weights::{Tensor, Weights};
