//! Model substrate: tiny Llama-architecture configs, synthetic weights
//! with planted outlier channels, the native forward oracle, the glue
//! that feeds weights/tokens to the PJRT artifacts, and the indexed
//! on-disk weight artifact behind the out-of-core [`WeightStore`]
//! (checkout/checkin leases with budgeted resident bytes — see
//! `docs/STREAMING.md`).

pub mod artifact_io;
pub mod config;
pub mod forward;
pub mod kv;
pub mod weights;

pub use artifact_io::{
    load_indexed, ppl_from_nll, save_indexed, stream_blocks, suggested_resident_budget,
    CapturedSites, TokenBatch, TrainState, WeightLease, WeightStore,
};
pub use config::{BitSetting, ModelConfig};
pub use forward::{
    fake_quant_row, fake_quant_rows, forward_batch, forward_one, nll_from_logits, quantize_act,
    CaptureHook, FwdOptions, NoCapture,
};
pub use weights::{Tensor, Weights};
