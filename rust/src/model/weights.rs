//! Model weights: ordered named matrices (the AOT artifact passing
//! convention), synthetic initialization with **planted outlier channels**
//! (the activation regime DartQuant targets — see DESIGN.md §3), and
//! checkpoint persistence through the indexed artifact format
//! (`artifact_io::save_indexed` — packed tensors roundtrip natively, and
//! the same file backs the out-of-core `WeightStore`).

use super::config::ModelConfig;
use crate::tensor::{Mat, QMat};
use crate::util::prng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// One weight tensor: dense f32, or packed low-bit codes + scales. The
/// pipeline starts dense; `--packed` quantization swaps the transformer
/// linears to `Packed` so the model holds its true low-bit footprint
/// end-to-end (embed/head always stay dense, as in the paper).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Mat),
    Packed(QMat),
}

impl Tensor {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Tensor::F32(m) => m.shape(),
            Tensor::Packed(q) => q.shape(),
        }
    }

    /// True resident bytes (packed codes + scales for `Packed`).
    pub fn nbytes(&self) -> u64 {
        match self {
            Tensor::F32(m) => m.nbytes(),
            Tensor::Packed(q) => q.nbytes(),
        }
    }

    /// Bytes of the dense f32 equivalent.
    pub fn dense_nbytes(&self) -> u64 {
        let (r, c) = self.shape();
        (r * c * 4) as u64
    }

    /// The dense view: a clone for `F32`, a dequantization for `Packed`
    /// (bit-identical to the fake-quant output, per the QMat contract).
    pub fn to_mat(&self) -> Mat {
        match self {
            Tensor::F32(m) => m.clone(),
            Tensor::Packed(q) => q.dequantize(),
        }
    }

    pub fn as_f32(&self) -> Option<&Mat> {
        match self {
            Tensor::F32(m) => Some(m),
            Tensor::Packed(_) => None,
        }
    }

    pub fn as_packed(&self) -> Option<&QMat> {
        match self {
            Tensor::F32(_) => None,
            Tensor::Packed(q) => Some(q),
        }
    }
}

/// Named weight collection with a stable parameter order.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    order: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl Weights {
    /// Synthetic init: scaled-normal fan-in init, plus `n_outlier_channels`
    /// residual-stream channels amplified by `outlier_scale` — planted in
    /// the output-side projections (wo, wd) and the embedding so the
    /// residual stream accumulates heavy-tailed channel magnitudes, the
    /// structure rotations are designed to smooth (paper Figs 2/6/11,
    /// Table 19 kurtosis).
    pub fn init_synthetic(
        cfg: &ModelConfig,
        seed: u64,
        n_outlier_channels: usize,
        outlier_scale: f32,
    ) -> Weights {
        let mut rng = Pcg64::new(seed);
        let mut map = BTreeMap::new();
        let order = cfg.param_names();
        for name in &order {
            let (rows, cols) = cfg.param_shape(name);
            let std = 1.0 / (cols as f32).sqrt();
            map.insert(name.clone(), Mat::from_fn(rows, cols, |_, _| rng.normal() * std));
        }
        // Plant outlier channels: fixed channel subset across layers
        // (mirrors the persistent outlier dims observed in real LLMs).
        let channels = rng.sample_indices(cfg.dim, n_outlier_channels.min(cfg.dim));
        for name in &order {
            let leaf = name.rsplit('.').next().unwrap();
            if leaf == "wo" || leaf == "wd" {
                let w = map.get_mut(name).unwrap();
                for &c in &channels {
                    for j in 0..w.cols {
                        *w.at_mut(c, j) *= outlier_scale;
                    }
                }
            }
        }
        if let Some(embed) = map.get_mut("embed") {
            for &c in &channels {
                for i in 0..embed.rows {
                    *embed.at_mut(i, c) *= outlier_scale;
                }
            }
        }
        Weights { cfg: cfg.clone(), order, map: dense_map(map) }
    }

    /// Default synthetic model used by the benches: ~3% outlier channels
    /// at 12× scale — yields activation kurtosis in the tens, matching the
    /// paper's Table 19 regime at our scale.
    pub fn default_synthetic(cfg: &ModelConfig, seed: u64) -> Weights {
        let n_out = (cfg.dim / 32).max(2);
        Weights::init_synthetic(cfg, seed, n_out, 12.0)
    }

    /// "Pretrained" synthetic model: plants a corpus grammar (successor
    /// table) directly into embed/head so the model predicts its dialect's
    /// bigram structure without any training:
    ///
    /// * `embed[t]` = random unit-ish token vector (with outlier channels),
    /// * `head[v]` = α · Σ_{t: succ(t)=v} embed[t] — so logits peak on the
    ///   successor of the current token, which dominates the residual
    ///   stream because the transformer blocks are initialized small.
    ///
    /// This gives every config meaningful perplexity and zero-shot accuracy
    /// on its own dialect (and degraded transfer to other dialects), which
    /// is what Tables 1/2/5 measure — without CPU-training five models.
    pub fn init_grammar(
        cfg: &ModelConfig,
        seed: u64,
        successor: &[usize],
        n_outlier_channels: usize,
        outlier_scale: f32,
    ) -> Result<Weights> {
        anyhow::ensure!(
            successor.len() == cfg.vocab,
            "successor table covers {} tokens but model {} has vocab {}",
            successor.len(),
            cfg.name,
            cfg.vocab
        );
        let mut rng = Pcg64::new(seed);
        let mut map = BTreeMap::new();
        let order = cfg.param_names();
        let (d, f, v) = (cfg.dim, cfg.ffn_dim, cfg.vocab);

        // Transformer blocks: small (residual-dominated) random weights.
        for name in &order {
            let (rows, cols) = cfg.param_shape(name);
            let std = 0.25 / (cols as f32).sqrt();
            map.insert(name.clone(), Mat::from_fn(rows, cols, |_, _| rng.normal() * std));
        }

        // Outlier channels (fixed subset, like the persistent outlier dims
        // of real LLMs) — chosen before the embedding so both the planting
        // and the head stay consistent.
        let channels = {
            let mut r2 = Pcg64::new(seed ^ 0xabcd);
            r2.sample_indices(d, n_outlier_channels.min(d))
        };

        // Token vectors: unit-RMS random directions, then outlier channels
        // amplified (the heavy-tailed activation regime of Table 19).
        let mut embed = Mat::from_fn(v, d, |_, _| rng.normal());
        for i in 0..v {
            let row = embed.row_mut(i);
            let rms = (row.iter().map(|x| x * x).sum::<f32>() / d as f32).sqrt();
            for x in row.iter_mut() {
                *x /= rms.max(1e-6);
            }
        }
        for &c in &channels {
            for i in 0..v {
                *embed.at_mut(i, c) *= outlier_scale;
            }
        }

        // ---- The grammar circuit: an associative-memory FFN in the LAST
        // layer. h = rmsnorm(x) ≈ normalized embed[t] is (a) fake-quantized,
        // (b) projected by the quantized wu/wg into a nonlinear feature
        // φ(t) = silu(u)·u, then (c) the quantized wd maps φ(t) to
        // μ·embed[succ(t)] via a hetero-associative store. The whole
        // prediction therefore flows through exactly the linears the paper
        // quantizes, so outliers in h corrupt the per-token quant scales
        // and rotations that smooth them visibly recover perplexity.
        let last = cfg.n_layers - 1;
        // Store associations for the most frequent tokens (Zipf rank order
        // = token id order in our corpora). The store is the minimal-norm
        // EXACT interpolator  wd = μ·Eᵀ(ΦΦᵀ+λI)⁻¹Φ  — recall at stored
        // feature points is exact (no Hebbian crosstalk), so the fp model
        // is cleanly predictive and quantization noise in φ is what
        // degrades it.
        let k_store = (f / 2).min(v * 3 / 4);
        let su = 1.5f32;
        let ffn_names: Vec<String> = if cfg.is_moe() {
            // Plant the same circuit in every expert of the last layer —
            // routing then picks experts without losing the grammar.
            (0..cfg.n_experts).map(|e| format!("l{last}.e{e}")).collect()
        } else {
            vec![format!("l{last}")]
        };
        for prefix in &ffn_names {
            let wu = Mat::from_fn(f, d, |_, _| rng.normal() * su / (d as f32).sqrt());
            // Normalized hidden state per token (what rmsnorm feeds the FFN
            // when the residual stream is embed-dominated).
            let mut hhat = embed.clone();
            for i in 0..v {
                let row = hhat.row_mut(i);
                let rms = (row.iter().map(|x| x * x).sum::<f32>() / d as f32).sqrt();
                for x in row.iter_mut() {
                    *x /= rms.max(1e-6);
                }
            }
            // Features φ(t) = silu(u)·u with u = wu·ĥ(t) (wg == wu).
            let uu = crate::tensor::matmul_transb(&hhat, &wu);
            let phi_all = Mat::from_fn(v, f, |t, r| {
                let x = uu.at(t, r);
                (x / (1.0 + (-x).exp())) * x
            });
            let phi = phi_all.rows_slice(0, k_store); // (k, f)
            // Targets: μ·embed[succ(t)] (k, d).
            let mu = 2.0f32;
            let targets = Mat::from_fn(k_store, d, |t, c| mu * embed.at(successor[t], c));
            // Gram matrix with Tikhonov damping for conditioning.
            let mut gram = crate::tensor::matmul(&phi, &phi.t()); // (k, k)
            let damp = {
                let tr: f32 = (0..k_store).map(|i| gram.at(i, i)).sum();
                1e-4 * tr / k_store as f32
            };
            for i in 0..k_store {
                *gram.at_mut(i, i) += damp;
            }
            let ginv = crate::linalg::cholesky_inverse(&gram).with_context(|| {
                format!(
                    "planting the {prefix} grammar circuit in model {}: Cholesky of the \
                     damped {k_store}x{k_store} feature Gram matrix failed (damp={damp:.3e}) \
                     — the matrix should be SPD by construction",
                    cfg.name
                )
            })?;
            // wd = targetsᵀ · G⁻¹ · Φ  → (d, f).
            let coef = crate::tensor::matmul(&ginv, &phi); // (k, f)
            let wd = crate::tensor::matmul(&targets.t(), &coef); // (d, f)
            map.insert(format!("{prefix}.wu"), wu.clone());
            map.insert(format!("{prefix}.wg"), wu);
            map.insert(format!("{prefix}.wd"), wd);
        }

        // Head: logits = α⟨ĥ, embed[v]⟩; α·d sets the successor logit gap
        // (≈ ln V + margin → realistic 0.5-0.8 successor probability).
        let alpha = std::env::var("DQ_ALPHA")
            .ok()
            .and_then(|s| s.parse::<f32>().ok())
            .unwrap_or(3.0)
            / d as f32;
        let mut head = embed.clone();
        head.scale(alpha);
        map.insert("embed".to_string(), embed);
        map.insert("head".to_string(), head);

        // Residual-stream outlier amplification through wo/wd of the other
        // layers keeps the outlier channels alive at every rotation site.
        let mut w = Weights { cfg: cfg.clone(), order, map: dense_map(map) };
        for name in w.order.clone() {
            let leaf = name.rsplit('.').next().unwrap().to_string();
            if (leaf == "wo") && !name.starts_with(&format!("l{last}.")) {
                let m = w.get_mut(&name);
                for &c in &channels {
                    for j in 0..m.cols {
                        *m.at_mut(c, j) *= outlier_scale;
                    }
                }
            }
        }
        Ok(w)
    }

    /// Grammar model with the default outlier planting.
    pub fn default_grammar(cfg: &ModelConfig, seed: u64, successor: &[usize]) -> Result<Weights> {
        let n_out = (cfg.dim / 32).max(2);
        Weights::init_grammar(cfg, seed, successor, n_out, 10.0)
    }

    /// The dense matrix for `name`. Panics for packed tensors — use
    /// [`Weights::tensor`] (or [`Tensor::to_mat`]) on models that may
    /// hold packed weights.
    pub fn get(&self, name: &str) -> &Mat {
        match self.tensor(name) {
            Tensor::F32(m) => m,
            Tensor::Packed(_) => {
                panic!("weight {name:?} is packed; use tensor()/to_mat() instead of get()")
            }
        }
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Mat {
        match self.map.get_mut(name).unwrap_or_else(|| panic!("no weight {name:?}")) {
            Tensor::F32(m) => m,
            Tensor::Packed(_) => {
                panic!("weight {name:?} is packed; packed tensors are immutable")
            }
        }
    }

    /// The representation-agnostic view of a weight.
    pub fn tensor(&self, name: &str) -> &Tensor {
        self.map.get(name).unwrap_or_else(|| panic!("no weight {name:?}"))
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        self.set_tensor(name, Tensor::F32(m));
    }

    /// Swap a weight to packed storage.
    pub fn set_packed(&mut self, name: &str, q: QMat) {
        self.set_tensor(name, Tensor::Packed(q));
    }

    pub fn set_tensor(&mut self, name: &str, t: Tensor) {
        let (r, c) = self.cfg.param_shape(name);
        assert_eq!(t.shape(), (r, c), "shape mismatch for {name}");
        self.map.insert(name.to_string(), t);
    }

    /// Whether any weight is held packed (such models cannot feed the
    /// PJRT artifacts, which take dense f32 inputs).
    pub fn has_packed(&self) -> bool {
        self.map.values().any(|t| matches!(t, Tensor::Packed(_)))
    }

    /// A fully dense copy: packed tensors dequantized (bit-identical to
    /// their fake-quant values, per the `QMat` contract), dense tensors
    /// cloned. The pipeline uses this to accept packed checkpoints —
    /// exactly what loading a pre-streaming checkpoint produced, when
    /// `save()` still wrote the dense dequantization.
    pub fn to_dense(&self) -> Weights {
        let mut map = BTreeMap::new();
        for (name, t) in &self.map {
            map.insert(name.clone(), Tensor::F32(t.to_mat()));
        }
        Weights { cfg: self.cfg.clone(), order: self.order.clone(), map }
    }

    /// Ordered iteration over dense matrices (the artifact input
    /// convention). Panics on packed tensors — artifact callers check
    /// [`Weights::has_packed`] first.
    pub fn ordered(&self) -> impl Iterator<Item = (&str, &Mat)> {
        self.order.iter().map(|n| (n.as_str(), self.get(n)))
    }

    /// Ordered iteration over the per-tensor representations.
    pub fn ordered_tensors(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.order.iter().map(|n| (n.as_str(), self.tensor(n)))
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// True resident weight bytes: dense f32 bytes plus packed
    /// codes + scales for packed tensors.
    pub fn nbytes(&self) -> u64 {
        self.map.values().map(|t| t.nbytes()).sum()
    }

    /// (dense-equivalent bytes, actual bytes) over the transformer
    /// linears (embed/head excluded) — the weight-residency measure
    /// behind `PipelineReport::compression_ratio`.
    pub fn linear_bytes(&self) -> (u64, u64) {
        let mut dense = 0u64;
        let mut actual = 0u64;
        for (n, t) in self.map.iter() {
            if n == "embed" || n == "head" {
                continue;
            }
            dense += t.dense_nbytes();
            actual += t.nbytes();
        }
        (dense, actual)
    }

    /// Apply `f` to every transformer weight (not embed/head). Panics on
    /// packed tensors (these passes run pre-quantization, on dense
    /// models).
    pub fn map_linear_weights(&mut self, mut f: impl FnMut(&str, &mut Mat)) {
        for n in self.order.clone() {
            if n != "embed" && n != "head" {
                f(&n, self.get_mut(&n));
            }
        }
    }

    /// Replace every transformer weight (not embed/head) with the packed
    /// matrix `f` produces from its dense value.
    pub fn pack_linear_weights(&mut self, mut f: impl FnMut(&str, &Mat) -> QMat) {
        for n in self.order.clone() {
            if n != "embed" && n != "head" {
                let q = f(&n, self.get(&n));
                self.set_packed(&n, q);
            }
        }
    }

    // -------------------------------------------------------- persistence

    /// Legacy (pre-streaming) checkpoint magic: flat dense f32 tensors,
    /// no index. Still readable by [`Weights::load`].
    pub(crate) const LEGACY_MAGIC: &'static [u8; 8] = b"DARTQWT1";

    /// Save as a chunked indexed artifact (`artifact_io::save_indexed`):
    /// magic, config name, a per-tensor offset index, then one
    /// independently-readable blob per tensor. Packed tensors persist
    /// their codes + scales **natively** (bit-identical roundtrip, true
    /// low-bit footprint on disk); dense tensors stay raw f32. The same
    /// file opens lazily through `artifact_io::WeightStore` for
    /// out-of-core runs — see `docs/STREAMING.md`.
    pub fn save(&self, path: &Path) -> Result<()> {
        super::artifact_io::save_indexed(self, path)
    }

    /// Load a checkpoint: the indexed format written by [`Weights::save`],
    /// or the legacy flat-dense format of earlier revisions.
    pub fn load(path: &Path) -> Result<Weights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic == super::artifact_io::INDEX_MAGIC {
            drop(f);
            return super::artifact_io::load_indexed(path);
        }
        if &magic != Self::LEGACY_MAGIC {
            bail!("{path:?} is not a dartquant checkpoint");
        }
        let cfg_name = read_str(&mut f)?;
        let cfg = ModelConfig::builtin(&cfg_name)?;
        let count = read_u32(&mut f)? as usize;
        let mut map = BTreeMap::new();
        for _ in 0..count {
            let name = read_str(&mut f)?;
            let rows = read_u32(&mut f)? as usize;
            let cols = read_u32(&mut f)? as usize;
            let mut data = vec![0f32; rows * cols];
            let mut buf = vec![0u8; rows * cols * 4];
            f.read_exact(&mut buf)?;
            for (i, ch) in buf.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            map.insert(name, Mat::from_vec(rows, cols, data));
        }
        let order = cfg.param_names();
        for n in &order {
            if !map.contains_key(n) {
                bail!("checkpoint missing weight {n:?}");
            }
        }
        Ok(Weights { cfg, order, map: dense_map(map) })
    }

    /// Assemble a (possibly partial) weight collection from named tensors
    /// — the `artifact_io::WeightStore` checkout path. Iteration order
    /// follows the given tensor order; shapes are validated against the
    /// config. A partial set supports `get`/`tensor`/`set*` for its
    /// resident names only, which is exactly what the out-of-core stages
    /// need: they touch the names they checked out, nothing else.
    pub(crate) fn from_parts(cfg: ModelConfig, tensors: Vec<(String, Tensor)>) -> Weights {
        let mut map = BTreeMap::new();
        let mut order = Vec::with_capacity(tensors.len());
        for (name, t) in tensors {
            assert_eq!(t.shape(), cfg.param_shape(&name), "shape mismatch for {name}");
            order.push(name.clone());
            map.insert(name, t);
        }
        Weights { cfg, order, map }
    }
}

/// Wrap a dense construction map into the per-tensor representation.
fn dense_map(map: BTreeMap<String, Mat>) -> BTreeMap<String, Tensor> {
    map.into_iter().map(|(k, v)| (k, Tensor::F32(v))).collect()
}

pub(crate) fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn read_str(f: &mut impl Read) -> Result<String> {
    let n = read_u32(f)? as usize;
    if n > 1 << 20 {
        bail!("corrupt checkpoint: string length {n}");
    }
    let mut buf = vec![0u8; n];
    f.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

pub(crate) fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::builtin("llama2-tiny").unwrap()
    }

    #[test]
    fn init_has_all_params_with_right_shapes() {
        let w = Weights::default_synthetic(&tiny(), 1);
        for name in w.names().to_vec() {
            let (r, c) = w.cfg.param_shape(&name);
            assert_eq!(w.get(&name).shape(), (r, c), "{name}");
        }
        assert_eq!(w.nbytes(), w.cfg.n_params() as u64 * 4);
    }

    #[test]
    fn outlier_channels_are_planted() {
        let cfg = tiny();
        let plain = Weights::init_synthetic(&cfg, 7, 0, 1.0);
        let spiky = Weights::init_synthetic(&cfg, 7, 8, 12.0);
        // Same seed => same base weights; the spiky one has amplified rows.
        assert!(spiky.get("l0.wo").max_abs() > 5.0 * plain.get("l0.wo").max_abs());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Weights::default_synthetic(&tiny(), 42);
        let b = Weights::default_synthetic(&tiny(), 42);
        assert_eq!(a.get("l1.wq").data, b.get("l1.wq").data);
        let c = Weights::default_synthetic(&tiny(), 43);
        assert_ne!(a.get("l1.wq").data, c.get("l1.wq").data);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("dartquant-test-wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let w = Weights::default_synthetic(&tiny(), 3);
        w.save(&path).unwrap();
        let l = Weights::load(&path).unwrap();
        assert_eq!(l.cfg.name, "llama2-tiny");
        for name in w.names() {
            assert_eq!(w.get(name).data, l.get(name).data, "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("dartquant-test-wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Weights::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn moe_init_works() {
        let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        assert_eq!(w.get("l0.router").shape(), (4, 256));
        assert_eq!(w.get("l2.e1.wg").shape(), (512, 256));
    }

    #[test]
    fn packed_tensors_report_true_bytes_and_roundtrip_natively() {
        use crate::tensor::{QMat, QuantSpec};
        let mut w = Weights::default_synthetic(&tiny(), 9);
        assert!(!w.has_packed());
        let dense_bytes = w.nbytes();
        let q = QMat::quantize_rtn(w.get("l0.wq"), QuantSpec::new(4));
        let deq = q.dequantize();
        w.set_packed("l0.wq", q.clone());
        assert!(w.has_packed());
        assert!(w.nbytes() < dense_bytes);
        assert_eq!(w.tensor("l0.wq").to_mat().data, deq.data);
        let (d, a) = w.linear_bytes();
        assert!(a < d, "packed linears must shrink: {a} vs {d}");
        // save keeps packed codes + scales natively; load round-trips
        // them bit-identically (no dequantize/requantize detour).
        let dir = std::env::temp_dir().join("dartquant-test-wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("packed.bin");
        w.save(&path).unwrap();
        let l = Weights::load(&path).unwrap();
        assert!(l.has_packed());
        assert_eq!(l.tensor("l0.wq").as_packed().unwrap(), &q);
        assert_eq!(l.nbytes(), w.nbytes());
        assert_eq!(l.get("l1.wq").data, w.get("l1.wq").data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_flat_checkpoints_still_load() {
        // Hand-write a v1 (DARTQWT1) checkpoint: magic, config name,
        // count, then (name, rows, cols, f32 LE data) per tensor.
        let w = Weights::default_synthetic(&tiny(), 11);
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(Weights::LEGACY_MAGIC);
        write_str(&mut buf, &w.cfg.name).unwrap();
        buf.extend_from_slice(&(w.names().len() as u32).to_le_bytes());
        for (name, m) in w.ordered() {
            write_str(&mut buf, name).unwrap();
            buf.extend_from_slice(&(m.rows as u32).to_le_bytes());
            buf.extend_from_slice(&(m.cols as u32).to_le_bytes());
            for v in &m.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let dir = std::env::temp_dir().join("dartquant-test-wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.bin");
        std::fs::write(&path, &buf).unwrap();
        let l = Weights::load(&path).unwrap();
        for name in w.names() {
            assert_eq!(l.get(name).data, w.get(name).data, "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "is packed")]
    fn get_panics_on_packed_weight() {
        use crate::tensor::{QMat, QuantSpec};
        let mut w = Weights::default_synthetic(&tiny(), 9);
        let q = QMat::quantize_rtn(w.get("l0.wq"), QuantSpec::new(4));
        w.set_packed("l0.wq", q);
        let _ = w.get("l0.wq");
    }
}

#[cfg(test)]
mod grammar_tests {
    use super::*;
    use crate::data::{Corpus, Dialect};
    use crate::model::forward::{forward_one, FwdOptions, NoCapture};

    #[test]
    fn grammar_model_predicts_its_dialect() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let wiki = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let ptb = Corpus::new(Dialect::Ptb, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, wiki.successor()).unwrap();
        let mean = |v: &[f32]| v.iter().sum::<f32>() as f64 / v.len() as f64;
        let seq_w = wiki.valid_batch(1, 96, 0).remove(0);
        let seq_p = ptb.valid_batch(1, 96, 0).remove(0);
        let nll_w = mean(&forward_one(&w, &seq_w, FwdOptions::FP, &mut NoCapture));
        let nll_p = mean(&forward_one(&w, &seq_p, FwdOptions::FP, &mut NoCapture));
        let uniform = (cfg.vocab as f64).ln();
        assert!(nll_w < uniform - 0.8, "grammar model not predictive: {nll_w} vs uniform {uniform}");
        assert!(nll_p > nll_w + 0.3, "no dialect specificity: wiki {nll_w} vs ptb {nll_p}");
    }

    #[test]
    fn grammar_init_errors_are_contextful() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let err = Weights::default_grammar(&cfg, 1, &[0, 1, 2]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("successor table"), "{msg}");
        assert!(msg.contains(&cfg.vocab.to_string()), "{msg}");
        assert!(msg.contains(&cfg.name), "{msg}");
    }
}
