//! Native (pure-rust) transformer forward — the flexible oracle path.
//!
//! The PJRT artifacts (`fwd_*`, `fwdq_*`, `capture_*`) are the fast path;
//! this implementation mirrors `python/compile/model.py` op-for-op and is
//! used for (a) cross-checking the artifacts in integration tests,
//! (b) GPTQ activation capture with arbitrary hooks, and (c) running
//! configurations for which no artifact was emitted.
//!
//! The attention/FFN block body is the **shared incremental function**
//! [`block_step`]: it processes "the next `tn` positions" against a
//! [`KvSlot`] cache (contiguous [`LayerKv`] or the serving layer's paged
//! view) holding everything before them. [`forward_one`]
//! calls it with a fresh per-layer cache over the whole sequence (the
//! historical full-sequence semantics, bit-for-bit); the serving path
//! (`serve::DecodeSession`) calls the same function per prefill chunk /
//! per decoded token with a persistent cache — which is why KV-cached
//! decode is bit-identical to this oracle in fp32 (`rust/tests/serving.rs`).

use super::kv::{KvSlot, LayerKv};
use super::weights::{Tensor, Weights};
use crate::tensor::{
    matmul_transb, matmul_transb_deq, matmul_transb_deq_sharded, matmul_transb_qact,
    matmul_transb_qact_sharded, matmul_transb_sharded, shard_ranges, Mat, QAct,
};

// The per-row asymmetric activation grid and its fake-quant kernels live
// with the quantized-activation type in `tensor::qact` (the KV-cache code
// storage in `model::kv` lands on exactly this grid too); re-exported
// here so the historical `model::forward` paths keep working.
pub use crate::tensor::qact::{fake_quant_row, fake_quant_rows, quantize_act};
pub(crate) use crate::tensor::qact::act_grid as fq_row_grid;

/// Quantization/rotation switches for the native forward.
#[derive(Clone, Copy, Debug)]
pub struct FwdOptions {
    /// Activation quant levels (65536.0 = off).
    pub a_levels: f32,
    /// KV-cache quant levels (65536.0 = off).
    pub kv_levels: f32,
    /// Apply the online R3/R4 Hadamards (requires wd pre-fused with H_f).
    pub use_had: bool,
    /// Within-layer tensor-parallel shards (1 = unsharded). Linears take
    /// the column-parallel plan and attention shards over kv heads —
    /// both bit-identical to the unsharded path by construction
    /// (`tensor::shard`, `docs/CONCURRENCY.md`).
    pub shards: usize,
}

impl FwdOptions {
    pub const FP: FwdOptions =
        FwdOptions { a_levels: 65536.0, kv_levels: 65536.0, use_had: false, shards: 1 };

    pub fn quant(a_bits: u8, kv_bits: u8, use_had: bool) -> FwdOptions {
        FwdOptions {
            a_levels: super::config::BitSetting::levels(a_bits),
            kv_levels: super::config::BitSetting::levels(kv_bits),
            use_had,
            shards: 1,
        }
    }

    /// The same options with a within-layer shard count.
    pub fn with_shards(mut self, shards: usize) -> FwdOptions {
        self.shards = shards.max(1);
        self
    }
}

fn rmsnorm(x: &Mat, eps: f32) -> Mat {
    let mut out = x.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// RoPE for one head row at absolute position `pos` — half-split
/// convention, matching `model.rope`.
fn rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let half = row.len() / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let a = row[i];
        let b = row[half + i];
        row[i] = a * cos - b * sin;
        row[half + i] = a * sin + b * cos;
    }
}

/// RoPE over one head's (T, hd) block whose first row sits at absolute
/// position `start`.
fn rope_block(x: &mut Mat, start: usize, theta: f32) {
    for i in 0..x.rows {
        rope_row(x.row_mut(i), start + i, theta);
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Gather a head block: columns [h*hd, (h+1)*hd) of a (T, H*hd) matrix.
fn head_block(x: &Mat, h: usize, hd: usize) -> Mat {
    Mat::from_fn(x.rows, hd, |i, j| x.at(i, h * hd + j))
}

/// Apply the orthonormal Hadamard to every row (native R3/R4).
fn hadamard_rows(x: &mut Mat) {
    crate::linalg::fwht_rows(x);
}

/// One linear (`y = x · Wᵀ`): dense weights take the f32 kernel; packed
/// weights stream their codes — the tiled i8×i8 → i32 panel GEMM when
/// the caller holds the activation's integer codes (`qx`, computed once
/// per layer boundary by [`quantize_act`]), the bit-exact dequantizing
/// path otherwise (fp/wide activation grids, grouped weight scales).
/// `shards > 1` routes every variant through its column-parallel plan —
/// the same per-element arithmetic over explicit disjoint output ranges,
/// so the result is bit-identical at any shard count.
fn linear(w: &Weights, name: &str, x: &Mat, qx: Option<&QAct>, shards: usize) -> Mat {
    if shards > 1 {
        return match (w.tensor(name), qx) {
            (Tensor::F32(m), _) => matmul_transb_sharded(x, m, shards),
            (Tensor::Packed(q), Some(qa)) => matmul_transb_qact_sharded(x, qa, q, shards),
            (Tensor::Packed(q), None) => matmul_transb_deq_sharded(x, q, shards),
        };
    }
    match (w.tensor(name), qx) {
        (Tensor::F32(m), _) => matmul_transb(x, m),
        (Tensor::Packed(q), Some(qa)) => matmul_transb_qact(x, qa, q),
        (Tensor::Packed(q), None) => matmul_transb_deq(x, q),
    }
}

/// Token embedding rows for a slice of token ids.
pub fn embed_tokens(w: &Weights, tokens: &[i32]) -> Mat {
    let embed = w.get("embed");
    Mat::from_fn(tokens.len(), w.cfg.dim, |i, j| embed.at(tokens[i] as usize, j))
}

/// Final RMSNorm + LM head over residual rows: logits `(rows, vocab)` —
/// the one head evaluation `forward_one` and the serving path share.
pub fn head_logits(w: &Weights, x: &Mat) -> Mat {
    let h = rmsnorm(x, w.cfg.norm_eps);
    matmul_transb(&h, w.get("head"))
}

/// [`head_logits`] over residual rows `[lo, hi)` only. RMSNorm and the
/// head are per-row, so this is bit-identical to slicing the full
/// `head_logits` output while skipping the other rows' vocab-wide
/// matmuls — what lets serving evaluate the head for exactly the
/// positions it will read (the last row for plain decode, a proposal
/// window for speculative verification).
pub fn head_logits_range(w: &Weights, x: &Mat, lo: usize, hi: usize) -> Mat {
    head_logits(w, &x.rows_slice(lo, hi))
}

/// NLL of token `next` under one logits row (log-sum-exp minus the
/// target logit) — shared by `forward_one` and the decode-parity tests.
pub fn nll_from_logits(row: &[f32], next: usize) -> f32 {
    let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
    let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
    lse - row[next]
}

/// Capture hook sites during a forward pass.
pub trait CaptureHook {
    /// Post-RMSNorm hidden state feeding attention (site `2l`) or the FFN
    /// (site `2l+1`) — the R1 calibration site.
    fn on_x_site(&mut self, _site: usize, _h: &Mat) {}
    /// Value-projection output of layer `l` — the R2 calibration site.
    fn on_v_site(&mut self, _layer: usize, _v: &Mat) {}
    /// Input activations of a named linear (GPTQ Hessian capture).
    fn on_linear_input(&mut self, _name: &str, _x: &Mat) {}
}

/// No-op hook.
pub struct NoCapture;
impl CaptureHook for NoCapture {}

/// One transformer block over the `x.rows` **new** positions starting at
/// `kv.positions()`: extends the layer's KV cache with the new K/V rows
/// (RoPE → optional online R3 → KV fake-quant, in the full-sequence
/// order), attends causally over the whole cache, then applies the FFN.
///
/// `x` is the residual stream of the new positions and is updated in
/// place. With a fresh cache this **is** the historical full-sequence
/// block; with a persistent cache it is one prefill chunk or one decoded
/// token — every per-row operation is position-local, so both schedules
/// produce bit-identical residuals.
pub fn block_step(
    w: &Weights,
    l: usize,
    x: &mut Mat,
    kv: &mut dyn KvSlot,
    opt: FwdOptions,
    hook: &mut dyn CaptureHook,
) {
    let cfg = &w.cfg;
    let (hd, nh, nkv) = (cfg.head_dim, cfg.n_heads, cfg.n_kv_heads);
    let start = kv.positions();
    let tn = x.rows;
    let name = |leaf: &str| format!("l{l}.{leaf}");

    // ---- attention ----
    let h = rmsnorm(x, cfg.norm_eps);
    hook.on_x_site(2 * l, &h);
    let mut hq = h;
    // One activation quantization at the boundary; wq/wk/wv share the
    // codes instead of re-deriving them per linear.
    let qh = quantize_act(&mut hq, opt.a_levels);
    hook.on_linear_input(&name("wq"), &hq);
    let q_all = linear(w, &name("wq"), &hq, qh.as_ref(), opt.shards);
    let k_all = linear(w, &name("wk"), &hq, qh.as_ref(), opt.shards);
    let v_all = linear(w, &name("wv"), &hq, qh.as_ref(), opt.shards);
    hook.on_v_site(l, &v_all);

    // New positions' K/V rows into the cache; KV quantization happens at
    // the cache boundary, on exactly the rows attention reads back.
    kv.extend(tn);
    for head in 0..nkv {
        let mut kh = head_block(&k_all, head, hd);
        rope_block(&mut kh, start, cfg.rope_theta);
        if opt.use_had {
            hadamard_rows(&mut kh); // R3 — cancels in q·kᵀ
        }
        let vh = head_block(&v_all, head, hd);
        for i in 0..tn {
            kv.set_k(start + i, head, kh.row(i));
            kv.set_v(start + i, head, vh.row(i));
        }
    }

    let mut attn_out = Mat::zeros(tn, nh * hd);
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let t_total = kv.positions();
    if opt.shards > 1 {
        // Per-kv-head sharded attention. KV decode stays **sequential**
        // on the calling thread — pager page faults / LRU touches keep
        // their deterministic order — then the pure-f32 per-head compute
        // fans out over kv heads, each shard writing the disjoint
        // attn_out column block of its q heads. Per-element arithmetic
        // (scores, softmax, weighted-V accumulation order) is the serial
        // loop verbatim, so the residual stream is bit-identical.
        let mut heads: Vec<(Mat, Mat)> = Vec::with_capacity(nkv);
        for kv_head in 0..nkv {
            let mut kh = Mat::zeros(t_total, hd);
            let mut vh = Mat::zeros(t_total, hd);
            kv.k_head_into(kv_head, &mut kh);
            kv.v_head_into(kv_head, &mut vh);
            heads.push((kh, vh));
        }
        let row_w = nh * hd;
        let out_ptr = crate::tensor::SendPtr(attn_out.data.as_mut_ptr());
        let q_all = &q_all;
        crate::tensor::run_shards(&shard_ranges(nkv, opt.shards), |lo, hi| {
            let out_ptr = &out_ptr;
            for kv_head in lo..hi {
                let (kh, vh) = &heads[kv_head];
                for head in kv_head * rep..(kv_head + 1) * rep {
                    let mut qh = head_block(q_all, head, hd);
                    rope_block(&mut qh, start, cfg.rope_theta);
                    if opt.use_had {
                        hadamard_rows(&mut qh);
                    }
                    for i in 0..tn {
                        let p = start + i;
                        let mut scores = vec![0f32; p + 1];
                        let qrow = qh.row(i);
                        let mut mx = f32::MIN;
                        for (j, s) in scores.iter_mut().enumerate() {
                            *s = qrow.iter().zip(kh.row(j)).map(|(a, b)| a * b).sum::<f32>()
                                * scale;
                            mx = mx.max(*s);
                        }
                        let mut denom = 0f32;
                        for s in scores.iter_mut() {
                            *s = (*s - mx).exp();
                            denom += *s;
                        }
                        for (j, s) in scores.iter().enumerate() {
                            let prob = s / denom;
                            for (c, vv) in vh.row(j).iter().enumerate() {
                                // SAFETY: this shard owns kv heads
                                // [lo, hi); their q heads' column blocks
                                // are disjoint from other shards' writes.
                                unsafe {
                                    *out_ptr.0.add(i * row_w + head * hd + c) += prob * vv;
                                }
                            }
                        }
                    }
                }
            }
        });
    } else {
        // One K and one V scratch per block call, refilled per kv head and
        // shared by its q heads — no per-head allocation on the decode path.
        let mut kh = Mat::zeros(t_total, hd);
        let mut vh = Mat::zeros(t_total, hd);
        for kv_head in 0..nkv {
            kv.k_head_into(kv_head, &mut kh);
            kv.v_head_into(kv_head, &mut vh);
            for head in kv_head * rep..(kv_head + 1) * rep {
                let mut qh = head_block(&q_all, head, hd);
                rope_block(&mut qh, start, cfg.rope_theta);
                if opt.use_had {
                    hadamard_rows(&mut qh);
                }
                // causal attention: new position start+i sees [0, start+i]
                for i in 0..tn {
                    let p = start + i;
                    let mut scores = vec![0f32; p + 1];
                    let qrow = qh.row(i);
                    let mut mx = f32::MIN;
                    for (j, s) in scores.iter_mut().enumerate() {
                        *s = qrow.iter().zip(kh.row(j)).map(|(a, b)| a * b).sum::<f32>() * scale;
                        mx = mx.max(*s);
                    }
                    let mut denom = 0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - mx).exp();
                        denom += *s;
                    }
                    let out_row = attn_out.row_mut(i);
                    for (j, s) in scores.iter().enumerate() {
                        let prob = s / denom;
                        for (c, vv) in vh.row(j).iter().enumerate() {
                            out_row[head * hd + c] += prob * vv;
                        }
                    }
                }
            }
        }
    }
    let qo = quantize_act(&mut attn_out, opt.a_levels);
    hook.on_linear_input(&name("wo"), &attn_out);
    let proj = linear(w, &name("wo"), &attn_out, qo.as_ref(), opt.shards);
    x.add_assign(&proj);

    // ---- ffn ----
    ffn_step(w, l, x, opt, hook);
}

/// The FFN half of a block over `x.rows` positions (position-local, so it
/// needs no cache).
fn ffn_step(w: &Weights, l: usize, x: &mut Mat, opt: FwdOptions, hook: &mut dyn CaptureHook) {
    let cfg = &w.cfg;
    let (d, t) = (cfg.dim, x.rows);
    let name = |leaf: &str| format!("l{l}.{leaf}");
    let h2 = rmsnorm(x, cfg.norm_eps);
    hook.on_x_site(2 * l + 1, &h2);
    let mut h2q = h2;
    let qh2 = quantize_act(&mut h2q, opt.a_levels);
    if cfg.is_moe() {
        let gate_logits = linear(w, &name("router"), &h2q, qh2.as_ref(), opt.shards); // (T, E)
        let mut ffn = Mat::zeros(t, d);
        for i in 0..t {
            // top-k experts by logit (jax lax.top_k tie-break: lower
            // index, including for -0.0 == +0.0; a NaN logit falls back
            // to total_cmp so the sort is deterministic instead of
            // panicking)
            let logits = gate_logits.row(i);
            let mut idx: Vec<usize> = (0..cfg.n_experts).collect();
            idx.sort_by(|&a, &b| {
                logits[b]
                    // dqlint::allow(float-sort-determinism): jax parity
                    // needs -0.0 == +0.0 resolved by the index tie-break,
                    // which total_cmp alone would order; NaN falls back to
                    // total_cmp so the comparator is still total.
                    .partial_cmp(&logits[a])
                    .unwrap_or_else(|| logits[b].total_cmp(&logits[a]))
                    .then(a.cmp(&b))
            });
            let top = &idx[..cfg.top_k];
            let mx = logits[top[0]];
            let exps: Vec<f32> = top.iter().map(|&e| (logits[e] - mx).exp()).collect();
            let denom: f32 = exps.iter().sum();
            // The token's codes come from the whole-matrix quantization —
            // the grid is per-row, so slicing commutes with quantizing.
            let qrow = qh2.as_ref().map(|qa| qa.rows_slice(i, i + 1));
            for (rank, &e) in top.iter().enumerate() {
                let gate = exps[rank] / denom;
                let ename = |leaf: &str| format!("l{l}.e{e}.{leaf}");
                let row = h2q.rows_slice(i, i + 1);
                let g = linear(w, &ename("wg"), &row, qrow.as_ref(), opt.shards);
                let u = linear(w, &ename("wu"), &row, qrow.as_ref(), opt.shards);
                let mut a = Mat::from_fn(1, cfg.ffn_dim, |_, j| silu(g.at(0, j)) * u.at(0, j));
                if opt.use_had {
                    hadamard_rows(&mut a);
                }
                let qa = quantize_act(&mut a, opt.a_levels);
                let y = linear(w, &ename("wd"), &a, qa.as_ref(), opt.shards);
                for j in 0..d {
                    *ffn.at_mut(i, j) += gate * y.at(0, j);
                }
            }
        }
        x.add_assign(&ffn);
    } else {
        hook.on_linear_input(&name("wg"), &h2q);
        let g = linear(w, &name("wg"), &h2q, qh2.as_ref(), opt.shards);
        let u = linear(w, &name("wu"), &h2q, qh2.as_ref(), opt.shards);
        let mut a = Mat::from_fn(t, cfg.ffn_dim, |i, j| silu(g.at(i, j)) * u.at(i, j));
        if opt.use_had {
            hadamard_rows(&mut a); // R4 (wd pre-fused with H)
        }
        let qa = quantize_act(&mut a, opt.a_levels);
        hook.on_linear_input(&name("wd"), &a);
        let y = linear(w, &name("wd"), &a, qa.as_ref(), opt.shards);
        x.add_assign(&y);
    }
}

/// Run the forward pass for one sequence, returning per-position NLL
/// (length T-1). `hook` observes activations on the way.
pub fn forward_one(
    w: &Weights,
    tokens: &[i32],
    opt: FwdOptions,
    hook: &mut dyn CaptureHook,
) -> Vec<f32> {
    let cfg = &w.cfg;
    let t = tokens.len();
    let mut x = embed_tokens(w, tokens);
    for l in 0..cfg.n_layers {
        // Fresh per-layer cache: the whole sequence is "new positions",
        // dropped after the block so peak memory matches the historical
        // full-sequence path.
        let mut kv = LayerKv::for_model(cfg, opt.kv_levels, false);
        block_step(w, l, &mut x, &mut kv, opt, hook);
    }
    // ---- head + NLL ----
    let logits = head_logits(w, &x);
    (0..t - 1)
        .map(|i| nll_from_logits(logits.row(i), tokens[i + 1] as usize))
        .collect()
}

/// Batch forward: thread-parallel over sequences; returns (B, T-1) NLLs.
pub fn forward_batch(w: &Weights, batch: &[Vec<i32>], opt: FwdOptions) -> Vec<Vec<f32>> {
    let pool = crate::util::threadpool::ThreadPool::new(
        crate::util::threadpool::ThreadPool::default_parallelism().min(batch.len().max(1)),
    );
    // Weights are shared read-only across workers.
    pool.map(batch.to_vec(), {
        let w = w.clone();
        move |seq| forward_one(&w, &seq, opt, &mut NoCapture)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::prng::Pcg64;

    fn setup() -> (Weights, Vec<i32>) {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let mut rng = Pcg64::new(2);
        let toks: Vec<i32> = (0..24).map(|_| rng.below(cfg.vocab) as i32).collect();
        (w, toks)
    }

    #[test]
    fn nll_is_finite_and_near_uniform_for_random_weights() {
        let (w, toks) = setup();
        let nll = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        assert_eq!(nll.len(), toks.len() - 1);
        let mean: f32 = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!(mean.is_finite());
        let uniform = (w.cfg.vocab as f32).ln();
        assert!((mean - uniform).abs() < 2.0, "mean nll {mean} vs ln V {uniform}");
    }

    #[test]
    fn fake_quant_rows_matches_semantics() {
        let mut x = Mat::from_vec(1, 4, vec![0.0, 1.0, 2.0, 3.0]);
        fake_quant_rows(&mut x, 4.0); // step = 1 → lossless here
        assert_eq!(x.data, vec![0.0, 1.0, 2.0, 3.0]);
        let mut y = Mat::from_vec(1, 3, vec![0.0, 0.4, 1.0]);
        fake_quant_rows(&mut y, 3.0); // step 0.5 → 0.4 -> 0.5
        assert_eq!(y.data, vec![0.0, 0.5, 1.0]);
        // levels >= 2^15 disables
        let mut z = Mat::from_vec(1, 3, vec![0.123, 4.567, -2.0]);
        let before = z.clone();
        fake_quant_rows(&mut z, 65536.0);
        assert_eq!(z.data, before.data);
    }

    #[test]
    fn fake_quant_disable_threshold_is_32768() {
        // The documented contract: `levels >= 32768` disables. 32768 is a
        // no-op; 32767 and 32766 still quantize.
        let src = vec![0.0f32, 0.137_731, 1.0];
        let mut off = Mat::from_vec(1, 3, src.clone());
        fake_quant_rows(&mut off, 32768.0);
        assert_eq!(off.data, src, "32768 levels must disable");
        for levels in [32767.0f32, 32766.0] {
            let mut on = Mat::from_vec(1, 3, src.clone());
            fake_quant_rows(&mut on, levels);
            assert_ne!(on.data, src, "{levels} levels must quantize");
            // and the quantized values still sit within half a step
            let step = 1.0 / (levels - 1.0);
            for (a, b) in src.iter().zip(&on.data) {
                assert!((a - b).abs() <= step / 2.0 + 1e-7);
            }
        }
    }

    #[test]
    fn quantization_increases_nll_mildly() {
        let (w, toks) = setup();
        let fp = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let q8 = forward_one(&w, &toks, FwdOptions::quant(8, 16, false), &mut NoCapture);
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean(&fp) - mean(&q8)).abs() < 0.5, "8-bit ≈ lossless");
    }

    #[test]
    fn hadamard_r3_cancels_in_fp_attention() {
        // With no quantization, use_had must not change outputs — but wd
        // must be pre-fused. Fuse H into each wd first.
        let (mut w, toks) = setup();
        let fp = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let h = crate::linalg::hadamard_matrix(w.cfg.ffn_dim);
        for l in 0..w.cfg.n_layers {
            let name = format!("l{l}.wd");
            let fused = crate::tensor::matmul(w.get(&name), &h);
            w.set(&name, fused);
        }
        let had = forward_one(
            &w,
            &toks,
            FwdOptions { a_levels: 65536.0, kv_levels: 65536.0, use_had: true, shards: 1 },
            &mut NoCapture,
        );
        for (a, b) in fp.iter().zip(&had) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn capture_hook_sees_all_sites() {
        struct Counter {
            x: usize,
            v: usize,
            lin: usize,
        }
        impl CaptureHook for Counter {
            fn on_x_site(&mut self, _s: usize, _h: &Mat) {
                self.x += 1;
            }
            fn on_v_site(&mut self, _l: usize, _v: &Mat) {
                self.v += 1;
            }
            fn on_linear_input(&mut self, _n: &str, _x: &Mat) {
                self.lin += 1;
            }
        }
        let (w, toks) = setup();
        let mut c = Counter { x: 0, v: 0, lin: 0 };
        forward_one(&w, &toks, FwdOptions::FP, &mut c);
        assert_eq!(c.x, 2 * w.cfg.n_layers);
        assert_eq!(c.v, w.cfg.n_layers);
        assert_eq!(c.lin, 4 * w.cfg.n_layers);
    }

    #[test]
    fn packed_forward_matches_dense_fake_quant_forward() {
        let (w, toks) = setup();
        let dense_q = crate::quant::rtn_quantize_model(&w, 4);
        let packed_q = crate::quant::rtn_quantize_model_packed(&w, 4);
        assert!(packed_q.has_packed());
        // W4A4: the packed path runs i8×i8 → i32 with exact integer
        // accumulation; only f32 reassociation separates it from the
        // dense fake-quant oracle.
        let opt = FwdOptions::quant(4, 16, false);
        let a = forward_one(&dense_q, &toks, opt, &mut NoCapture);
        let b = forward_one(&packed_q, &toks, opt, &mut NoCapture);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-3 * x.abs().max(1.0), "{x} vs {y}");
        }
        // With fp activations the packed path is the bit-exact deq oracle.
        let fp_dense = forward_one(&dense_q, &toks, FwdOptions::FP, &mut NoCapture);
        let fp_packed = forward_one(&packed_q, &toks, FwdOptions::FP, &mut NoCapture);
        assert_eq!(fp_dense, fp_packed);
    }

    #[test]
    fn packed_moe_forward_runs() {
        let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 5);
        let q = crate::quant::rtn_quantize_model_packed(&w, 4);
        let mut rng = Pcg64::new(6);
        let toks: Vec<i32> = (0..16).map(|_| rng.below(cfg.vocab) as i32).collect();
        let nll = forward_one(&q, &toks, FwdOptions::quant(4, 16, false), &mut NoCapture);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn moe_forward_runs() {
        let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 5);
        let mut rng = Pcg64::new(6);
        let toks: Vec<i32> = (0..16).map(|_| rng.below(cfg.vocab) as i32).collect();
        let nll = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batch_matches_single() {
        let (w, toks) = setup();
        let single = forward_one(&w, &toks, FwdOptions::FP, &mut NoCapture);
        let batch = forward_batch(&w, &[toks.clone(), toks], FwdOptions::FP);
        assert_eq!(batch[0], single);
        assert_eq!(batch[1], single);
    }
}
