//! Model configurations — rust mirror of `python/compile/configs.py`.
//!
//! The python side embeds its configs into `artifacts/manifest.json`; an
//! integration test asserts both sides agree, so drift is caught at
//! `make test` time rather than as silent shape errors.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// A tiny Llama-architecture configuration (see DESIGN.md §3 for how these
/// stand in for the paper's Llama-2/3 7B–70B).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn_dim: usize,
    pub vocab: usize,
    pub head_dim: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
    pub n_experts: usize,
    pub top_k: usize,
}

impl ModelConfig {
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.param_names()
            .iter()
            .map(|n| {
                let (r, c) = self.param_shape(n);
                r * c
            })
            .sum()
    }

    /// Flat ordered parameter list — must match `configs.param_names`.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        for l in 0..self.n_layers {
            for leaf in ["wq", "wk", "wv", "wo"] {
                names.push(format!("l{l}.{leaf}"));
            }
            if self.is_moe() {
                names.push(format!("l{l}.router"));
                for e in 0..self.n_experts {
                    for leaf in ["wg", "wu", "wd"] {
                        names.push(format!("l{l}.e{e}.{leaf}"));
                    }
                }
            } else {
                for leaf in ["wg", "wu", "wd"] {
                    names.push(format!("l{l}.{leaf}"));
                }
            }
        }
        names.push("head".to_string());
        names
    }

    /// Shape of each named parameter ([out, in], applied as x @ Wᵀ).
    pub fn param_shape(&self, name: &str) -> (usize, usize) {
        let (d, f, v, kd) = (self.dim, self.ffn_dim, self.vocab, self.kv_dim());
        match name {
            "embed" | "head" => (v, d),
            _ => {
                let leaf = name.rsplit('.').next().unwrap();
                match leaf {
                    "wq" => (self.q_dim(), d),
                    "wk" | "wv" => (kd, d),
                    "wo" => (d, self.q_dim()),
                    "wg" | "wu" => (f, d),
                    "wd" => (d, f),
                    "router" => (self.n_experts, d),
                    other => panic!("unknown param leaf {other:?}"),
                }
            }
        }
    }

    /// Built-in config set (mirrors python `CONFIGS`).
    pub fn builtin(name: &str) -> Result<ModelConfig> {
        let mk = |name: &str, dim, n_layers, n_heads, n_kv_heads, ffn_dim, vocab,
                  n_experts, top_k| ModelConfig {
            name: name.to_string(),
            dim,
            n_layers,
            n_heads,
            n_kv_heads,
            ffn_dim,
            vocab,
            head_dim: 64,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            n_experts,
            top_k,
        };
        Ok(match name {
            "llama2-tiny" => mk("llama2-tiny", 256, 4, 4, 4, 512, 512, 0, 0),
            "llama2-small" => mk("llama2-small", 320, 5, 5, 5, 768, 512, 0, 0),
            "llama2-large" => mk("llama2-large", 512, 8, 8, 8, 1280, 512, 0, 0),
            "llama3-small" => mk("llama3-small", 384, 4, 6, 2, 1024, 1024, 0, 0),
            "llama3-large" => mk("llama3-large", 640, 8, 10, 2, 1536, 1024, 0, 0),
            "mixtral-tiny" => mk("mixtral-tiny", 256, 4, 4, 4, 512, 512, 4, 2),
            other => bail!(
                "unknown model config {other:?} (expected one of: llama2-tiny, \
                 llama2-small, llama2-large, llama3-small, llama3-large, mixtral-tiny)"
            ),
        })
    }

    pub fn all_builtin() -> Vec<ModelConfig> {
        ["llama2-tiny", "llama2-small", "llama2-large", "llama3-small",
         "llama3-large", "mixtral-tiny"]
            .iter()
            .map(|n| Self::builtin(n).unwrap())
            .collect()
    }

    /// The paper model each config stands in for (labels in bench output).
    pub fn paper_name(&self) -> &'static str {
        match self.name.as_str() {
            "llama2-tiny" => "Llama-2 7B (tiny stand-in)",
            "llama2-small" => "Llama-2 13B (tiny stand-in)",
            "llama2-large" => "Llama-2 70B (tiny stand-in)",
            "llama3-small" => "Llama-3 8B (tiny stand-in)",
            "llama3-large" => "Llama-3 70B (tiny stand-in)",
            "mixtral-tiny" => "Mixtral-8x7B (tiny stand-in)",
            _ => "custom",
        }
    }

    /// Parse from the manifest's `models` section (written by aot.py).
    pub fn from_manifest_json(name: &str, j: &Json) -> Result<ModelConfig> {
        let g = |k: &str| -> Result<usize> {
            j.get_usize(k).with_context(|| format!("model {name}: missing {k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            dim: g("dim")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            n_kv_heads: g("n_kv_heads")?,
            ffn_dim: g("ffn_dim")?,
            vocab: g("vocab")?,
            head_dim: g("head_dim")?,
            rope_theta: j.get_f64("rope_theta").unwrap_or(10000.0) as f32,
            norm_eps: j.get_f64("norm_eps").unwrap_or(1e-5) as f32,
            n_experts: j.get_usize("n_experts").unwrap_or(0),
            top_k: j.get_usize("top_k").unwrap_or(0),
        })
    }
}

/// Quantization bit setting in the paper's W-A-KV notation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitSetting {
    pub w: u8,
    pub a: u8,
    pub kv: u8,
}

impl BitSetting {
    pub const FP: BitSetting = BitSetting { w: 16, a: 16, kv: 16 };
    pub const W4A8: BitSetting = BitSetting { w: 4, a: 8, kv: 16 };
    pub const W4A4: BitSetting = BitSetting { w: 4, a: 4, kv: 16 };
    pub const W4A4KV4: BitSetting = BitSetting { w: 4, a: 4, kv: 4 };

    pub fn parse(s: &str) -> Result<BitSetting> {
        let parts: Vec<&str> = s.split('-').collect();
        if parts.len() != 3 {
            bail!("bit setting must be W-A-KV, e.g. 4-4-16, got {s:?}");
        }
        let p = |x: &str| -> Result<u8> {
            x.parse().map_err(|_| anyhow::anyhow!("bad bit width {x:?}"))
        };
        Ok(BitSetting { w: p(parts[0])?, a: p(parts[1])?, kv: p(parts[2])? })
    }

    pub fn label(&self) -> String {
        format!("{}-{}-{}", self.w, self.a, self.kv)
    }

    /// Level count for a bit width (16 ⇒ "off": sentinel ≥ 2^15 disables
    /// the in-graph fake-quant, matching `model._fq_act`).
    pub fn levels(bits: u8) -> f32 {
        if bits >= 16 {
            65536.0
        } else {
            (1u32 << bits) as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_roundtrip_and_shapes() {
        for cfg in ModelConfig::all_builtin() {
            assert_eq!(cfg.head_dim * cfg.n_heads, cfg.q_dim());
            assert!(cfg.n_heads % cfg.n_kv_heads.max(1) == 0, "{}", cfg.name);
            for n in cfg.param_names() {
                let (r, c) = cfg.param_shape(&n);
                assert!(r > 0 && c > 0);
            }
            assert!(cfg.n_params() > 100_000, "{}", cfg.name);
        }
    }

    #[test]
    fn param_order_starts_embed_ends_head() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let names = cfg.param_names();
        assert_eq!(names.first().unwrap(), "embed");
        assert_eq!(names.last().unwrap(), "head");
        assert_eq!(names.len(), 1 + 4 * 7 + 1);
    }

    #[test]
    fn moe_param_names_include_experts() {
        let cfg = ModelConfig::builtin("mixtral-tiny").unwrap();
        let names = cfg.param_names();
        assert!(names.iter().any(|n| n == "l0.router"));
        assert!(names.iter().any(|n| n == "l3.e3.wd"));
        assert_eq!(cfg.param_shape("l0.router"), (4, 256));
    }

    #[test]
    fn hadamard_constructible_at_every_rotation_site() {
        use crate::linalg::hadamard_supported;
        for cfg in ModelConfig::all_builtin() {
            assert!(hadamard_supported(cfg.dim), "{} dim", cfg.name);
            assert!(hadamard_supported(cfg.head_dim), "{} head", cfg.name);
            assert!(hadamard_supported(cfg.ffn_dim), "{} ffn", cfg.name);
        }
    }

    #[test]
    fn bit_settings_parse_and_label() {
        assert_eq!(BitSetting::parse("4-4-16").unwrap(), BitSetting::W4A4);
        assert_eq!(BitSetting::W4A4KV4.label(), "4-4-4");
        assert!(BitSetting::parse("4-4").is_err());
        assert_eq!(BitSetting::levels(4), 16.0);
        assert_eq!(BitSetting::levels(16), 65536.0);
    }

    #[test]
    fn unknown_config_is_an_error() {
        assert!(ModelConfig::builtin("llama9").is_err());
    }
}
