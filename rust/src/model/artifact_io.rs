//! Artifact I/O, in both senses:
//!
//! * **PJRT glue** — builds the ordered `Value` input lists for `fwd_*`,
//!   `fwdq_*`, `capture_*`, `spin_*` and `train_*` entry points and
//!   unpacks their outputs (the original role of this module);
//! * **the chunked on-disk weight artifact** — [`save_indexed`] /
//!   [`load_indexed`] write/read a per-tensor offset index followed by
//!   independently-readable blobs (dense f32 *or* packed `QMat`
//!   codes + scales, roundtripped natively), and [`WeightStore`] opens
//!   the same file lazily: tensors are checked out as [`WeightLease`]s,
//!   charged against a `MemoryGate`, optionally mutated and written
//!   back, then released. This is the substrate of the out-of-core
//!   streaming pipeline (`Pipeline::builder(..).streaming(true)`) — see
//!   `docs/STREAMING.md` for the index format, the lease lifecycle and
//!   the resident-budget accounting rules.

use super::config::ModelConfig;
use super::weights::{read_str, read_u32, write_str, Tensor, Weights};
use crate::coordinator::budget::{MemoryGate, MemoryLease};
use crate::runtime::{Executable, Runtime, Value};
use crate::tensor::{Mat, QMat};
use crate::util::sync::lock_or_poisoned;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Token batch with the fixed artifact geometry (B, T).
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>, // row-major (B, T)
}

impl TokenBatch {
    pub fn new(seqs: &[Vec<i32>]) -> TokenBatch {
        assert!(!seqs.is_empty());
        let seq = seqs[0].len();
        assert!(seqs.iter().all(|s| s.len() == seq), "ragged batch");
        TokenBatch {
            batch: seqs.len(),
            seq,
            tokens: seqs.iter().flatten().copied().collect(),
        }
    }

    pub fn rows(&self) -> Vec<Vec<i32>> {
        self.tokens.chunks(self.seq).map(|c| c.to_vec()).collect()
    }

    pub fn to_value(&self) -> Value {
        Value::from_i32(vec![self.batch, self.seq], self.tokens.clone())
    }
}

/// Weight tensors as ordered artifact inputs (dense models only; the
/// artifact entry points bail first when the model holds packed weights,
/// which cannot feed the f32-shaped artifact signatures).
pub fn weight_values(w: &Weights) -> Vec<Value> {
    w.ordered().map(|(_, m)| Value::from_mat(m)).collect()
}

/// Contextful guard for the artifact entry points: packed models must
/// evaluate through the native forward instead.
fn ensure_dense(w: &Weights) -> Result<()> {
    anyhow::ensure!(
        !w.has_packed(),
        "model '{}' holds packed weights, which cannot feed the PJRT artifacts \
         (dense f32 inputs) — evaluate with the native path (eval::ppl_native, \
         zeroshot::*_native) or rerun the pipeline without --packed",
        w.cfg.name
    );
    Ok(())
}

/// Run `fwd_{cfg}`: per-position NLL (B, T-1).
pub fn run_fwd(rt: &Runtime, w: &Weights, toks: &TokenBatch) -> Result<Mat> {
    ensure_dense(w)?;
    let name = format!("fwd_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    let out = rt.run(&name, &inputs)?;
    out[0].to_mat()
}

/// Run `fwdq_{cfg}` with activation/KV fake-quant and optional online
/// Hadamard (wd must be pre-fused when `use_had`).
pub fn run_fwdq(
    rt: &Runtime,
    w: &Weights,
    toks: &TokenBatch,
    a_levels: f32,
    kv_levels: f32,
    use_had: bool,
) -> Result<Mat> {
    ensure_dense(w)?;
    let name = format!("fwdq_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    inputs.push(Value::scalar(a_levels));
    inputs.push(Value::scalar(kv_levels));
    inputs.push(Value::scalar(if use_had { 1.0 } else { 0.0 }));
    let out = rt.run(&name, &inputs)?;
    out[0].to_mat()
}

/// Captured calibration sites from `capture_{cfg}`.
pub struct CapturedSites {
    /// Post-RMSNorm hidden states per site (2L sites), each (B·T, d).
    pub x_sites: Vec<Mat>,
    /// Value-projection outputs per layer (L), each (B·T, kv_dim).
    pub v_sites: Vec<Mat>,
}

pub fn run_capture(rt: &Runtime, w: &Weights, toks: &TokenBatch) -> Result<CapturedSites> {
    ensure_dense(w)?;
    let name = format!("capture_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    let out = rt.run(&name, &inputs)?;
    let unstack = |v: &Value, count: usize| -> Result<Vec<Mat>> {
        let shape = v.shape();
        if shape.len() != 3 || shape[0] != count {
            bail!("capture output shape {shape:?}, expected [{count}, ., .]");
        }
        let (rows, cols) = (shape[1], shape[2]);
        let data = v.f32_data()?;
        Ok((0..count)
            .map(|s| {
                Mat::from_vec(rows, cols, data[s * rows * cols..(s + 1) * rows * cols].to_vec())
            })
            .collect())
    };
    let l = w.cfg.n_layers;
    // out[2] is the parameter-liveness checksum (see aot.py) — ignored.
    Ok(CapturedSites {
        x_sites: unstack(&out[0], 2 * l)?,
        v_sites: unstack(&out[1], l)?,
    })
}

/// One SpinQuant-sim end-to-end Cayley step via `spin_{cfg}`.
/// Returns (R1', M', loss).
pub fn run_spin_step(
    exe: &Executable,
    r1: &Mat,
    m: &Mat,
    w: &Weights,
    toks: &TokenBatch,
    lr: f32,
) -> Result<(Mat, Mat, f32)> {
    let mut inputs = vec![Value::from_mat(r1), Value::from_mat(m)];
    inputs.extend(weight_values(w));
    inputs.push(toks.to_value());
    inputs.push(Value::scalar(lr));
    let out = exe.run(&inputs)?;
    Ok((out[0].to_mat()?, out[1].to_mat()?, out[2].to_scalar()?))
}

/// Adam training state for `train_{cfg}`.
pub struct TrainState {
    pub weights: Weights,
    m: Vec<Mat>,
    v: Vec<Mat>,
    pub t: f32,
}

impl TrainState {
    pub fn new(weights: Weights) -> TrainState {
        let zeros: Vec<Mat> = weights
            .ordered()
            .map(|(_, w)| Mat::zeros(w.rows, w.cols))
            .collect();
        TrainState { m: zeros.clone(), v: zeros, weights, t: 0.0 }
    }

    /// One Adam step via the `train_{cfg}` artifact; returns the loss.
    pub fn step(&mut self, rt: &Runtime, toks: &TokenBatch, lr: f32) -> Result<f32> {
        let name = format!("train_{}", self.weights.cfg.name);
        let exe = rt.load(&name).with_context(|| {
            format!("train artifact for {} (only emitted for the tiny config)", self.weights.cfg.name)
        })?;
        let mut inputs = weight_values(&self.weights);
        inputs.extend(self.m.iter().map(Value::from_mat));
        inputs.extend(self.v.iter().map(Value::from_mat));
        inputs.push(Value::scalar(self.t));
        inputs.push(toks.to_value());
        inputs.push(Value::scalar(lr));
        let out = exe.run(&inputs)?;
        let names: Vec<String> = self.weights.names().to_vec();
        let k = names.len();
        for (i, name) in names.iter().enumerate() {
            self.weights.set(name, out[i].to_mat()?);
            self.m[i] = out[k + i].to_mat()?;
            self.v[i] = out[2 * k + i].to_mat()?;
        }
        self.t = out[3 * k].to_scalar()?;
        out[3 * k + 1].to_scalar()
    }
}

/// Mean NLL → perplexity.
pub fn ppl_from_nll(nll: &Mat) -> f64 {
    let mean: f64 =
        nll.data.iter().map(|&v| v as f64).sum::<f64>() / nll.data.len() as f64;
    mean.exp()
}

/// Load model configs embedded in the manifest (cross-check vs builtin).
pub fn manifest_models(rt: &Runtime, manifest_path: &std::path::Path) -> Result<Vec<ModelConfig>> {
    let _ = rt;
    let text = std::fs::read_to_string(manifest_path)?;
    let j = crate::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
    let models = j
        .get("models")
        .and_then(|m| m.as_obj())
        .context("manifest missing models section")?;
    models
        .iter()
        .map(|(name, spec)| ModelConfig::from_manifest_json(name, spec))
        .collect()
}

// ===========================================================================
// The chunked indexed weight artifact + the out-of-core WeightStore.
// ===========================================================================

/// Magic of the indexed artifact format (`Weights::save` v2).
pub(crate) const INDEX_MAGIC: &[u8; 8] = b"DARTQWT2";

const KIND_DENSE: u8 = 0;
const KIND_PACKED: u8 = 1;

/// Fixed-width tail of an index entry (everything after the name):
/// kind u8 + rows u32 + cols u32 + offset u64 + len u64 + nbytes u64.
/// Write-back patches exactly these bytes in place.
const ENTRY_PATCH_LEN: usize = 1 + 4 + 4 + 8 + 8 + 8;

#[derive(Clone, Debug)]
struct IndexEntry {
    kind: u8,
    rows: u32,
    cols: u32,
    /// Absolute file offset of the tensor blob.
    offset: u64,
    /// Blob byte length.
    len: u64,
    /// Resident bytes of the decoded tensor (`Tensor::nbytes`).
    nbytes: u64,
    /// Absolute file position of this entry's `kind` byte — the start of
    /// the fixed-width patch region rewritten on write-back.
    patch_pos: u64,
}

fn tensor_kind(t: &Tensor) -> u8 {
    match t {
        Tensor::F32(_) => KIND_DENSE,
        Tensor::Packed(_) => KIND_PACKED,
    }
}

fn tensor_to_blob(t: &Tensor) -> Vec<u8> {
    match t {
        Tensor::F32(m) => {
            let mut b = Vec::with_capacity(m.data.len() * 4);
            for v in &m.data {
                b.extend_from_slice(&v.to_le_bytes());
            }
            b
        }
        Tensor::Packed(q) => q.to_bytes(),
    }
}

fn tensor_from_blob(kind: u8, rows: usize, cols: usize, blob: &[u8]) -> Result<Tensor> {
    match kind {
        KIND_DENSE => {
            anyhow::ensure!(
                blob.len() == rows * cols * 4,
                "dense blob is {} bytes, expected {rows}×{cols}×4",
                blob.len()
            );
            let data =
                blob.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
            Ok(Tensor::F32(Mat::from_vec(rows, cols, data)))
        }
        KIND_PACKED => {
            let q = QMat::from_bytes(blob)?;
            anyhow::ensure!(
                q.shape() == (rows, cols),
                "packed blob shape {:?} != index shape ({rows}, {cols})",
                q.shape()
            );
            Ok(Tensor::Packed(q))
        }
        other => bail!("unknown tensor kind tag {other}"),
    }
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write `weights` as a chunked indexed artifact: magic, config name,
/// the per-tensor offset index, then one blob per tensor (raw f32 for
/// dense tensors, native codes + scales for packed ones — bit-identical
/// roundtrip, no dequantize/requantize detour). Blobs are streamed one
/// tensor at a time, so saving never holds more than one tensor's
/// serialization in memory on top of the model itself.
pub fn save_indexed(weights: &Weights, path: &Path) -> Result<()> {
    let mut header: Vec<u8> = Vec::new();
    header.extend_from_slice(INDEX_MAGIC);
    write_str(&mut header, &weights.cfg.name)?;
    let count = weights.names().len();
    header.extend_from_slice(&(count as u32).to_le_bytes());
    let mut patch_pos = Vec::with_capacity(count);
    for (name, t) in weights.ordered_tensors() {
        write_str(&mut header, name)?;
        patch_pos.push(header.len() as u64);
        let (r, c) = t.shape();
        header.push(tensor_kind(t));
        header.extend_from_slice(&(r as u32).to_le_bytes());
        header.extend_from_slice(&(c as u32).to_le_bytes());
        header.extend_from_slice(&0u64.to_le_bytes()); // offset — patched below
        header.extend_from_slice(&0u64.to_le_bytes()); // len — patched below
        header.extend_from_slice(&t.nbytes().to_le_bytes());
    }
    let mut f =
        File::create(path).with_context(|| format!("creating indexed artifact {path:?}"))?;
    f.write_all(&header)?;
    let mut spans = Vec::with_capacity(count);
    let mut cur = header.len() as u64;
    for (_, t) in weights.ordered_tensors() {
        let blob = tensor_to_blob(t);
        f.write_all(&blob)?;
        spans.push((cur, blob.len() as u64));
        cur += blob.len() as u64;
    }
    for (pos, (off, len)) in patch_pos.iter().zip(&spans) {
        f.seek(SeekFrom::Start(pos + 9))?; // skip kind + rows + cols
        f.write_all(&off.to_le_bytes())?;
        f.write_all(&len.to_le_bytes())?;
    }
    Ok(())
}

struct ParsedIndex {
    cfg: ModelConfig,
    order: Vec<String>,
    entries: BTreeMap<String, IndexEntry>,
}

fn read_index(f: &mut File, path: &Path) -> Result<ParsedIndex> {
    f.seek(SeekFrom::Start(0))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != INDEX_MAGIC {
        bail!("{path:?} is not an indexed dartquant artifact");
    }
    let cfg_name = read_str(f)?;
    let cfg = ModelConfig::builtin(&cfg_name)?;
    let count = read_u32(f)? as usize;
    anyhow::ensure!(count <= 1 << 20, "corrupt artifact: {count} tensors");
    // Validate names/shapes against the config here, contextfully — a
    // truncated or stale index must not panic downstream (the in-memory
    // assembly asserts these as internal invariants).
    let valid: std::collections::BTreeSet<String> = cfg.param_names().into_iter().collect();
    let mut order = Vec::with_capacity(count);
    let mut entries = BTreeMap::new();
    for _ in 0..count {
        let name = read_str(f)?;
        anyhow::ensure!(
            valid.contains(&name),
            "{path:?} indexes unknown weight {name:?} for config {cfg_name}"
        );
        let patch_pos = f.stream_position()?;
        let mut kind = [0u8; 1];
        f.read_exact(&mut kind)?;
        let rows = read_u32(f)?;
        let cols = read_u32(f)?;
        let offset = read_u64(f)?;
        let len = read_u64(f)?;
        let nbytes = read_u64(f)?;
        let expect = cfg.param_shape(&name);
        anyhow::ensure!(
            (rows as usize, cols as usize) == expect,
            "{path:?} entry {name:?} has shape ({rows}, {cols}), config expects {expect:?}"
        );
        entries.insert(
            name.clone(),
            IndexEntry { kind: kind[0], rows, cols, offset, len, nbytes, patch_pos },
        );
        order.push(name);
    }
    Ok(ParsedIndex { cfg, order, entries })
}

fn read_blob(f: &mut File, e: &IndexEntry) -> Result<Tensor> {
    f.seek(SeekFrom::Start(e.offset))?;
    let mut buf = vec![0u8; e.len as usize];
    f.read_exact(&mut buf)?;
    tensor_from_blob(e.kind, e.rows as usize, e.cols as usize, &buf)
}

/// Load a whole indexed artifact into memory (the eager counterpart of
/// [`WeightStore::open`]; `Weights::load` dispatches here on the v2
/// magic). Fails if any config parameter is missing.
pub fn load_indexed(path: &Path) -> Result<Weights> {
    let mut f = File::open(path).with_context(|| format!("opening {path:?}"))?;
    let idx = read_index(&mut f, path)?;
    let mut tensors = Vec::with_capacity(idx.order.len());
    for name in &idx.order {
        let e = &idx.entries[name];
        tensors.push((name.clone(), read_blob(&mut f, e)?));
    }
    for n in idx.cfg.param_names() {
        if !idx.entries.contains_key(&n) {
            bail!("artifact {path:?} is missing weight {n:?}");
        }
    }
    Ok(Weights::from_parts(idx.cfg, tensors))
}

/// The smallest resident budget at which every built-in streamed stage
/// fits: the largest single checkout any stage performs — one layer's
/// tensors, or embed + head together (all dense f32; quantization only
/// shrinks tensors). On every built-in config this is a small fraction
/// of the full model (≤ ~1/4), which is what makes out-of-core runs
/// worthwhile — see `docs/STREAMING.md` and the `perf_streaming` bench.
pub fn suggested_resident_budget(cfg: &ModelConfig) -> u64 {
    let bytes = |name: &str| {
        let (r, c) = cfg.param_shape(name);
        (r * c * 4) as u64
    };
    let mut mx = bytes("embed") + bytes("head");
    for l in 0..cfg.n_layers {
        let prefix = format!("l{l}.");
        let mut layer = 0u64;
        for n in cfg.param_names() {
            if n.starts_with(&prefix) {
                layer += bytes(&n);
            }
        }
        mx = mx.max(layer);
    }
    mx
}

struct StoreState {
    file: File,
    entries: BTreeMap<String, IndexEntry>,
}

/// Lazily-loading, evicting view over an indexed weight artifact — the
/// out-of-core weight-ownership primitive behind
/// `Pipeline::builder(..).streaming(true)`.
///
/// Tensors are **checked out** by name ([`WeightStore::checkout`] /
/// [`WeightStore::checkout_layer`]) as a [`WeightLease`]: the store
/// admits the lease's decoded bytes against its `MemoryGate` (blocking
/// while over budget, erroring if the checkout can never fit), reads the
/// blobs, and hands back a partial `Weights`. Dropping the lease
/// releases the bytes; [`WeightLease::commit`] first writes mutated
/// tensors back (appending new blobs and patching the index in place —
/// dense tensors may come back packed). Peak resident weight bytes over
/// the store's lifetime are therefore bounded by the budget, not by
/// model size.
///
/// ```no_run
/// use dartquant::model::{ModelConfig, Weights, WeightStore};
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ModelConfig::builtin("llama2-tiny")?;
/// let weights = Weights::default_synthetic(&cfg, 1);
/// let path = std::env::temp_dir().join("model.dartq");
/// let store = WeightStore::create(&path, &weights, Some(4 << 20))?;
/// // Check one layer out, quantize it, write it back packed:
/// let mut lease = store.checkout_layer(0)?;
/// let names = lease.weights().names().to_vec();
/// for name in names {
///     let q = dartquant::quant::rtn_quantize_qmat(lease.weights().get(&name), 4);
///     lease.weights_mut().set_packed(&name, q);
/// }
/// lease.commit()?; // append packed blobs, patch the index, release bytes
/// assert_eq!(store.resident_bytes(), 0);
/// # Ok(()) }
/// ```
pub struct WeightStore {
    cfg: ModelConfig,
    order: Vec<String>,
    state: Mutex<StoreState>,
    gate: Arc<MemoryGate>,
}

impl WeightStore {
    /// Spill `weights` to `path` as an indexed artifact and open it with
    /// `budget` bytes of resident capacity (`None` = unlimited, still
    /// peak-tracked).
    pub fn create(path: &Path, weights: &Weights, budget: Option<u64>) -> Result<WeightStore> {
        save_indexed(weights, path)?;
        WeightStore::open_with_budget(path, budget)
    }

    /// Open an existing indexed artifact with unlimited resident budget.
    pub fn open(path: &Path) -> Result<WeightStore> {
        WeightStore::open_with_budget(path, None)
    }

    /// Open an existing indexed artifact with a resident-byte budget.
    pub fn open_with_budget(path: &Path, budget: Option<u64>) -> Result<WeightStore> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening weight store {path:?}"))?;
        let idx = read_index(&mut file, path)?;
        Ok(WeightStore {
            cfg: idx.cfg,
            order: idx.order,
            state: Mutex::new(StoreState { file, entries: idx.entries }),
            gate: Arc::new(MemoryGate::new(budget)),
        })
    }

    /// The stored model's configuration.
    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Stored tensor names, in parameter order.
    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// The configured resident budget (`None` = unlimited).
    pub fn budget(&self) -> Option<u64> {
        self.gate.budget()
    }

    /// Decoded bytes currently checked out across all live leases.
    pub fn resident_bytes(&self) -> u64 {
        self.gate.current_bytes()
    }

    /// Peak simultaneously-resident decoded bytes over the store's
    /// lifetime — the number `perf_streaming` compares to the budget.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.gate.peak_bytes()
    }

    /// Total decoded bytes of the stored model (sum of per-tensor
    /// `nbytes` in the index — shrinks as write-backs pack tensors).
    pub fn total_nbytes(&self) -> u64 {
        lock_or_poisoned(&self.state).entries.values().map(|e| e.nbytes).sum()
    }

    /// Check `names` out of the store: blocks until their decoded bytes
    /// fit under the budget (erroring if they never can), then loads the
    /// blobs into a partial `Weights` behind a [`WeightLease`].
    pub fn checkout<S: AsRef<str>>(&self, names: &[S]) -> Result<WeightLease<'_>> {
        let mut bytes = 0u64;
        {
            let st = lock_or_poisoned(&self.state);
            for n in names {
                let e = st
                    .entries
                    .get(n.as_ref())
                    .with_context(|| format!("no weight {:?} in the store", n.as_ref()))?;
                bytes += e.nbytes;
            }
        }
        // Admit before touching the file: blocking on the gate must not
        // hold the store lock, or committing leases could never release
        // capacity.
        let lease = self.gate.admit(bytes).with_context(|| {
            format!("streamed checkout of {} tensors ({bytes} bytes)", names.len())
        })?;
        let mut tensors = Vec::with_capacity(names.len());
        {
            let mut st = lock_or_poisoned(&self.state);
            let StoreState { file, entries } = &mut *st;
            for n in names {
                let e = entries[n.as_ref()].clone();
                let t = read_blob(file, &e)
                    .with_context(|| format!("reading stored weight {:?}", n.as_ref()))?;
                tensors.push((n.as_ref().to_string(), t));
            }
        }
        Ok(WeightLease {
            store: self,
            weights: Weights::from_parts(self.cfg.clone(), tensors),
            bytes,
            dirty: false,
            _lease: lease,
        })
    }

    /// Check out every tensor of layer `l` (attention + FFN, including
    /// MoE router/experts) — the per-layer unit the streamed stages and
    /// scheduler jobs work in.
    pub fn checkout_layer(&self, l: usize) -> Result<WeightLease<'_>> {
        let prefix = format!("l{l}.");
        let names: Vec<&String> = self.order.iter().filter(|n| n.starts_with(&prefix)).collect();
        anyhow::ensure!(!names.is_empty(), "model {} has no layer {l}", self.cfg.name);
        self.checkout(&names)
    }

    /// Append fresh blobs for every tensor in `weights` and patch their
    /// index entries in place (old blobs become dead file space — the
    /// file is a scratch artifact, not an archival format).
    fn write_back(&self, weights: &Weights) -> Result<()> {
        let mut st = lock_or_poisoned(&self.state);
        let StoreState { file, entries } = &mut *st;
        for (name, t) in weights.ordered_tensors() {
            let e = entries
                .get_mut(name)
                .with_context(|| format!("write-back of unknown weight {name:?}"))?;
            anyhow::ensure!(
                (e.rows as usize, e.cols as usize) == t.shape(),
                "write-back shape mismatch for {name}"
            );
            let blob = tensor_to_blob(t);
            let offset = file.seek(SeekFrom::End(0))?;
            file.write_all(&blob)?;
            e.kind = tensor_kind(t);
            e.offset = offset;
            e.len = blob.len() as u64;
            e.nbytes = t.nbytes();
            let mut patch = Vec::with_capacity(ENTRY_PATCH_LEN);
            patch.push(e.kind);
            patch.extend_from_slice(&e.rows.to_le_bytes());
            patch.extend_from_slice(&e.cols.to_le_bytes());
            patch.extend_from_slice(&e.offset.to_le_bytes());
            patch.extend_from_slice(&e.len.to_le_bytes());
            patch.extend_from_slice(&e.nbytes.to_le_bytes());
            file.seek(SeekFrom::Start(e.patch_pos))?;
            file.write_all(&patch)?;
        }
        Ok(())
    }

    /// Load the whole stored model into memory — the in-memory hand-off
    /// at the end of a streamed run (the report wants a `Weights`).
    /// Deliberately bypasses the admission gate: the streamed stages ran
    /// under the budget; materializing the result is the caller's
    /// explicit decision to hold the full model.
    pub fn materialize(&self) -> Result<Weights> {
        let mut st = lock_or_poisoned(&self.state);
        let StoreState { file, entries } = &mut *st;
        let mut tensors = Vec::with_capacity(self.order.len());
        for name in &self.order {
            let e = entries[name].clone();
            tensors.push((name.clone(), read_blob(file, &e)?));
        }
        Ok(Weights::from_parts(self.cfg.clone(), tensors))
    }
}

/// RAII checkout of a subset of a [`WeightStore`]'s tensors: a partial
/// `Weights` plus the gate lease charging its decoded bytes. Drop = plain
/// release (check-in without write-back); [`WeightLease::commit`] writes
/// the checked-out tensors back first when the lease was mutated.
pub struct WeightLease<'s> {
    store: &'s WeightStore,
    weights: Weights,
    bytes: u64,
    dirty: bool,
    _lease: MemoryLease<'s>,
}

impl WeightLease<'_> {
    /// The checked-out tensors as a partial `Weights` (resident names
    /// only — `get`/`tensor` panic for names outside the lease, exactly
    /// like unknown names on a full model).
    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Mutable view; marks the lease dirty, so [`WeightLease::commit`]
    /// writes every checked-out tensor back.
    pub fn weights_mut(&mut self) -> &mut Weights {
        self.dirty = true;
        &mut self.weights
    }

    /// The decoded bytes this lease holds against the store's gate
    /// (fixed at checkout time — the accounting contract in
    /// `docs/STREAMING.md`).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Whether [`WeightLease::weights_mut`] was taken.
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Check the tensors back in: write them back to the store if the
    /// lease is dirty, then release the resident bytes.
    pub fn commit(self) -> Result<()> {
        if self.dirty {
            self.store.write_back(&self.weights)?;
        }
        Ok(())
    }
}

/// Layer-at-a-time forward over a [`WeightStore`]: embed all sequences
/// (embed checked out alone, then released), then per layer check the
/// layer's tensors out, advance every sequence's residual through
/// `forward::block_step` (with a fresh per-layer KV cache — the
/// full-sequence semantics), invoke `after_layer` (quantize-in-place
/// passes mutate the lease here), and commit the lease.
///
/// Because every per-sequence operation is exactly the one `forward_one`
/// runs, the residual streams — and everything `hook` observes — are
/// **bit-identical** to the in-memory forward; only the event order
/// changes (layer-major instead of sequence-major). Peak weight
/// residency is one layer (or the embedding), never the model.
pub fn stream_blocks<H: crate::model::CaptureHook>(
    store: &WeightStore,
    seqs: &[Vec<i32>],
    opt: crate::model::FwdOptions,
    hook: &mut H,
    mut after_layer: impl FnMut(usize, &mut H, &mut WeightLease) -> Result<()>,
) -> Result<()> {
    let cfg = store.cfg().clone();
    let mut xs: Vec<Mat> = {
        let lease = store.checkout(&["embed"])?;
        seqs.iter().map(|s| crate::model::forward::embed_tokens(lease.weights(), s)).collect()
    };
    for l in 0..cfg.n_layers {
        let mut lease = store.checkout_layer(l)?;
        for x in xs.iter_mut() {
            let mut kv = super::kv::LayerKv::for_model(&cfg, opt.kv_levels, false);
            crate::model::forward::block_step(lease.weights(), l, x, &mut kv, opt, hook);
        }
        after_layer(l, hook, &mut lease)?;
        lease.commit()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_layout() {
        let tb = TokenBatch::new(&[vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!((tb.batch, tb.seq), (2, 3));
        assert_eq!(tb.tokens, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(tb.rows()[1], vec![4, 5, 6]);
        assert_eq!(tb.to_value().shape(), vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_batch_panics() {
        TokenBatch::new(&[vec![1], vec![2, 3]]);
    }

    #[test]
    fn ppl_of_constant_nll() {
        let nll = Mat::from_vec(1, 4, vec![2.0; 4]);
        assert!((ppl_from_nll(&nll) - (2.0f64).exp()).abs() < 1e-9);
    }

    #[test]
    fn weight_values_ordered_like_param_names() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let vals = weight_values(&w);
        assert_eq!(vals.len(), cfg.param_names().len());
        assert_eq!(vals[0].shape(), vec![cfg.vocab, cfg.dim]); // embed first
    }

    // ------------------------------------------------ indexed artifact

    fn store_path(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dartquant-test-store");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}-{}.dartq", std::process::id()))
    }

    #[test]
    fn indexed_roundtrip_dense_and_packed() {
        use crate::tensor::{QMat, QuantSpec};
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let mut w = Weights::default_synthetic(&cfg, 3);
        let q = QMat::quantize_rtn(w.get("l1.wd"), QuantSpec::new(4));
        w.set_packed("l1.wd", q.clone());
        let path = store_path("roundtrip");
        save_indexed(&w, &path).unwrap();
        let l = load_indexed(&path).unwrap();
        assert_eq!(l.names(), w.names());
        assert_eq!(l.tensor("l1.wd").as_packed().unwrap(), &q);
        for name in w.names() {
            assert_eq!(l.tensor(name), w.tensor(name), "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn store_checkout_charges_and_releases_exact_bytes() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 5);
        let path = store_path("charge");
        let store = WeightStore::create(&path, &w, None).unwrap();
        assert_eq!(store.total_nbytes(), w.nbytes());
        assert_eq!(store.resident_bytes(), 0);
        let a = store.checkout(&["embed"]).unwrap();
        assert_eq!(a.bytes(), w.tensor("embed").nbytes());
        assert_eq!(store.resident_bytes(), a.bytes());
        let b = store.checkout_layer(0).unwrap();
        assert_eq!(store.resident_bytes(), a.bytes() + b.bytes());
        assert_eq!(a.weights().get("embed").data, w.get("embed").data);
        assert_eq!(b.weights().get("l0.wq").data, w.get("l0.wq").data);
        drop(b);
        assert_eq!(store.resident_bytes(), a.bytes());
        drop(a);
        assert_eq!(store.resident_bytes(), 0);
        assert!(store.peak_resident_bytes() < w.nbytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn store_write_back_repacks_and_shrinks_the_index() {
        use crate::tensor::QuantSpec;
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 7);
        let path = store_path("writeback");
        let store = WeightStore::create(&path, &w, None).unwrap();
        let before = store.total_nbytes();
        let mut lease = store.checkout_layer(2).unwrap();
        let names = lease.weights().names().to_vec();
        assert!(!lease.is_dirty());
        for name in &names {
            let q = crate::tensor::QMat::quantize_rtn(
                lease.weights().get(name),
                QuantSpec::new(4),
            );
            lease.weights_mut().set_packed(name, q);
        }
        assert!(lease.is_dirty());
        lease.commit().unwrap();
        assert_eq!(store.resident_bytes(), 0, "commit releases the lease");
        assert!(store.total_nbytes() < before, "packed write-back shrinks the index");
        // A fresh checkout and a full materialization both see the packed
        // tensors; dense tensors are untouched.
        let again = store.checkout_layer(2).unwrap();
        assert!(again.weights().tensor(&names[0]).as_packed().is_some());
        drop(again);
        let full = store.materialize().unwrap();
        assert!(full.has_packed());
        assert_eq!(full.get("l0.wq").data, w.get("l0.wq").data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn store_budget_blocks_oversized_checkouts() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 9);
        let path = store_path("budget");
        let store = WeightStore::create(&path, &w, Some(64)).unwrap();
        let err = store.checkout(&["embed"]).unwrap_err();
        assert!(format!("{err:#}").contains("memory budget"), "got: {err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn suggested_budget_is_a_small_model_fraction_that_fits_every_stage() {
        for cfg in ModelConfig::all_builtin() {
            let budget = suggested_resident_budget(&cfg);
            let model = cfg.n_params() as u64 * 4;
            assert!(budget < model / 2, "{}: {budget} vs {model}", cfg.name);
            let w = Weights::default_synthetic(&cfg, 1);
            let path = store_path(&format!("fits-{}", cfg.name));
            let store = WeightStore::create(&path, &w, Some(budget)).unwrap();
            for l in 0..cfg.n_layers {
                drop(store.checkout_layer(l).unwrap());
            }
            drop(store.checkout(&["embed", "head"]).unwrap());
            assert!(store.peak_resident_bytes() <= budget);
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn stream_blocks_sees_the_same_sites_as_forward_one() {
        use crate::model::forward::{forward_one, CaptureHook, FwdOptions};
        #[derive(Default)]
        struct Counter {
            x: usize,
            v: usize,
            lin: usize,
        }
        impl CaptureHook for Counter {
            fn on_x_site(&mut self, _s: usize, _h: &Mat) {
                self.x += 1;
            }
            fn on_v_site(&mut self, _l: usize, _v: &Mat) {
                self.v += 1;
            }
            fn on_linear_input(&mut self, _n: &str, _x: &Mat) {
                self.lin += 1;
            }
        }
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 13);
        let path = store_path("stream");
        let store =
            WeightStore::create(&path, &w, Some(suggested_resident_budget(&cfg))).unwrap();
        let seqs: Vec<Vec<i32>> = vec![(0..24).collect(), (5..29).collect()];
        let mut streamed = Counter::default();
        stream_blocks(&store, &seqs, FwdOptions::FP, &mut streamed, |_, _, _| Ok(())).unwrap();
        let mut inmem = Counter::default();
        for s in &seqs {
            forward_one(&w, s, FwdOptions::FP, &mut inmem);
        }
        assert_eq!((streamed.x, streamed.v, streamed.lin), (inmem.x, inmem.v, inmem.lin));
        assert_eq!(store.resident_bytes(), 0);
        std::fs::remove_file(path).ok();
    }
}
