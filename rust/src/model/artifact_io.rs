//! Glue between `Weights`/token batches and the PJRT artifact signatures:
//! builds the ordered `Value` input lists for `fwd_*`, `fwdq_*`,
//! `capture_*`, `spin_*` and `train_*` entry points, and unpacks their
//! outputs.

use super::config::ModelConfig;
use super::weights::Weights;
use crate::runtime::{Executable, Runtime, Value};
use crate::tensor::Mat;
use anyhow::{bail, Context, Result};

/// Token batch with the fixed artifact geometry (B, T).
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>, // row-major (B, T)
}

impl TokenBatch {
    pub fn new(seqs: &[Vec<i32>]) -> TokenBatch {
        assert!(!seqs.is_empty());
        let seq = seqs[0].len();
        assert!(seqs.iter().all(|s| s.len() == seq), "ragged batch");
        TokenBatch {
            batch: seqs.len(),
            seq,
            tokens: seqs.iter().flatten().copied().collect(),
        }
    }

    pub fn rows(&self) -> Vec<Vec<i32>> {
        self.tokens.chunks(self.seq).map(|c| c.to_vec()).collect()
    }

    pub fn to_value(&self) -> Value {
        Value::from_i32(vec![self.batch, self.seq], self.tokens.clone())
    }
}

/// Weight tensors as ordered artifact inputs (dense models only; the
/// artifact entry points bail first when the model holds packed weights,
/// which cannot feed the f32-shaped artifact signatures).
pub fn weight_values(w: &Weights) -> Vec<Value> {
    w.ordered().map(|(_, m)| Value::from_mat(m)).collect()
}

/// Contextful guard for the artifact entry points: packed models must
/// evaluate through the native forward instead.
fn ensure_dense(w: &Weights) -> Result<()> {
    anyhow::ensure!(
        !w.has_packed(),
        "model '{}' holds packed weights, which cannot feed the PJRT artifacts \
         (dense f32 inputs) — evaluate with the native path (eval::ppl_native, \
         zeroshot::*_native) or rerun the pipeline without --packed",
        w.cfg.name
    );
    Ok(())
}

/// Run `fwd_{cfg}`: per-position NLL (B, T-1).
pub fn run_fwd(rt: &Runtime, w: &Weights, toks: &TokenBatch) -> Result<Mat> {
    ensure_dense(w)?;
    let name = format!("fwd_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    let out = rt.run(&name, &inputs)?;
    out[0].to_mat()
}

/// Run `fwdq_{cfg}` with activation/KV fake-quant and optional online
/// Hadamard (wd must be pre-fused when `use_had`).
pub fn run_fwdq(
    rt: &Runtime,
    w: &Weights,
    toks: &TokenBatch,
    a_levels: f32,
    kv_levels: f32,
    use_had: bool,
) -> Result<Mat> {
    ensure_dense(w)?;
    let name = format!("fwdq_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    inputs.push(Value::scalar(a_levels));
    inputs.push(Value::scalar(kv_levels));
    inputs.push(Value::scalar(if use_had { 1.0 } else { 0.0 }));
    let out = rt.run(&name, &inputs)?;
    out[0].to_mat()
}

/// Captured calibration sites from `capture_{cfg}`.
pub struct CapturedSites {
    /// Post-RMSNorm hidden states per site (2L sites), each (B·T, d).
    pub x_sites: Vec<Mat>,
    /// Value-projection outputs per layer (L), each (B·T, kv_dim).
    pub v_sites: Vec<Mat>,
}

pub fn run_capture(rt: &Runtime, w: &Weights, toks: &TokenBatch) -> Result<CapturedSites> {
    ensure_dense(w)?;
    let name = format!("capture_{}", w.cfg.name);
    let mut inputs = weight_values(w);
    inputs.push(toks.to_value());
    let out = rt.run(&name, &inputs)?;
    let unstack = |v: &Value, count: usize| -> Result<Vec<Mat>> {
        let shape = v.shape();
        if shape.len() != 3 || shape[0] != count {
            bail!("capture output shape {shape:?}, expected [{count}, ., .]");
        }
        let (rows, cols) = (shape[1], shape[2]);
        let data = v.f32_data()?;
        Ok((0..count)
            .map(|s| {
                Mat::from_vec(rows, cols, data[s * rows * cols..(s + 1) * rows * cols].to_vec())
            })
            .collect())
    };
    let l = w.cfg.n_layers;
    // out[2] is the parameter-liveness checksum (see aot.py) — ignored.
    Ok(CapturedSites {
        x_sites: unstack(&out[0], 2 * l)?,
        v_sites: unstack(&out[1], l)?,
    })
}

/// One SpinQuant-sim end-to-end Cayley step via `spin_{cfg}`.
/// Returns (R1', M', loss).
pub fn run_spin_step(
    exe: &Executable,
    r1: &Mat,
    m: &Mat,
    w: &Weights,
    toks: &TokenBatch,
    lr: f32,
) -> Result<(Mat, Mat, f32)> {
    let mut inputs = vec![Value::from_mat(r1), Value::from_mat(m)];
    inputs.extend(weight_values(w));
    inputs.push(toks.to_value());
    inputs.push(Value::scalar(lr));
    let out = exe.run(&inputs)?;
    Ok((out[0].to_mat()?, out[1].to_mat()?, out[2].to_scalar()?))
}

/// Adam training state for `train_{cfg}`.
pub struct TrainState {
    pub weights: Weights,
    m: Vec<Mat>,
    v: Vec<Mat>,
    pub t: f32,
}

impl TrainState {
    pub fn new(weights: Weights) -> TrainState {
        let zeros: Vec<Mat> = weights
            .ordered()
            .map(|(_, w)| Mat::zeros(w.rows, w.cols))
            .collect();
        TrainState { m: zeros.clone(), v: zeros, weights, t: 0.0 }
    }

    /// One Adam step via the `train_{cfg}` artifact; returns the loss.
    pub fn step(&mut self, rt: &Runtime, toks: &TokenBatch, lr: f32) -> Result<f32> {
        let name = format!("train_{}", self.weights.cfg.name);
        let exe = rt.load(&name).with_context(|| {
            format!("train artifact for {} (only emitted for the tiny config)", self.weights.cfg.name)
        })?;
        let mut inputs = weight_values(&self.weights);
        inputs.extend(self.m.iter().map(Value::from_mat));
        inputs.extend(self.v.iter().map(Value::from_mat));
        inputs.push(Value::scalar(self.t));
        inputs.push(toks.to_value());
        inputs.push(Value::scalar(lr));
        let out = exe.run(&inputs)?;
        let names: Vec<String> = self.weights.names().to_vec();
        let k = names.len();
        for (i, name) in names.iter().enumerate() {
            self.weights.set(name, out[i].to_mat()?);
            self.m[i] = out[k + i].to_mat()?;
            self.v[i] = out[2 * k + i].to_mat()?;
        }
        self.t = out[3 * k].to_scalar()?;
        out[3 * k + 1].to_scalar()
    }
}

/// Mean NLL → perplexity.
pub fn ppl_from_nll(nll: &Mat) -> f64 {
    let mean: f64 =
        nll.data.iter().map(|&v| v as f64).sum::<f64>() / nll.data.len() as f64;
    mean.exp()
}

/// Load model configs embedded in the manifest (cross-check vs builtin).
pub fn manifest_models(rt: &Runtime, manifest_path: &std::path::Path) -> Result<Vec<ModelConfig>> {
    let _ = rt;
    let text = std::fs::read_to_string(manifest_path)?;
    let j = crate::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
    let models = j
        .get("models")
        .and_then(|m| m.as_obj())
        .context("manifest missing models section")?;
    models
        .iter()
        .map(|(name, spec)| ModelConfig::from_manifest_json(name, spec))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_layout() {
        let tb = TokenBatch::new(&[vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!((tb.batch, tb.seq), (2, 3));
        assert_eq!(tb.tokens, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(tb.rows()[1], vec![4, 5, 6]);
        assert_eq!(tb.to_value().shape(), vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_batch_panics() {
        TokenBatch::new(&[vec![1], vec![2, 3]]);
    }

    #[test]
    fn ppl_of_constant_nll() {
        let nll = Mat::from_vec(1, 4, vec![2.0; 4]);
        assert!((ppl_from_nll(&nll) - (2.0f64).exp()).abs() < 1e-9);
    }

    #[test]
    fn weight_values_ordered_like_param_names() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let vals = weight_values(&w);
        assert_eq!(vals.len(), cfg.param_names().len());
        assert_eq!(vals[0].shape(), vec![cfg.vocab, cfg.dim]); // embed first
    }
}
